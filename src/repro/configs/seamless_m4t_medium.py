"""seamless-m4t-medium [arXiv:2308.11596; hf]. Enc-dec, 12L+12L d=1024 16H
(kv=16) d_ff=4096 vocab=256206. Speech frontend STUBBED to frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium",
    family="audio",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attention="global",
    frontend="audio_frames",
    remat="full",
    mesh_strategy="dp",
)
