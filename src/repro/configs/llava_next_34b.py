"""llava-next-34b [hf:llava-hf family; unverified] — yi-34b LM backbone,
anyres vision tiling STUBBED to precomputed patch embeddings (2880 tokens).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava_next_34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attention="global",
    frontend="vision_patches",
    num_frontend_tokens=2880,  # anyres: base 576 + 4 tiles x 576
    remat="full",
)
