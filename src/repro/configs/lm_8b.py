"""Paper's 8B local-SGD model (Section 4)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="lm_8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=14336,
    vocab_size=32768,
    attention="global",
    remat="full",
)
