"""yi-34b [arXiv:2403.04652; hf]. llama-arch GQA: 60L d=7168 56H (kv=8)
d_ff=20480 vocab=64000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi_34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attention="global",
    remat="full",
)
