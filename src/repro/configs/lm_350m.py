"""Paper's 350M local-SGD model (Section 4). GPT-style, seq 512."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="lm_350m",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=32768,
    attention="global",
    remat="full",
    mesh_strategy="dp",
)
