"""Paper's 1B local-SGD model (Section 4)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="lm_1b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=32768,
    attention="global",
    remat="full",

)
