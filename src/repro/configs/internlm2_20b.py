"""internlm2-20b [arXiv:2403.17297; hf]. 48L d=6144 48H (GQA kv=8) d_ff=16384
vocab=92544."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    attention="global",
    remat="full",
)
