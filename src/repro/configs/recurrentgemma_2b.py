"""recurrentgemma-2b [arXiv:2402.19427; hf]. 26L d=2560 10H (MQA kv=1,
head_dim 256) d_ff=7680, vocab 256000. RG-LRU + local attn (win 2048), 1:2.
Sub-quadratic => runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attention="local",
    window_size=2048,
    block_pattern=("recurrent", "recurrent", "attention"),
    lru_width=2560,
    act="gelu",
    scan_layers=False,  # mixed block kinds
    remat="full",
    mesh_strategy="dp",
)
