"""Per-architecture configs (assigned pool + the paper's own LM sizes)."""
