"""rwkv6-3b "Finch" [arXiv:2404.05892; hf]. 32L d=2560 attn-free (WKV6,
head_dim 64 => 40 heads) d_ff=8960 vocab=65536. O(1) state => runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6_3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,
    num_kv_heads=0,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention="none",
    remat="full",
    mesh_strategy="dp",
)
