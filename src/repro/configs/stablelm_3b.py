"""stablelm-3b [hf:stabilityai family; unverified]. 32L d=2560 32H (kv=32 =>
full MHA) d_ff=6912 vocab=50304."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm_3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    attention="global",
    remat="full",
    mesh_strategy="dp",
)
