"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf].

94L d_model=4096 64H (GQA kv=4) d_ff=1536/expert, vocab 151936,
MoE 128 experts top-8. head_dim=128 (Qwen3 uses decoupled head_dim).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    attention="global",
    remat="full",
)
