"""qwen2-72b [arXiv:2407.10671; hf]. 80L d=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    attention="global",
    remat="full",
)
