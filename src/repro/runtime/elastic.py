"""Elastic scaling: change device count / cohort size without changing the
program.

The paper's decoupling of *logical* partition size from *physical* devices is
exactly what makes DrJAX elastic: a partition of n groups runs on any m | n
devices. When a pod is lost (or gained):

 1. pick the new mesh from the surviving devices;
 2. (optionally) pick a new cohort size n' compatible with m';
 3. re-jit the same round function for the new (n', mesh) — the *model* and
    *server state* are placement-free pytrees and transfer unchanged.

No resharding of training state is required beyond what pjit does on the new
mesh; client state is per-round (clients re-init from broadcast), so nothing
is lost with the failed pod — the defining fault-tolerance advantage of
MapReduce rounds over long-lived SPMD replicas.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class ElasticSchedule:
    """Cohort-size policy as the device pool grows/shrinks.

    ``groups_per_device`` keeps per-device load constant (weak scaling, the
    paper's Fig. 4 regime).
    """

    groups_per_device: int = 1

    def cohort_size(self, num_devices: int) -> int:
        return max(1, num_devices * self.groups_per_device)


def rescale_partition(
    round_data: dict, old_n: int, new_n: int
) -> dict:
    """Adapt a round's stacked cohort data from n to n' groups.

    Shrink: drop the tail groups (they simply aren't sampled).
    Grow: wrap-around repeat (callers normally just sample a bigger cohort).
    """
    def leaf(x):
        if not hasattr(x, "shape") or x.ndim == 0 or x.shape[0] != old_n:
            return x
        if new_n <= old_n:
            return x[:new_n]
        reps = -(-new_n // old_n)
        return np.concatenate([x] * reps, axis=0)[:new_n]

    return jax.tree_util.tree_map(leaf, round_data)


def available_mesh_shapes(num_devices: int,
                          model_parallelism: int) -> List[Tuple[int, int]]:
    """All viable (data, model) mesh shapes for a (possibly degraded) pool.

    Tries the requested model parallelism first, then every halved fallback
    down to 1, keeping each shape that tiles the device pool exactly. The
    first entry is the preferred shape; later entries trade model parallelism
    for data parallelism (useful when the degraded pool can't tile the
    original model-parallel group).
    """
    shapes: List[Tuple[int, int]] = []
    mp = model_parallelism
    while mp >= 1:
        if num_devices % mp == 0:
            shape = (num_devices // mp, mp)
            if shape not in shapes:
                shapes.append(shape)
        if mp == 1:
            break
        mp //= 2
    return shapes
