"""Elastic scaling: change device count / cohort size without changing the
program.

The paper's decoupling of *logical* partition size from *physical* devices is
exactly what makes DrJAX elastic: a partition of n groups runs on any m | n
devices. When a pod is lost (or gained):

 1. pick the new mesh from the surviving devices;
 2. (optionally) pick a new cohort size n' compatible with m';
 3. re-jit the same round function for the new (n', mesh) — the *model* and
    *server state* are placement-free pytrees and transfer unchanged.

No resharding of training state is required beyond what pjit does on the new
mesh; client state is per-round (clients re-init from broadcast), so nothing
is lost with the failed pod — the defining fault-tolerance advantage of
MapReduce rounds over long-lived SPMD replicas.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ElasticSchedule:
    """Cohort-size policy as the device pool grows/shrinks.

    ``groups_per_device`` keeps per-device load constant (weak scaling, the
    paper's Fig. 4 regime).
    """

    groups_per_device: int = 1

    def cohort_size(self, num_devices: int) -> int:
        return max(1, num_devices * self.groups_per_device)


def rescale_partition(
    round_data: dict, old_n: int, new_n: int
) -> dict:
    """Adapt a round's stacked cohort data from n to n' groups.

    Shrink: drop the tail groups (they simply aren't sampled).
    Grow: wrap-around repeat (callers normally just sample a bigger cohort).
    """
    def leaf(x):
        if not hasattr(x, "shape") or x.ndim == 0 or x.shape[0] != old_n:
            return x
        if new_n <= old_n:
            return x[:new_n]
        reps = -(-new_n // old_n)
        return np.concatenate([x] * reps, axis=0)[:new_n]

    return jax.tree_util.tree_map(leaf, round_data)


def make_elastic_hierarchical_round(
    loss_fn: Callable,
    client_opt,
    server_opt,
    cfg,
    *,
    loops: str = "native",
    donate_cross: bool = False,
    straggler_mask: bool = False,
):
    """Pod-hierarchical local SGD that survives pod dropout WITHOUT
    recompiling the per-client leg.

    Numerically equivalent to
    :func:`repro.algorithms.rounds.make_hierarchical_local_sgd_round`
    (uncompressed path), but compiled per placement level through the
    executor's split cache (:class:`repro.runtime.executor.
    ElasticHierarchicalRound`): the per-client leg is one compiled per-pod
    plan — ``cfg.partition_size`` clients, shapes independent of the pod
    count — dispatched once per pod; the cross-pod leg (mean of pod partials
    + server update) is a small executable keyed by the pod count. The
    returned object's ``step(params, server_state, round_data)`` accepts
    ``round_data`` leaves of shape ``(num_pods, clients_per_pod, ...)`` for
    ANY ``num_pods``, so a shrunken cohort after a pod loss re-uses the
    cached client executable and recompiles only the cross-pod leg.

    ``straggler_mask=True`` makes the round deadline-masked end to end:
    ``step`` then takes ``round_data = {"data": <leaves (num_pods,
    clients_per_pod, ...)>, "mask": (num_pods, clients_per_pod)}``. The
    per-pod leg reduces with ``drjax.masked_reduce_mean`` (an unbiased mean
    over that pod's finishers; a fully-dropped pod yields zeros) and also
    reduces the finisher count, and the cross-pod leg weights each pod
    partial by its finisher count — so the composition equals the flat
    masked mean over ALL finishers (the unbiasedness invariant the chaos
    soak asserts against :func:`repro.algorithms.rounds.
    make_local_sgd_round`'s masked path). The mask is data, not control
    flow: shapes are fixed per pod count and the per-client leg never
    recompiles when the finisher set changes.
    """
    from repro import core as drjax
    from repro.algorithms.rounds import _make_client_update
    from repro.optim.optimizers import apply_updates
    from repro.runtime.executor import ElasticHierarchicalRound

    client_update = _make_client_update(loss_fn, client_opt, cfg)

    program = drjax.program(
        partition_size=cfg.partition_size,
        partition_axes=cfg.partition_axes,
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )

    if straggler_mask:

        @program
        def client_leg(global_params, pod_batch):
            # Masked intra-pod leg: unbiased mean over the pod's finishers
            # plus the finisher count (the cross-pod weighting).
            params_b = drjax.broadcast(global_params)
            deltas, losses = drjax.map_fn(
                client_update, (params_b, pod_batch["data"])
            )
            mask = pod_batch["mask"]
            return (
                drjax.masked_reduce_mean(deltas, mask),
                drjax.masked_reduce_mean(losses, mask),
                drjax.reduce_sum(mask),
            )

        def cross_leg(global_params, server_state, partials):
            # Finisher-weighted cross-pod mean: sum_p(fin_p * mean_p) /
            # sum_p(fin_p) == the flat masked mean over all finishers. An
            # all-dropped cohort (every weight zero) yields zeros, matching
            # masked_reduce_mean's zero-weight contract.
            pod_deltas, pod_losses, pod_fin = partials
            total = jnp.sum(pod_fin)
            denom = jnp.maximum(total, 1.0)

            def wmean(d):
                w = pod_fin.reshape((-1,) + (1,) * (d.ndim - 1))
                s = jnp.sum(d * w, axis=0) / denom
                return jnp.where(total > 0, s, jnp.zeros_like(s))

            mean_delta = jax.tree_util.tree_map(wmean, pod_deltas)
            mean_loss = wmean(pod_losses)
            updates, new_server_state = server_opt.update(
                mean_delta, server_state, global_params
            )
            new_params = apply_updates(global_params, updates)
            return new_params, new_server_state, {
                "loss": mean_loss,
                "finishers": total,
            }

    else:

        @program
        def client_leg(global_params, pod_data):
            # The per-pod program: intra-pod leg of the hierarchical round.
            params_b = drjax.broadcast(global_params)
            deltas, losses = drjax.map_fn(client_update, (params_b, pod_data))
            return drjax.reduce_mean(deltas), drjax.reduce_mean(losses)

        def cross_leg(global_params, server_state, partials):
            # Cross-pod leg: mean of the pod partials (the bytes that cross
            # the DCN) + the server optimizer step.
            pod_deltas, pod_losses = partials
            mean_delta = jax.tree_util.tree_map(
                lambda d: jnp.mean(d, axis=0), pod_deltas
            )
            updates, new_server_state = server_opt.update(
                mean_delta, server_state, global_params
            )
            new_params = apply_updates(global_params, updates)
            return new_params, new_server_state, {
                "loss": jnp.mean(pod_losses, 0)
            }

    return ElasticHierarchicalRound(
        client_leg,
        cross_leg,
        clients_per_pod=cfg.partition_size,
        loops=loops,
        donate_cross=donate_cross,
    )


def available_mesh_shapes(num_devices: int,
                          model_parallelism: int = 1,
                          *,
                          placements=None) -> List:
    """All viable mesh shapes for a (possibly degraded) device pool.

    Tries the requested model parallelism first, then every halved fallback
    down to 1, keeping each shape that tiles the device pool exactly. The
    first entry is the preferred shape; later entries trade model parallelism
    for data parallelism (useful when the degraded pool can't tile the
    original model-parallel group).

    Legacy form (``placements=None``): returns ``(data, model)`` int pairs
    for a flat pool — unchanged historical behavior.

    With ``placements`` (any spec :func:`repro.launch.mesh.level_axes_for`
    accepts): the N-level generalization. Every level but the OUTERMOST
    keeps its size (the inner levels are fast-interconnect groups a dropout
    does not re-tile); the outermost level absorbs the degraded pool. Each
    entry is ``(shape, axes)`` with axis names from ``level_axes_for`` — the
    axis-tuple literals stay in ``launch/mesh.py`` so the
    ``mesh-axes-literal`` lint covers this path too.
    """
    if placements is None:
        shapes: List[Tuple[int, int]] = []
        mp = model_parallelism
        while mp >= 1:
            if num_devices % mp == 0:
                shape = (num_devices // mp, mp)
                if shape not in shapes:
                    shapes.append(shape)
            if mp == 1:
                break
            mp //= 2
        return shapes

    from repro.launch.mesh import _normalize_stack, level_axes_for

    stack = _normalize_stack(placements)
    if not stack:
        raise ValueError("placements must not be empty")
    level_axes = level_axes_for(stack)
    inner_sizes = tuple(s for _, s, _ in stack[1:])
    inner = 1
    for s in inner_sizes:
        inner *= s
    out: List[Tuple[Tuple[int, ...], Tuple[str, ...]]] = []
    mp = model_parallelism
    while mp >= 1:
        denom = inner * mp
        if denom and num_devices % denom == 0 and num_devices >= denom:
            shape: Tuple[int, ...] = (num_devices // denom,) + inner_sizes
            axes: Tuple[str, ...] = level_axes
            if model_parallelism > 1:
                shape = shape + (mp,)
                axes = axes + ("model",)
            if (shape, axes) not in out:
                out.append((shape, axes))
        if mp == 1:
            break
        mp //= 2
    return out


def pod_device_pool(num_pods: int, clients_per_pod: int,
                    devices=None) -> np.ndarray:
    """The host's devices as a ``(num_pods, clients_per_pod)`` object array.

    Row p holds pod p's local devices — the assignment the full
    ``{"pods": P, "clients": m}`` mesh factorizes over, and the unit of
    loss when a pod drops: :func:`mesh_for_surviving_pods` rebuilds the
    degraded mesh from the surviving rows.
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    need = num_pods * clients_per_pod
    if len(devs) < need:
        raise ValueError(
            f"pod pool needs {need} devices ({num_pods} pods x "
            f"{clients_per_pod} clients) but only {len(devs)} are available"
        )
    pool = np.empty((num_pods, clients_per_pod), dtype=object)
    for i in range(num_pods):
        for j in range(clients_per_pod):
            pool[i, j] = devs[i * clients_per_pod + j]
    return pool


def mesh_for_surviving_pods(pool: np.ndarray, alive) -> jax.sharding.Mesh:
    """Degraded ``(pod, data)`` mesh over the surviving pods of ``pool``.

    ``alive`` is the ordered tuple of surviving pod ids (rows of ``pool``).
    The mesh keeps the per-pod client dimension intact — a dropout removes
    whole rows, never re-tiles within a pod — and goes through
    :func:`repro.launch.mesh.mesh_for_placements`'s ``devices=`` subset
    path so any N-level stack would factorize the same way.
    """
    from repro.launch.mesh import mesh_for_placements

    alive = tuple(int(a) for a in alive)
    if not alive:
        raise ValueError("need at least one surviving pod to build a mesh")
    sub = pool[list(alive), :]
    return mesh_for_placements(
        {"pods": sub.shape[0], "clients": sub.shape[1]},
        devices=sub.reshape(-1),
    )
