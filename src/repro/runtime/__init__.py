"""Distributed runtime: compiled execution, fault tolerance, elasticity."""

from .executor import (
    CompiledPlan,
    ElasticHierarchicalRound,
    TraceCounter,
    clear_executor_cache,
    compile_plan,
    fuse_stages,
    plan_fingerprint,
)
from .failure import FailureInjector, run_with_recovery
from .stragglers import StragglerSimulator, straggler_mask
from .elastic import (
    ElasticSchedule,
    make_elastic_hierarchical_round,
    rescale_partition,
)

__all__ = [
    "CompiledPlan",
    "ElasticHierarchicalRound",
    "TraceCounter",
    "clear_executor_cache",
    "compile_plan",
    "fuse_stages",
    "plan_fingerprint",
    "FailureInjector",
    "run_with_recovery",
    "StragglerSimulator",
    "straggler_mask",
    "ElasticSchedule",
    "make_elastic_hierarchical_round",
    "rescale_partition",
]
