"""Distributed runtime: compiled execution, fault tolerance, elasticity."""

from .executor import (
    CompiledPlan,
    ElasticHierarchicalRound,
    TraceCounter,
    clear_executor_cache,
    compile_plan,
    fuse_stages,
    plan_fingerprint,
)
from .chaos import ChaosConfig, ChaosReport, ChaosSchedule, run_chaos_soak
from .failure import (
    DEFAULT_RECOVERABLE,
    FailureInjector,
    SimulatedDeviceFailure,
    run_with_recovery,
)
from .stragglers import (
    StragglerSimulator,
    effective_round_time,
    straggler_mask,
)
from .elastic import (
    ElasticSchedule,
    make_elastic_hierarchical_round,
    rescale_partition,
)

__all__ = [
    "CompiledPlan",
    "ElasticHierarchicalRound",
    "TraceCounter",
    "clear_executor_cache",
    "compile_plan",
    "fuse_stages",
    "plan_fingerprint",
    "ChaosConfig",
    "ChaosReport",
    "ChaosSchedule",
    "run_chaos_soak",
    "DEFAULT_RECOVERABLE",
    "FailureInjector",
    "SimulatedDeviceFailure",
    "run_with_recovery",
    "StragglerSimulator",
    "effective_round_time",
    "straggler_mask",
    "ElasticSchedule",
    "make_elastic_hierarchical_round",
    "rescale_partition",
]
