"""Distributed runtime: fault tolerance, stragglers, elastic scaling."""

from .failure import FailureInjector, run_with_recovery
from .stragglers import StragglerSimulator, straggler_mask
from .elastic import ElasticSchedule, rescale_partition

__all__ = [
    "FailureInjector",
    "run_with_recovery",
    "StragglerSimulator",
    "straggler_mask",
    "ElasticSchedule",
    "rescale_partition",
]
