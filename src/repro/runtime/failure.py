"""Failure injection + checkpoint-restart recovery loop.

At pod scale, node failures are routine; the recovery contract here is the
standard one: on a step failure, restore the latest complete checkpoint and
replay from there (the data pipeline is deterministic in the step index, so
replay is exact). ``run_with_recovery`` is the driver used by
``launch/train.py``; ``FailureInjector`` simulates device loss in tests and
examples.
"""

from __future__ import annotations

import logging
from typing import Any, Callable, Optional, Tuple

logger = logging.getLogger(__name__)


class SimulatedDeviceFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises SimulatedDeviceFailure at the given step indices (once each)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.failures = 0

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise SimulatedDeviceFailure(f"injected failure at step {step}")


def run_with_recovery(
    step_fn: Callable[[int, Any], Any],
    init_state: Any,
    num_steps: int,
    checkpoint_mgr,
    *,
    checkpoint_every: int = 10,
    max_restarts: int = 5,
    state_metadata: Optional[Callable[[Any], dict]] = None,
    on_restore: Optional[Callable[[Any, dict], Any]] = None,
) -> Tuple[Any, dict]:
    """Run ``state = step_fn(step, state)`` for num_steps with restart-on-fail.

    Returns (final_state, stats). Steps are 0-indexed; checkpoints are taken
    *after* the step completes and record ``step + 1`` as the resume point.
    """
    stats = {"restarts": 0, "completed_steps": 0}
    state = init_state
    step = 0
    restored = checkpoint_mgr.restore_latest(state)
    if restored is not None:
        step, state, meta = restored
        if on_restore is not None:
            state = on_restore(state, meta)
        logger.info("resumed from checkpoint at step %d", step)

    restarts = 0
    while step < num_steps:
        try:
            state = step_fn(step, state)
            stats["completed_steps"] += 1
            step += 1
            if step % checkpoint_every == 0 or step == num_steps:
                meta = state_metadata(state) if state_metadata else {}
                checkpoint_mgr.save(step, state, metadata=meta, blocking=False)
        except Exception as e:  # noqa: BLE001 — any device failure
            restarts += 1
            stats["restarts"] = restarts
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded max_restarts={max_restarts}"
                ) from e
            logger.warning("step %d failed (%s); restoring", step, e)
            restored = checkpoint_mgr.restore_latest(state)
            if restored is None:
                # no checkpoint yet: restart from the initial state
                state, step = init_state, 0
            else:
                step, state, meta = restored
                if on_restore is not None:
                    state = on_restore(state, meta)
    checkpoint_mgr.wait()
    return state, stats
