"""Failure injection + checkpoint-restart recovery loop.

At pod scale, node failures are routine; the recovery contract here is the
standard one: on a step failure, restore the latest complete checkpoint and
replay from there (the data pipeline is deterministic in the step index, so
replay is exact). ``run_with_recovery`` is the driver used by
``launch/train.py`` and the chaos soak harness (``runtime/chaos.py``);
``FailureInjector`` simulates device loss in tests and examples.

Recovery policy:

 * only exceptions in the ``recoverable`` allowlist trigger a
   restore-and-replay — programming errors (``TypeError``/``ValueError``/...)
   propagate immediately instead of burning ``max_restarts`` on an error
   that every replay will hit again;
 * restarts back off exponentially (``backoff_base_s * 2**(restart-1)``,
   capped) so a crash-looping fleet does not hammer the checkpoint store;
 * ``stats["completed_steps"]`` counts *forward progress* (high-water mark
   of the step counter), never replayed work — a restart from scratch
   replays steps without re-counting them; ``stats["replayed_steps"]``
   counts the replays separately.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Optional, Tuple, Type

logger = logging.getLogger(__name__)


class SimulatedDeviceFailure(RuntimeError):
    pass


#: Default restart allowlist: injected/real device failures surface as
#: RuntimeError subclasses (XlaRuntimeError included); anything else is a
#: programming bug and should fail fast.
DEFAULT_RECOVERABLE: Tuple[Type[BaseException], ...] = (
    SimulatedDeviceFailure,
    RuntimeError,
)


class FailureInjector:
    """Raises SimulatedDeviceFailure at the given step indices (once each)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.failures = 0

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise SimulatedDeviceFailure(f"injected failure at step {step}")


def run_with_recovery(
    step_fn: Callable[[int, Any], Any],
    init_state: Any,
    num_steps: int,
    checkpoint_mgr,
    *,
    checkpoint_every: int = 10,
    max_restarts: int = 5,
    recoverable: Tuple[Type[BaseException], ...] = DEFAULT_RECOVERABLE,
    backoff_base_s: float = 0.0,
    backoff_cap_s: float = 30.0,
    state_metadata: Optional[Callable[[Any], dict]] = None,
    on_restore: Optional[Callable[[Any, dict], Any]] = None,
    on_recovery: Optional[Callable[[int, Optional[int]], None]] = None,
) -> Tuple[Any, dict]:
    """Run ``state = step_fn(step, state)`` for num_steps with restart-on-fail.

    Returns (final_state, stats). Steps are 0-indexed; checkpoints are taken
    *after* the step completes and record ``step + 1`` as the resume point
    (the resume step is also injected into the checkpoint metadata under
    ``"step"``, so ``on_restore`` callbacks can see where they landed).

    Only exceptions matching ``recoverable`` trigger a restore; everything
    else propagates. ``backoff_base_s > 0`` sleeps
    ``min(backoff_cap_s, backoff_base_s * 2**(restart-1))`` before each
    restore.

    stats keys: ``restarts``, ``scratch_restarts`` (restarts with no
    checkpoint to restore), ``completed_steps`` (unique forward progress,
    replays excluded), ``replayed_steps``, ``backoff_s``.

    ``on_recovery(restart_index, restored_step_or_None)`` fires after every
    recovery restore (1-indexed restart counter; ``None`` means a
    from-scratch restart) — the observation point chaos harnesses use to
    audit which checkpoint each failure actually fell back to.
    """
    stats = {
        "restarts": 0,
        "scratch_restarts": 0,
        "completed_steps": 0,
        "replayed_steps": 0,
        "backoff_s": 0.0,
    }
    state = init_state
    step = 0
    restored = checkpoint_mgr.restore_latest(state)
    if restored is not None:
        step, state, meta = restored
        if on_restore is not None:
            state = on_restore(state, meta)
        logger.info("resumed from checkpoint at step %d", step)

    start_step = step
    high_water = step  # completed_steps counts progress past this, once
    restarts = 0
    while step < num_steps:
        try:
            state = step_fn(step, state)
            step += 1
            if step > high_water:
                high_water = step
                stats["completed_steps"] = high_water - start_step
            else:
                stats["replayed_steps"] += 1
            if step % checkpoint_every == 0 or step == num_steps:
                meta = state_metadata(state) if state_metadata else {}
                meta = dict(meta, step=step)
                checkpoint_mgr.save(step, state, metadata=meta, blocking=False)
        except recoverable as e:
            restarts += 1
            stats["restarts"] = restarts
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded max_restarts={max_restarts}"
                ) from e
            if backoff_base_s > 0.0:
                delay = min(backoff_cap_s, backoff_base_s * 2 ** (restarts - 1))
                stats["backoff_s"] += delay
                time.sleep(delay)
            logger.warning("step %d failed (%s); restoring", step, e)
            restored = checkpoint_mgr.restore_latest(state)
            if restored is None:
                # no checkpoint yet: restart from the initial state. The
                # step counter resets but completed_steps does not — the
                # replayed prefix is not new progress.
                state, step = init_state, 0
                stats["scratch_restarts"] += 1
                if on_recovery is not None:
                    on_recovery(restarts, None)
            else:
                step, state, meta = restored
                if on_restore is not None:
                    state = on_restore(state, meta)
                if on_recovery is not None:
                    on_recovery(restarts, step)
    checkpoint_mgr.wait()
    return state, stats
