"""Straggler mitigation: over-provisioned cohorts + deadline-masked reduce.

MapReduce semantics make this clean (vs. synchronous SPMD allreduce, where
one slow worker stalls the step): sample ``n + s`` groups, set a deadline,
and reduce over whichever groups finish. The mask enters the reduction as
weights (``drjax.masked_reduce_mean``), so:

 * the result is an unbiased mean over the finished groups;
 * differentiability is preserved (the mask is data, not control flow);
 * the XLA program is fixed-shape — no recompilation when the set of
   finishers changes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class StragglerSimulator:
    """Log-normal per-group round durations (heavy tail, like real fleets)."""

    median_s: float = 10.0
    sigma: float = 0.4
    seed: int = 23

    def durations(self, round_idx: int, n: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, round_idx]))
        return self.median_s * np.exp(self.sigma * rng.standard_normal(n))


def _clamped_min_finishers(min_finishers: Optional[int], n: int) -> Optional[int]:
    """``min_finishers`` capped at the cohort size (asking for more finishers
    than groups exist can only mean "wait for everyone"), floored at 0."""
    if min_finishers is None:
        return None
    return max(0, min(int(min_finishers), n))


def straggler_mask(durations: np.ndarray, deadline_s: float,
                   min_finishers: Optional[int] = None) -> jnp.ndarray:
    """1.0 for groups finishing before the deadline (always >= min_finishers,
    extending the deadline to the k-th finisher if needed).

    ``min_finishers`` is clamped to the cohort size; ``min_finishers == n``
    therefore keeps every group (the synchronous limit). Without
    ``min_finishers`` an all-miss round yields the all-zero mask — the
    matching reduction (``drjax.masked_reduce_mean``) returns zeros for a
    zero-weight cohort, so the composition stays NaN-free.
    """
    durations = np.asarray(durations)
    mask = durations <= deadline_s
    k = _clamped_min_finishers(min_finishers, durations.size)
    if k and mask.sum() < k:
        kth = np.partition(durations, k - 1)[k - 1]
        mask = durations <= kth
    return jnp.asarray(mask, jnp.float32)


def effective_round_time(durations: np.ndarray, deadline_s: float,
                         min_finishers: Optional[int] = None) -> float:
    """Wall time of the round under deadline dropping.

    Without ``min_finishers`` the round ends at the deadline even when every
    group misses it (you waited the deadline out, then reduced over nobody);
    with it, the round extends to the k-th finisher.
    """
    durations = np.asarray(durations)
    mask = durations <= deadline_s
    k = _clamped_min_finishers(min_finishers, durations.size)
    if k and mask.sum() < k:
        kth = np.partition(durations, k - 1)[k - 1]
        return float(kth)
    return float(min(deadline_s, durations.max(initial=0.0)))
