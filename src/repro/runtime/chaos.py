"""Chaos soak: composed fault injection with production invariants.

The runtime pieces in this package are individually tested, but production
failures compose: a pod drops WHILE a straggler deadline is active WHILE the
latest checkpoint turns out torn WHILE serve traffic shares the fleet. This
module drives a real hierarchical training round
(:func:`repro.runtime.elastic.make_elastic_hierarchical_round`, masked
variant) through :func:`repro.runtime.failure.run_with_recovery` while a
deterministic, seeded :class:`ChaosSchedule` injects overlapping adversity,
and asserts the system's production invariants as hard checks:

* **determinism under recovery** — after device failures, checkpoint
  restores (including skip-and-fall-back over torn/corrupt checkpoints) and
  restart-from-scratch, the final model + server state is BITWISE identical
  to an uninterrupted oracle run of the same schedule;
* **zero retraces under elasticity** — the per-client leg compiles at most
  once for the whole soak; pod dropout/regrowth recompiles only the small
  cross-pod leg (one executable per distinct pod count), and the oracle
  replay adds zero traces of either kind;
* **bounded tail latency under stragglers** — deadline-masked rounds have a
  strictly smaller p99 and p99/p50 ratio than the synchronous
  wait-for-all baseline on the same duration draws;
* **unbiasedness of the masked mean** — on audit rounds the hierarchical
  finisher-weighted composition is checked against the flat
  ``masked_reduce_mean`` reference round over the same cohort;
* **serve isolation** — concurrent bursts through
  :class:`~repro.launch.serve.ContinuousBatchingScheduler` complete every
  request (surviving an injected scheduler fault via
  ``reset_slots`` + resubmit) with trace counts flat after warmup; bursts
  are dispatched while the training round is still in flight, and their
  completion latencies are recorded as the ``serve_p99_contended`` column;
* **crash-consistent checkpointing** — the fault cycle includes mid-write
  writer kills (``kill@<bytes>`` at a seeded offset inside ``arrays.npz``);
  every kill must be survived by a fallback restore strictly below the
  killed step (``mid_write_kills_survived == mid_write_kills_injected``);
* **physical resharding** (``physical_mesh=True``, needs ``num_pods *
  clients_per_pod`` devices, e.g. a ``REPRO_HOST_DEVICES=8`` worker) — the
  soak runs on a real ``(pod, data)`` mesh; every pod dropout/regrowth
  rebuilds a degraded mesh from the surviving devices and migrates the
  server state onto it (``reshards``/``mesh_migrate_ms``), with exactly one
  cross-pod executable per distinct mesh.

``ChaosConfig(minutes=N)`` replaces the fixed round count with a wall-clock
budget: a probe round is timed (:func:`_calibrate_round_s`) and the
schedule rescaled (:func:`scale_config_to_minutes`) so the soak fills ~N
minutes with proportionally scaled fault counts.

Seeding rule (ROADMAP "Chaos soak"): every chaos stream derives from
``np.random.SeedSequence([seed, stream_id, ...])`` so streams are
independent, stable under config changes to OTHER streams, and replayable —
``step_fn`` is deterministic in the round index, which is what makes
restore-and-replay exact and the oracle comparison bitwise.

Entry points: ``run_chaos_soak(ChaosConfig(...))`` returns a
:class:`ChaosReport` (and asserts the invariants unless ``check=False``);
``launch/train.py --chaos`` and ``benchmarks/chaos.py`` wrap it.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.failure import (
    DEFAULT_RECOVERABLE,
    FailureInjector,
    SimulatedDeviceFailure,
    run_with_recovery,
)
from repro.runtime.stragglers import (
    StragglerSimulator,
    effective_round_time,
    straggler_mask,
)

# Stream ids for SeedSequence([seed, stream_id, ...]) — never renumber
# (renumbering silently changes every recorded soak).
STREAM_FAILURES = 1
STREAM_ELASTIC = 2
STREAM_DATA = 3
STREAM_SERVE = 4
STREAM_CKPT = 5


def _rng(*ids: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence(list(ids)))


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Knobs for one soak. Defaults are the CI 'full soak' shape: 48 rounds
    with >= 2 device failures, >= 2 elastic events, straggler deadlines every
    round, 2 checkpoint faults and concurrent serve bursts."""

    rounds: int = 48
    seed: int = 0

    # training problem (tiny linear regression; the *runtime* is under test)
    num_pods: int = 4
    clients_per_pod: int = 2
    local_steps: int = 2
    batch: int = 8
    dim: int = 3  # deliberately != clients_per_pod (plan heuristic)
    client_lr: float = 0.05
    server_momentum: float = 0.9

    # fault injection
    num_device_failures: int = 2
    num_elastic_events: int = 4
    num_ckpt_faults: int = 2

    # stragglers
    straggler_median_s: float = 10.0
    straggler_sigma: float = 0.6
    deadline_pct: float = 90.0
    min_finisher_frac: float = 0.5

    # recovery
    checkpoint_every: int = 8
    keep_last_n: int = 3
    max_restarts: int = 8
    backoff_base_s: float = 0.0
    ckpt_dir: Optional[str] = None  # None -> fresh tempdir

    # serve traffic
    serve_traffic: bool = True
    serve_every: int = 16
    serve_requests: int = 3
    serve_slots: int = 2
    serve_max_new: int = 4
    serve_fault: bool = True
    serve_chunk: int = 8
    serve_arch: str = "stablelm_3b"

    # audits
    audit_every: int = 12

    # physical elasticity: run the masked elastic round on a real
    # (pod, data) mesh over this host's devices so pod dropout exercises
    # live resharding (needs num_pods * clients_per_pod devices, e.g. a
    # REPRO_HOST_DEVICES=8 device-pool worker)
    physical_mesh: bool = False

    # time budget: scale the schedule to ~N minutes of wall clock instead
    # of a fixed round count (calibrated from a probe round at soak start)
    minutes: Optional[float] = None

    def validate(self) -> None:
        if self.rounds < 8:
            raise ValueError(f"need rounds >= 8 for a soak, got {self.rounds}")
        if self.max_restarts <= self.num_device_failures:
            raise ValueError(
                "max_restarts must exceed num_device_failures "
                f"({self.max_restarts} <= {self.num_device_failures})"
            )
        if self.dim == self.clients_per_pod:
            raise ValueError(
                "dim must differ from clients_per_pod (the plan's "
                "partitioned-invar heuristic matches leading dims)"
            )


class ChaosSchedule:
    """Deterministic, seeded schedule of composed adversity.

    Built once from a :class:`ChaosConfig`; every accessor is a pure
    function of ``(seed, round)`` so replay after restore sees exactly the
    data/mask/pod-count the first execution saw.
    """

    def __init__(self, cfg: ChaosConfig, pod_counts: Tuple[int, ...],
                 elastic_events: Tuple[Tuple[int, int, int], ...],
                 failure_rounds: Tuple[int, ...],
                 ckpt_faults: Dict[int, str],
                 serve_rounds: Tuple[int, ...],
                 serve_fault_round: Optional[int],
                 audit_rounds: frozenset,
                 alive_pods: Optional[Tuple[Tuple[int, ...], ...]] = None):
        self.cfg = cfg
        self.pod_counts = pod_counts
        self.elastic_events = elastic_events  # (round, old_pods, new_pods)
        self.failure_rounds = failure_rounds
        self.ckpt_faults = dict(ckpt_faults)  # checkpoint step -> kind
        self.serve_rounds = serve_rounds
        self.serve_fault_round = serve_fault_round
        self.audit_rounds = audit_rounds
        # which pod IDS are alive each round — the physical identity a real
        # mesh reshard needs (pod_counts alone can't say WHICH pod died).
        # Default (logical schedules): the leading pods.
        self.alive_pods = alive_pods or tuple(
            tuple(range(p)) for p in pod_counts
        )
        self._sim = StragglerSimulator(
            median_s=cfg.straggler_median_s,
            sigma=cfg.straggler_sigma,
            seed=cfg.seed,
        )
        # fixed ground-truth weights for the regression data
        self._w_true = _rng(cfg.seed, STREAM_DATA).standard_normal(
            cfg.dim
        ).astype(np.float32)

    @classmethod
    def from_config(cls, cfg: ChaosConfig) -> "ChaosSchedule":
        cfg.validate()
        # --- elastic: alternating drop/regrow at sampled rounds ---
        rng = _rng(cfg.seed, STREAM_ELASTIC)
        lo, hi = 2, cfg.rounds - 1
        k = min(cfg.num_elastic_events, max(0, hi - lo))
        event_at = set(
            int(r)
            for r in rng.choice(np.arange(lo, hi), size=k, replace=False)
        ) if k else set()
        pods: List[int] = []
        events: List[Tuple[int, int, int]] = []
        alive_per_round: List[Tuple[int, ...]] = []
        alive = list(range(cfg.num_pods))
        cur, drop_next = cfg.num_pods, True
        for r in range(cfg.rounds):
            if r in event_at:
                old = cur
                if drop_next and cur > 1:
                    cur -= 1
                elif cur < cfg.num_pods:
                    cur += 1
                else:
                    cur = max(1, cur - 1)
                drop_next = not drop_next
                if cur != old:
                    events.append((r, old, cur))
                    # pod-identity draws come AFTER the event_at choice on
                    # the same stream, so pod_counts/events of existing
                    # recorded schedules are unchanged
                    if cur < old:  # dropout: pick the victim
                        victim = alive[int(rng.integers(len(alive)))]
                        alive.remove(victim)
                    else:  # regrowth: revive a dead pod
                        dead = sorted(set(range(cfg.num_pods)) - set(alive))
                        alive.append(dead[int(rng.integers(len(dead)))])
                        alive.sort()
            pods.append(cur)
            alive_per_round.append(tuple(alive))

        # --- device failures: distinct rounds in [1, rounds) ---
        rng = _rng(cfg.seed, STREAM_FAILURES)
        nf = min(cfg.num_device_failures, cfg.rounds - 1)
        failure_rounds = tuple(
            sorted(
                int(r)
                for r in rng.choice(
                    np.arange(1, cfg.rounds), size=nf, replace=False
                )
            )
        )

        # --- checkpoint faults: break the checkpoint a failure will want.
        # For each failure round r, the restore target is the last
        # checkpoint step <= r; faulting exactly that step guarantees the
        # skip-and-fall-back path runs under real recovery pressure. Kinds
        # cycle mid-write kill / corrupt / torn — the kill offset (drawn
        # from its own stream) lands inside arrays.npz so the writer dies
        # with bytes in flight.
        ckpt_rng = _rng(cfg.seed, STREAM_CKPT)
        faults: Dict[int, str] = {}
        for r in failure_rounds:
            if len(faults) >= cfg.num_ckpt_faults:
                break
            s = (r // cfg.checkpoint_every) * cfg.checkpoint_every
            if s >= cfg.checkpoint_every and s not in faults:
                i = len(faults)
                if i % 3 == 0:
                    faults[s] = f"kill@{int(ckpt_rng.integers(64, 2048))}"
                else:
                    faults[s] = ("corrupt", "torn")[i % 3 - 1]

        # --- serve bursts + one scheduler-level fault ---
        serve_rounds: Tuple[int, ...] = ()
        serve_fault_round = None
        if cfg.serve_traffic:
            serve_rounds = tuple(
                r for r in range(1, cfg.rounds) if r % cfg.serve_every == 0
            )
            if cfg.serve_fault and serve_rounds:
                serve_fault_round = serve_rounds[min(1, len(serve_rounds) - 1)]

        # --- unbiasedness audits: periodic + at every elastic transition ---
        audits = {0} | {
            r for r in range(cfg.rounds) if r % cfg.audit_every == 0
        } | {r for (r, _, _) in events}

        return cls(cfg, tuple(pods), tuple(events), failure_rounds, faults,
                   serve_rounds, serve_fault_round, frozenset(audits),
                   alive_pods=tuple(alive_per_round))

    # ------------------------------------------------------------------
    # per-round accessors (pure in (seed, round))
    # ------------------------------------------------------------------

    def data_for_round(self, r: int, p: int):
        """Cohort batches: leaves (p, clients_per_pod, local_steps, B, ...)."""
        cfg = self.cfg
        rng = _rng(cfg.seed, STREAM_DATA, r)
        shape = (p, cfg.clients_per_pod, cfg.local_steps, cfg.batch)
        x = rng.standard_normal(shape + (cfg.dim,)).astype(np.float32)
        noise = rng.standard_normal(shape).astype(np.float32)
        y = np.einsum("pcsbd,d->pcsb", x, self._w_true) + 0.05 * noise
        return jnp.asarray(x), jnp.asarray(y)

    def round_mask_and_times(self, r: int, p: int):
        """(mask (p, C), masked_round_time_s, synchronous_round_time_s)."""
        cfg = self.cfg
        n = p * cfg.clients_per_pod
        d = self._sim.durations(r, n)
        deadline = float(np.percentile(d, cfg.deadline_pct))
        k = max(1, int(np.ceil(cfg.min_finisher_frac * n)))
        mask = straggler_mask(d, deadline, min_finishers=k)
        masked_t = effective_round_time(d, deadline, min_finishers=k)
        return (
            jnp.reshape(mask, (p, cfg.clients_per_pod)),
            masked_t,
            float(d.max()),
        )

    def serve_requests_for(self, r: int, vocab: int):
        """One burst of serve requests; prompt lengths stay inside the chunk
        buckets the warmup covered (<= 2*chunk - 1), so traces stay flat."""
        from repro.launch.serve import Request

        cfg = self.cfg
        rng = _rng(cfg.seed, STREAM_SERVE, r)
        lens = rng.integers(1, 2 * cfg.serve_chunk, size=cfg.serve_requests)
        return [
            Request(
                rid=i,
                prompt=rng.integers(0, vocab, (int(n),)).astype(np.int32),
                max_new=cfg.serve_max_new,
            )
            for i, n in enumerate(lens)
        ]


@dataclasses.dataclass
class ChaosReport:
    """Everything the soak measured; ``assert_invariants`` is the verdict."""

    rounds: int
    seed: int
    # recovery
    restarts: int
    scratch_restarts: int
    completed_steps: int
    replayed_steps: int
    backoff_s: float
    device_failures: int
    failure_rounds: Tuple[int, ...]
    restores: Tuple[Optional[int], ...]  # restored step per recovery (None=scratch)
    fallback_restores: int
    ckpt_faults_injected: Dict[int, str]
    # elasticity
    elastic_events: Tuple[Tuple[int, int, int], ...]
    pods_seen: Tuple[int, ...]
    client_leg_traces: int
    client_retraces: int
    cross_compiles: int
    oracle_extra_traces: int
    # physical resharding (all zero/False in logical mode)
    physical_mesh: bool
    reshards: int
    mesh_migrate_ms: float
    meshes_seen: int
    # mid-write checkpoint kills
    mid_write_kills_injected: int
    mid_write_kills_survived: int
    # stragglers
    straggler: Dict[str, float]
    # unbiasedness
    audit: Dict[str, Any]
    # training signal
    loss_first: float
    loss_final: float
    # the verdict input
    oracle_bitwise_equal: bool
    serve: Optional[Dict[str, Any]]
    # serve p99 while a training round is in flight on the same devices
    # (None when serve traffic is off)
    serve_p99_contended: Optional[float]
    minutes_budget: Optional[float]
    wall_s: float

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["ckpt_faults_injected"] = {
            str(k): v for k, v in self.ckpt_faults_injected.items()
        }
        return json.loads(json.dumps(d))  # normalize tuples -> lists

    def assert_invariants(self) -> None:
        errs = []
        if not self.oracle_bitwise_equal:
            errs.append(
                "post-recovery state is not bitwise identical to the "
                "uninterrupted oracle run"
            )
        if self.client_retraces != 0:
            errs.append(
                f"per-client leg retraced {self.client_retraces}x across "
                "elastic/recovery events (must be 0)"
            )
        if self.oracle_extra_traces != 0:
            errs.append(
                f"oracle replay added {self.oracle_extra_traces} traces "
                "(executables must be reused)"
            )
        if self.restarts < self.device_failures:
            errs.append(
                f"only {self.restarts} restarts for {self.device_failures} "
                "injected device failures"
            )
        st = self.straggler
        if st["p99_masked_s"] >= st["p99_sync_s"]:
            errs.append(
                "masked p99 round time not below synchronous baseline: "
                f"{st['p99_masked_s']:.3f} >= {st['p99_sync_s']:.3f}"
            )
        if st["tail_ratio_masked"] >= st["tail_ratio_sync"]:
            errs.append(
                "masked p99/p50 not below synchronous p99/p50: "
                f"{st['tail_ratio_masked']:.4f} >= {st['tail_ratio_sync']:.4f}"
            )
        if self.audit["max_rel_err"] > 1e-3:
            errs.append(
                "hierarchical masked mean diverged from flat "
                f"masked_reduce_mean reference: rel err "
                f"{self.audit['max_rel_err']:.2e}"
            )
        if self.ckpt_faults_injected and self.fallback_restores < 1:
            errs.append(
                "checkpoint faults were injected but no restore fell back "
                "past a broken checkpoint"
            )
        if self.mid_write_kills_survived < self.mid_write_kills_injected:
            errs.append(
                f"only {self.mid_write_kills_survived}/"
                f"{self.mid_write_kills_injected} mid-write checkpoint kills "
                "were survived via fallback restore"
            )
        if self.physical_mesh:
            if self.reshards < len(self.elastic_events):
                errs.append(
                    f"only {self.reshards} physical reshards for "
                    f"{len(self.elastic_events)} elastic events (every pod "
                    "change must re-map the mesh)"
                )
            if self.cross_compiles != self.meshes_seen:
                errs.append(
                    "cross-pod executable count != distinct meshes "
                    f"({self.cross_compiles} != {self.meshes_seen}): the "
                    "cache must hold exactly one executable per mesh"
                )
        if self.serve is not None:
            if not self.serve["flat_traces"]:
                errs.append("serve traces grew after the warmup burst")
            if self.serve["completed"] != self.serve["requests"]:
                errs.append(
                    f"serve completed {self.serve['completed']}/"
                    f"{self.serve['requests']} requests"
                )
            if self.serve["faults_injected"] and not self.serve["recoveries"]:
                errs.append("serve fault injected but never recovered")
        if errs:
            raise AssertionError(
                "chaos invariants violated:\n  - " + "\n  - ".join(errs)
            )


def _loss_fn(params, batch):
    x, y = batch
    pred = jnp.einsum("bd,d->b", x, params["w"]) + params["b"]
    return jnp.mean((pred - y) ** 2)


def _init_state(cfg: ChaosConfig, server_opt):
    # Non-weak leaves only: a weak-typed scalar (e.g. jnp.float32(0.0))
    # comes back from checkpoint restore as non-weak numpy, changing the
    # aval key and forcing a client-leg retrace.
    key = jax.random.PRNGKey(cfg.seed)
    params = {
        "w": jax.random.normal(key, (cfg.dim,), jnp.float32),
        "b": jnp.zeros((), jnp.float32),
    }
    return {"params": params, "server": server_opt.init(params)}


def _percentiles(values: List[float]) -> Tuple[float, float]:
    a = np.asarray(values, np.float64)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _calibrate_round_s(run_round) -> float:
    """Seconds per training round: one warmup (compile), two timed runs.

    Module-level so tests can monkeypatch it (a fake calibration makes
    ``minutes`` scaling deterministic)."""
    run_round()
    t0 = time.perf_counter()
    run_round()
    run_round()
    return max((time.perf_counter() - t0) / 2.0, 1e-4)


def scale_config_to_minutes(cfg: ChaosConfig, round_s: float) -> ChaosConfig:
    """Rescale a soak config to a ~``cfg.minutes`` wall-clock budget.

    Pure in ``(cfg, round_s)``: rounds become ``minutes * 60 / round_s``
    (floor 8 — the minimum ``validate`` accepts), fault counts scale
    proportionally (floor 1 for any fault class the template enabled), and
    ``max_restarts`` grows to keep headroom over the scaled failure count.
    ``minutes`` is cleared on the result so the scaling never re-triggers.
    """
    if cfg.minutes is None:
        return cfg
    target = max(8, int(round(cfg.minutes * 60.0 / round_s)))
    factor = target / max(cfg.rounds, 1)

    def scaled(n: int) -> int:
        return max(1, int(round(n * factor))) if n > 0 else 0

    nf = scaled(cfg.num_device_failures)
    return dataclasses.replace(
        cfg,
        rounds=target,
        num_device_failures=nf,
        num_elastic_events=scaled(cfg.num_elastic_events),
        num_ckpt_faults=scaled(cfg.num_ckpt_faults),
        max_restarts=max(cfg.max_restarts, nf + 2),
        minutes=None,
    )


class _ServeTraffic:
    """Lazy serve fleet: a ContinuousBatchingScheduler at a reduced config,
    warmed on a bucket-covering burst, with a one-shot fault armed on the
    schedule's designated burst. Recovery = reset_slots + resubmit."""

    def __init__(self, cfg: ChaosConfig):
        from repro.launch.serve import ContinuousBatchingScheduler, Request
        from repro.models import registry

        self.cfg = cfg
        self.scfg = registry.get_config(cfg.serve_arch).reduced()
        params = registry.init_params(jax.random.PRNGKey(cfg.seed), self.scfg)
        max_len = (2 * cfg.serve_chunk - 1) + cfg.serve_max_new
        self.fault = {"at": None, "injected": 0}

        def hook(idx: int) -> None:
            if self.fault["at"] is not None and idx >= self.fault["at"]:
                self.fault["at"] = None
                self.fault["injected"] += 1
                raise SimulatedDeviceFailure(
                    f"injected serve fault at scheduler step {idx}"
                )

        self.sched = ContinuousBatchingScheduler(
            self.scfg, params, cfg.serve_slots, max_len,
            chunk=cfg.serve_chunk, fault_hook=hook,
        )
        self._request_cls = Request
        # warmup: one burst whose prompt (2*chunk - 1 tokens) touches every
        # power-of-two chunk bucket, plus the decode-only step
        rng = _rng(cfg.seed, STREAM_SERVE)
        warm = [
            self._request_cls(
                rid=i,
                prompt=rng.integers(
                    0, self.scfg.vocab_size, (2 * cfg.serve_chunk - 1,)
                ).astype(np.int32),
                max_new=2,
            )
            for i in range(2)
        ]
        self.sched.run(warm)
        self.warm_traces = (self.sched.prefill_traces,
                            self.sched.decode_traces)
        self.fault_armed_once = False
        self.stats = {
            "bursts": 0,
            "requests": 0,
            "completed": 0,
            "recoveries": 0,
        }
        self._done_rids: Dict[int, set] = {}
        # per-round completion latencies (scheduler clock, arrival 0).
        # Bursts are dispatched while a training round is still in flight
        # on the same devices, so these ARE the contended latencies.
        self._latencies: Dict[int, List[float]] = {}

    def burst(self, r: int, schedule: ChaosSchedule) -> None:
        reqs = schedule.serve_requests_for(r, self.scfg.vocab_size)
        if r == schedule.serve_fault_round and not self.fault_armed_once:
            self.fault_armed_once = True
            self.fault["at"] = self.sched.step_index + 3
        self.stats["bursts"] += 1
        pending = list(reqs)
        all_objs = list(reqs)
        for _ in range(4):
            if not pending:
                break
            try:
                self.sched.run(pending)
                break
            except SimulatedDeviceFailure:
                self.stats["recoveries"] += 1
                self.sched.reset_slots()
                pending = [
                    self._request_cls(
                        rid=q.rid, prompt=q.prompt, max_new=q.max_new
                    )
                    for q in pending
                    if not q.done
                ]
                all_objs.extend(pending)
        else:
            raise RuntimeError("serve burst failed to recover after retries")
        # replay of a burst overwrites its per-round completion record
        self._done_rids[r] = {q.rid for q in all_objs if q.done}
        self._latencies[r] = [
            float(q.t_done) for q in all_objs
            if q.done and q.t_done is not None
        ]

    def report(self, num_rounds_requests: int) -> Dict[str, Any]:
        now = (self.sched.prefill_traces, self.sched.decode_traces)
        completed = sum(len(s) for s in self._done_rids.values())
        lats = [t for r in sorted(self._latencies)
                for t in self._latencies[r]]
        p50, p99 = _percentiles(lats) if lats else (0.0, 0.0)
        return {
            "bursts": self.stats["bursts"],
            "requests": num_rounds_requests,
            "completed": completed,
            "faults_injected": self.fault["injected"],
            "recoveries": self.stats["recoveries"],
            "prefill_traces": now[0],
            "decode_traces": now[1],
            "flat_traces": now == self.warm_traces,
            "p50_contended_s": round(p50, 4),
            "p99_contended_s": round(p99, 4),
        }


def run_chaos_soak(cfg: Optional[ChaosConfig] = None, *,
                   check: bool = True) -> ChaosReport:
    """Run the soak; returns a :class:`ChaosReport` (asserting the
    production invariants first unless ``check=False``)."""
    import tempfile

    from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
    from repro.optim.optimizers import sgd
    from repro.optim.server import fedavg_momentum
    from repro.runtime.elastic import (
        make_elastic_hierarchical_round,
        mesh_for_surviving_pods,
        pod_device_pool,
    )

    t_start = time.time()
    cfg = cfg or ChaosConfig()
    C = cfg.clients_per_pod

    # --- physical elasticity: a real (pod, data) mesh per alive-set -----
    pool = None
    if cfg.physical_mesh:
        need = cfg.num_pods * C
        if jax.device_count() < need:
            raise RuntimeError(
                f"physical_mesh soak needs {need} devices "
                f"({cfg.num_pods} pods x {C} clients) but this process has "
                f"{jax.device_count()}; launch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need} (CPU) or on "
                "a large-enough accelerator worker"
            )
        pool = pod_device_pool(cfg.num_pods, C)
    mesh_cache: Dict[Tuple[int, ...], Any] = {}

    def mesh_for(alive: Tuple[int, ...]):
        # one Mesh OBJECT per alive-set for the whole soak (oracle replay
        # included), so the executor's mesh-keyed caches get stable keys
        if pool is None:
            return None
        if alive not in mesh_cache:
            mesh_cache[alive] = mesh_for_surviving_pods(pool, alive)
        return mesh_cache[alive]

    client_opt = sgd(cfg.client_lr)
    server_opt = fedavg_momentum(1.0, momentum=cfg.server_momentum)
    round_cfg = LocalSGDConfig(
        partition_size=C,
        num_local_steps=cfg.local_steps,
        straggler_mask=True,
    )
    elastic = make_elastic_hierarchical_round(
        _loss_fn, client_opt, server_opt, round_cfg, straggler_mask=True
    )
    init_state = _init_state(cfg, server_opt)

    # --- time budget: calibrate a probe round, rescale the schedule -----
    minutes_budget = cfg.minutes
    if cfg.minutes is not None:
        rng_p = _rng(cfg.seed, STREAM_DATA, 0)
        shape = (cfg.num_pods, C, cfg.local_steps, cfg.batch)
        probe_batch = {
            "data": (
                jnp.asarray(
                    rng_p.standard_normal(shape + (cfg.dim,)).astype(np.float32)
                ),
                jnp.asarray(rng_p.standard_normal(shape).astype(np.float32)),
            ),
            # all-finishers mask, same dtype/shape as the soak's masks so the
            # calibration warmup IS the per-client leg's one compile
            "mask": jnp.ones((cfg.num_pods, C), jnp.float32),
        }
        probe_mesh = mesh_for(tuple(range(cfg.num_pods)))

        def probe_round():
            _, _, m = elastic.step(
                init_state["params"], init_state["server"], probe_batch,
                mesh=probe_mesh,
            )
            float(m["loss"])

        cfg = scale_config_to_minutes(cfg, _calibrate_round_s(probe_round))

    schedule = ChaosSchedule.from_config(cfg)

    # flat masked reference rounds for the unbiasedness audits, one per
    # distinct cohort size (jit cached; state NOT donated — reference reuse)
    flat_cache: Dict[int, Any] = {}

    def flat_round(n: int):
        if n not in flat_cache:
            fcfg = LocalSGDConfig(
                partition_size=n,
                num_local_steps=cfg.local_steps,
                straggler_mask=True,
            )
            flat_cache[n] = jax.jit(
                make_local_sgd_round(_loss_fn, client_opt, server_opt, fcfg)
            )
        return flat_cache[n]

    # --- chaos plumbing -------------------------------------------------
    ckpt_dir = cfg.ckpt_dir or tempfile.mkdtemp(prefix="chaos_ckpt_")
    remaining_faults = dict(schedule.ckpt_faults)
    injected_faults: Dict[int, str] = {}

    def ckpt_fault_hook(step: int) -> Optional[str]:
        kind = remaining_faults.pop(step, None)  # once: replays re-save clean
        if kind is not None:
            injected_faults[step] = kind
        return kind

    mgr = CheckpointManager(
        ckpt_dir, keep_last_n=cfg.keep_last_n, fault_hook=ckpt_fault_hook
    )
    # every recovery's restored step (None for a from-scratch restart),
    # observed through run_with_recovery's on_recovery hook
    recovery_log: List[Optional[int]] = []

    injector = FailureInjector(schedule.failure_rounds)
    fired_failures: List[int] = []

    serve = _ServeTraffic(cfg) if schedule.serve_rounds else None

    # per-round records keyed by round index: replay overwrites with the
    # identical value (step_fn is deterministic in the round), so replays
    # never double-count
    losses: Dict[int, float] = {}
    masked_t: Dict[int, float] = {}
    sync_t: Dict[int, float] = {}
    audit_errs: Dict[int, float] = {}

    def step_fn(r: int, state):
        try:
            injector.check(r)
        except SimulatedDeviceFailure:
            fired_failures.append(r)
            raise
        p = schedule.pod_counts[r]
        x, y = schedule.data_for_round(r, p)
        mask, mt, st_ = schedule.round_mask_and_times(r, p)
        masked_t[r], sync_t[r] = mt, st_
        batch = {"data": (x, y), "mask": mask}
        params, server, metrics = elastic.step(
            state["params"], state["server"], batch,
            mesh=mesh_for(schedule.alive_pods[r]),
        )
        if serve is not None and r in schedule.serve_rounds:
            # dispatch the burst BEFORE syncing on the training loss: the
            # async-dispatched round is still in flight on the same devices,
            # so these latencies measure co-located contention
            serve.burst(r, schedule)
        losses[r] = float(metrics["loss"])
        if r in schedule.audit_rounds:
            n = p * C
            ref_p, _, _ = flat_round(n)(
                state["params"], state["server"],
                (x.reshape((n,) + x.shape[2:]), y.reshape((n,) + y.shape[2:])),
                mask.reshape((n,)),
            )
            errs = jax.tree_util.tree_map(
                lambda a, b: float(
                    np.max(np.abs(np.asarray(a) - np.asarray(b)))
                    / (np.max(np.abs(np.asarray(b))) + 1e-12)
                ),
                params, ref_p,
            )
            audit_errs[r] = max(jax.tree_util.tree_leaves(errs))
        return {"params": params, "server": server}

    final_state, stats = run_with_recovery(
        step_fn,
        init_state,
        cfg.rounds,
        mgr,
        checkpoint_every=cfg.checkpoint_every,
        max_restarts=cfg.max_restarts,
        recoverable=DEFAULT_RECOVERABLE,
        backoff_base_s=cfg.backoff_base_s,
        on_recovery=lambda _i, s: recovery_log.append(s),
    )

    # --- fallback accounting: a recovery fell back iff it restored below
    # (or from scratch instead of) the newest checkpoint its failure round
    # implies must exist ---
    fallbacks = 0
    for r, s in zip(fired_failures, recovery_log):
        expected = (r // cfg.checkpoint_every) * cfg.checkpoint_every
        if expected > 0 and (s is None or s < expected):
            fallbacks += 1

    # --- mid-write kill accounting: every injected kill must have been
    # survived — its step never committed, the manager recorded the death,
    # and the failure that wanted that checkpoint restored strictly below
    # it (or from scratch) ---
    kill_steps = sorted(
        s for s, k in injected_faults.items() if k.startswith("kill@")
    )
    kills_survived = 0
    for s in kill_steps:
        died = s in mgr.killed_writes
        fell_back = any(
            (r // cfg.checkpoint_every) * cfg.checkpoint_every == s
            and (rest is None or rest < s)
            for r, rest in zip(fired_failures, recovery_log)
        )
        if died and fell_back:
            kills_survived += 1

    # physical reshard counters: snapshot BEFORE the oracle replay (the
    # replay re-adopts every mesh and would double-count migrations)
    reshards = elastic.reshard_count
    mesh_migrate_ms = elastic.mesh_migrate_ms
    meshes_seen = elastic.meshes_seen

    # --- oracle: the same schedule, uninterrupted, on the SAME executor —
    # must add zero traces and reproduce the final state bitwise ---
    traces_before = elastic.client_trace_count
    cross_before = elastic.cross_compile_count
    o_state = init_state
    for r in range(cfg.rounds):
        p = schedule.pod_counts[r]
        x, y = schedule.data_for_round(r, p)
        mask, _, _ = schedule.round_mask_and_times(r, p)
        pp, ss, _ = elastic.step(
            o_state["params"], o_state["server"],
            {"data": (x, y), "mask": mask},
            mesh=mesh_for(schedule.alive_pods[r]),
        )
        o_state = {"params": pp, "server": ss}
    oracle_extra = (elastic.client_trace_count - traces_before) + (
        elastic.cross_compile_count - cross_before
    )
    bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
            jax.tree_util.tree_leaves(final_state),
            jax.tree_util.tree_leaves(o_state),
        )
    )

    mp50, mp99 = _percentiles([masked_t[r] for r in sorted(masked_t)])
    sp50, sp99 = _percentiles([sync_t[r] for r in sorted(sync_t)])
    serve_report = (
        serve.report(len(schedule.serve_rounds) * cfg.serve_requests)
        if serve is not None
        else None
    )
    report = ChaosReport(
        rounds=cfg.rounds,
        seed=cfg.seed,
        restarts=stats["restarts"],
        scratch_restarts=stats["scratch_restarts"],
        completed_steps=stats["completed_steps"],
        replayed_steps=stats["replayed_steps"],
        backoff_s=stats["backoff_s"],
        device_failures=injector.failures,
        failure_rounds=tuple(fired_failures),
        restores=tuple(recovery_log),
        fallback_restores=fallbacks,
        ckpt_faults_injected=dict(injected_faults),
        elastic_events=schedule.elastic_events,
        pods_seen=tuple(sorted(set(schedule.pod_counts))),
        client_leg_traces=elastic.client_trace_count,
        client_retraces=max(0, elastic.client_trace_count - 1),
        cross_compiles=elastic.cross_compile_count,
        oracle_extra_traces=oracle_extra,
        physical_mesh=cfg.physical_mesh,
        reshards=reshards,
        mesh_migrate_ms=round(mesh_migrate_ms, 3),
        meshes_seen=meshes_seen,
        mid_write_kills_injected=len(kill_steps),
        mid_write_kills_survived=kills_survived,
        straggler={
            "p50_masked_s": round(mp50, 4),
            "p99_masked_s": round(mp99, 4),
            "p50_sync_s": round(sp50, 4),
            "p99_sync_s": round(sp99, 4),
            "tail_ratio_masked": round(mp99 / mp50, 4),
            "tail_ratio_sync": round(sp99 / sp50, 4),
            "speedup": round(
                sum(sync_t.values()) / max(sum(masked_t.values()), 1e-9), 4
            ),
        },
        audit={
            "rounds": sorted(audit_errs),
            "max_rel_err": max(audit_errs.values()) if audit_errs else 0.0,
        },
        loss_first=losses.get(0, float("nan")),
        loss_final=losses.get(cfg.rounds - 1, float("nan")),
        oracle_bitwise_equal=bool(bitwise),
        serve=serve_report,
        serve_p99_contended=(
            serve_report["p99_contended_s"] if serve_report else None
        ),
        minutes_budget=minutes_budget,
        wall_s=round(time.time() - t_start, 2),
    )
    if check:
        report.assert_invariants()
    return report
