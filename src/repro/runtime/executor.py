"""Compiled plan executor: AOT lowering of MapReduce plans to one executable.

``run_plan`` (the §5 correctness oracle) dispatches every stage eagerly from
Python — one device round-trip per eqn, control flow owned by the host. That
is the right *reference* semantics, but the paper's systems claim is that
DrJAX programs "translate directly to XLA HLO": the staged plan should lower
to ONE donation-aware executable, compiled once, with zero per-round Python
overhead and zero retraces across rounds.

This module provides that compiled path:

* :func:`compile_plan` / ``plan.compile(...)`` — lower an entire
  :class:`~repro.core.interpreter.MapReducePlan` (including
  ``LoopStage``/``CondStage`` sub-plans) into a single ``jax.jit``
  executable. Loop stages become ``lax.scan``/``lax.while_loop`` (carries
  live in-place inside the executable), cond stages become ``lax.switch``,
  and adjacent ``GROUP_COMPUTE``/``SERVER_COMPUTE`` stages are **fused**
  into one compute unit so no intermediate materializes at an interpreter
  stage boundary. Bitwise-equal to ``run_plan`` on CPU (asserted by
  ``tests/test_executor.py`` over every control-flow test program).

* an **executable cache** keyed by ``(plan fingerprint, mesh key, arg
  shapes/dtypes, donation, loop mode)``. Two structurally identical plans —
  e.g. the same program re-traced — share one executable: compiling the
  second is a cache hit and triggers **zero** new traces
  (:func:`plan_fingerprint` hashes the canonical jaxpr print, the stage
  skeleton and the captured const values).

* donation plumbing: ``compile_plan(plan, donate_argnums=...)`` donates the
  carried arguments (params / server state in a round plan), matching the
  ``donate_argnums`` discipline of ``launch/dryrun.py``. The donation rule
  for this repo: **any jitted hot loop donates its carried state**; inputs
  that are re-used across calls (model params at serve time, eval batches)
  are never donated.

* per-stage sharding constraints: with ``mesh=``/``placement_axes=``, the
  output of every BROADCAST/REDUCE stage is pinned to its placement-stack
  sharding (k leading group axes each on their own mesh axes, reduce
  results replicated at the server) exactly as the primitive impls do under
  an ambient context.

* :class:`ElasticHierarchicalRound` (per-placement-level cache split): the
  per-client leg of a pod-hierarchical round is compiled ONCE from the
  per-pod plan — whose shapes do not mention the pod count — and dispatched
  per pod; only the tiny cross-pod leg is keyed by the pod count. Elastic
  pod dropout therefore recompiles the cross-pod leg and **never** the
  per-client leg (closing the ROADMAP elastic-resharding item).

Fallback: ``run_plan`` remains the eager reference executor; anything the
compiled path cannot express should raise at compile time, never silently
diverge.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src import core as _src_core
from jax.extend import core as jex_core

from repro.core import interpreter as interp
from repro.core import placement as placement_lib
from repro.core import sharding as sharding_lib

__all__ = [
    "CompiledPlan",
    "ElasticHierarchicalRound",
    "FusedCompute",
    "TraceCounter",
    "clear_executor_cache",
    "compile_plan",
    "fingerprint_components",
    "fingerprint_parts",
    "fuse_stages",
    "plan_fingerprint",
]


# ---------------------------------------------------------------------------
# trace counting
# ---------------------------------------------------------------------------


class TraceCounter:
    """Counts how many times JAX (re)traces a wrapped function.

    ``jit`` only calls the underlying Python callable when tracing, so a
    plain side-effecting counter measures exactly the retrace count — the
    no-retrace invariants in ``tests/test_executor.py`` and
    ``benchmarks/executor.py`` are asserted with this.
    """

    def __init__(self):
        self.count = 0

    def wrap(self, fn: Callable) -> Callable:
        def wrapped(*args, **kwargs):
            self.count += 1
            return fn(*args, **kwargs)

        return wrapped


# ---------------------------------------------------------------------------
# plan fingerprinting
# ---------------------------------------------------------------------------


def fingerprint_parts(plan) -> List[Tuple[str, bytes]]:
    """The named byte components ``plan_fingerprint`` hashes, in hash order.

    Concatenating the byte values in order reproduces the exact stream
    ``plan_fingerprint`` feeds sha1 — the fingerprint is defined over this
    decomposition, so the two can never drift. The names exist for the
    analysis layer: ``repro.analysis.explain_fingerprint_mismatch`` compares
    plans part by part to say *which* component broke executable sharing.
    """
    parts: List[Tuple[str, bytes]] = [
        ("placements", str(plan.placements).encode()),
        ("placement_kinds", str(tuple(plan.placement_kinds)).encode()),
        (
            "partitioned_invars",
            str(tuple(int(d) for d in plan.partitioned_invars)).encode(),
        ),
        (
            "partitioned_outvars",
            str(tuple(int(d) for d in plan.partitioned_outvars)).encode(),
        ),
        # The jaxpr pretty-printer assigns var names deterministically, so
        # the string is canonical for structurally identical programs (and
        # covers every sub-jaxpr, so LoopStage/CondStage bodies included).
        ("jaxpr", str(plan.jaxpr.jaxpr).encode()),
        (
            "stage_skeleton",
            "|".join(
                name + ":" + s.kind for name, s, _ in plan.named_stages()
            ).encode(),
        ),
    ]
    idx = 0
    for p in interp._all_plans(plan):
        for atom, val in p.const_env().items():
            arr = np.asarray(val)
            parts.append((
                f"const[{idx}]",
                str(getattr(atom, "aval", None)).encode()
                + str((arr.shape, str(arr.dtype))).encode()
                + arr.tobytes(),
            ))
            idx += 1
    return parts


def fingerprint_components(plan) -> List[Tuple[str, str]]:
    """Per-component sha1 hexdigests of :func:`fingerprint_parts`.

    Cheap to diff between two plans; used by the retrace-hazard analysis to
    explain fingerprint mismatches without shipping raw const bytes around.
    """
    return [
        (name, hashlib.sha1(data).hexdigest())
        for name, data in fingerprint_parts(plan)
    ]


def plan_fingerprint(plan) -> str:
    """Structural hash of a plan: canonical jaxpr print + placements + stage
    skeleton + captured const values.

    Two plans built from separate traces of the same program (same shapes)
    produce the same fingerprint — the executable cache uses this to share
    one compiled artifact across re-plans.
    """
    h = hashlib.sha1()
    for _name, data in fingerprint_parts(plan):
        h.update(data)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# stage fusion
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FusedCompute:
    """A maximal run of adjacent LocalCompute stages, fused into one unit.

    ``run_plan`` treats GROUP_COMPUTE → SERVER_COMPUTE adjacency as two
    dispatch units with a materialized boundary; inside one executable there
    is no placement barrier between purely local stages, so the compiled
    path evaluates the whole run as a single fused unit and lets XLA fuse
    across the former boundary.
    """

    eqns: List[Any]
    kinds: Tuple[str, ...]

    @property
    def kind(self) -> str:
        return "FUSED_COMPUTE"


def fuse_stages(stages: Sequence[Any]) -> List[Any]:
    """Merge adjacent LocalCompute stages (any placement) into FusedCompute."""
    out: List[Any] = []
    for s in stages:
        if isinstance(s, interp.LocalCompute):
            if out and isinstance(out[-1], FusedCompute):
                out[-1].eqns.extend(s.eqns)
                out[-1].kinds = out[-1].kinds + (s.kind,)
            else:
                out.append(FusedCompute(eqns=list(s.eqns), kinds=(s.kind,)))
        else:
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# traceable plan evaluation
# ---------------------------------------------------------------------------


_UNROLL_LIMIT = 32


def _is_literal(a) -> bool:
    return isinstance(a, jex_core.Literal)


def _is_dropvar(v) -> bool:
    return isinstance(v, _src_core.DropVar)


def _plan_consts(plan) -> Dict[Any, Any]:
    """Const env for a plan, hoisted once per compile (not per call/round):
    the values are closed over by the traced function and baked into the
    executable as constants instead of being re-bound every dispatch."""
    return plan.const_env()


class _PlanTracer:
    """Executes a plan with traceable control flow (jit-able end to end)."""

    def __init__(self, *, loops: str, constrain: Optional[Callable]):
        if loops not in ("native", "unroll", "auto"):
            raise ValueError(f"loops must be native|unroll|auto, got {loops!r}")
        self.loops = loops
        self.constrain = constrain
        self._consts: Dict[int, Dict[Any, Any]] = {}

    def consts_for(self, plan) -> Dict[Any, Any]:
        key = id(plan)
        if key not in self._consts:
            self._consts[key] = _plan_consts(plan)
        return self._consts[key]

    # -- main entry ----------------------------------------------------------

    def run(self, plan, args: Sequence[Any]) -> List[Any]:
        jaxpr = plan.jaxpr.jaxpr
        env: Dict[Any, Any] = dict(self.consts_for(plan))

        def read(a):
            if _is_literal(a):
                return a.val
            return env[a]

        def write(v, val):
            if not _is_dropvar(v):
                env[v] = val

        if len(args) != len(jaxpr.invars):
            raise TypeError(
                f"plan expects {len(jaxpr.invars)} flat args, got {len(args)}"
            )
        for v, val in zip(jaxpr.invars, args):
            write(v, val)

        for stage in fuse_stages(plan.stages):
            if isinstance(stage, FusedCompute):
                for eqn in stage.eqns:
                    for o, val in zip(eqn.outvars, interp._eval_eqn(eqn, read)):
                        write(o, val)
            elif isinstance(
                stage, (interp.Broadcast, interp.Reduce, interp.Transfer)
            ):
                eqn = stage.eqn
                vals = interp._eval_eqn(eqn, read)
                if self.constrain is not None:
                    names, i = interp._eqn_placement(eqn)
                    # Broadcast lands one level deeper (depth i+1), Reduce
                    # one level up (depth i); Transfer stays at the stage
                    # level's own depth i+1 — that constraint is what pins
                    # the stage axis so the shift lowers to neighbor
                    # collective-permute traffic.
                    depth = (
                        i if isinstance(stage, interp.Reduce) else i + 1
                    )
                    vals = [self.constrain(v, depth) for v in vals]
                for o, val in zip(eqn.outvars, vals):
                    write(o, val)
            elif isinstance(stage, interp.LoopStage):
                self._run_loop(stage, read, write)
            elif isinstance(stage, interp.CondStage):
                self._run_cond(stage, read, write)
            else:  # pragma: no cover - future stage kinds
                raise TypeError(f"unknown stage kind: {stage!r}")

        return [read(a) for a in plan.out_atoms]

    # -- control flow --------------------------------------------------------

    def _run_loop(self, stage, read, write):
        if stage.loop_kind == "scan":
            self._run_scan(stage, read, write)
        else:
            self._run_while(stage, read, write)

    def _run_scan(self, stage, read, write):
        eqn = stage.eqn
        params = eqn.params
        nc, ncar, length = params["num_consts"], params["num_carry"], params["length"]
        reverse = params.get("reverse", False)
        invals = [read(a) for a in eqn.invars]
        consts = invals[:nc]
        carry0 = invals[nc : nc + ncar]
        xs = invals[nc + ncar :]
        num_ys = len(eqn.outvars) - ncar
        unroll = self.loops == "unroll" or (
            self.loops == "auto" and length <= _UNROLL_LIMIT
        )
        if unroll:
            carry = list(carry0)
            ys: List[Tuple[Any, ...]] = []
            indices = range(length - 1, -1, -1) if reverse else range(length)
            for i in indices:
                xi = [x[i] for x in xs]
                outs = self.run(stage.body_plan, consts + carry + xi)
                carry = list(outs[:ncar])
                ys.append(tuple(outs[ncar:]))
            if reverse:
                ys.reverse()
            if length == 0:
                stacked = [
                    jnp.zeros(v.aval.shape, v.aval.dtype)
                    for v in eqn.outvars[ncar:]
                ]
            else:
                stacked = [
                    jnp.stack([ys[t][j] for t in range(length)])
                    for j in range(num_ys)
                ]
            for o, val in zip(eqn.outvars, carry + stacked):
                write(o, val)
            return

        def body(carry, x):
            xi = list(x) if x is not None else []
            outs = self.run(stage.body_plan, list(consts) + list(carry) + xi)
            return tuple(outs[:ncar]), tuple(outs[ncar:])

        carry, ys = jax.lax.scan(
            body,
            tuple(carry0),
            tuple(xs) if xs else None,
            length=length,
            reverse=reverse,
        )
        for o, val in zip(eqn.outvars, list(carry) + list(ys)):
            write(o, val)

    def _run_while(self, stage, read, write):
        eqn = stage.eqn
        params = eqn.params
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        invals = [read(a) for a in eqn.invars]
        cond_consts = invals[:cn]
        body_consts = invals[cn : cn + bn]
        carry0 = invals[cn + bn :]

        def cond_f(carry):
            if stage.cond_plan is not None:
                pred = self.run(stage.cond_plan, list(cond_consts) + list(carry))[0]
            else:
                cond_jaxpr = params["cond_jaxpr"]
                pred = _src_core.eval_jaxpr(
                    cond_jaxpr.jaxpr, cond_jaxpr.consts, *cond_consts, *carry
                )[0]
            return jnp.reshape(pred, ())

        def body_f(carry):
            return tuple(self.run(stage.body_plan, list(body_consts) + list(carry)))

        carry = jax.lax.while_loop(cond_f, body_f, tuple(carry0))
        for o, val in zip(eqn.outvars, carry):
            write(o, val)

    def _run_cond(self, stage, read, write):
        eqn = stage.eqn
        n = len(stage.branch_plans)
        idx = jnp.clip(jnp.asarray(read(eqn.invars[0])).astype(jnp.int32), 0, n - 1)
        ops = [read(a) for a in eqn.invars[1:]]

        def make_branch(bp):
            def branch(*operands):
                return tuple(self.run(bp, list(operands)))

            return branch

        outs = jax.lax.switch(idx, [make_branch(bp) for bp in stage.branch_plans], *ops)
        for o, val in zip(eqn.outvars, outs):
            write(o, val)


# ---------------------------------------------------------------------------
# sharding constraints from the placement stack
# ---------------------------------------------------------------------------


def _make_constrainer(plan, mesh, placement_axes):
    """A ``(value, depth) -> value`` sharding pin for stage boundaries.

    Builds a placement context over ``plan.placements`` with each level's
    mesh axes from ``placement_axes`` (name -> axis name(s)), then reuses
    the core sharding helpers: depth-k values pin their k leading group
    axes, depth-0 (server) values pin full replication.
    """
    if mesh is None:
        return None
    placement_axes = placement_axes or {}
    ctx = placement_lib.PlacementContext(
        placements=tuple(
            placement_lib.Placement(n, s, placement_axes.get(n), kind=k)
            for (n, s), k in zip(plan.placements, plan.placement_kinds)
        ),
        mesh=mesh,
    )

    def constrain(val, depth: int):
        if not hasattr(val, "ndim") or val.ndim == 0:
            return val
        if depth <= 0:
            return sharding_lib.constrain_replicated(val, ctx)
        return sharding_lib.constrain_partitioned(val, ctx, depth=depth)

    return constrain


# ---------------------------------------------------------------------------
# executable cache + CompiledPlan
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _CacheEntry:
    fn: Callable  # the jitted executable
    counter: TraceCounter


_EXEC_CACHE: Dict[Any, _CacheEntry] = {}


def clear_executor_cache() -> None:
    _EXEC_CACHE.clear()


def executor_cache_size() -> int:
    return len(_EXEC_CACHE)


def _aval_key(args) -> Tuple:
    out = []
    for a in args:
        aval = _src_core.get_aval(a)
        out.append(
            (tuple(aval.shape), str(aval.dtype), bool(getattr(aval, "weak_type", False)))
        )
    return tuple(out)


def _mesh_key(mesh, placement_axes) -> Tuple:
    if mesh is None:
        return (None, None, None)
    return (
        tuple(zip(mesh.axis_names, mesh.devices.shape)),
        # Device IDENTITY matters: the same (axes, shape) remapped onto
        # different devices (elastic re-mapping around a failed pod) must
        # not share an executable whose constraints pin the old devices.
        tuple(d.id for d in mesh.devices.flat),
        tuple(sorted((placement_axes or {}).items())),
    )


class CompiledPlan:
    """A plan lowered to one donation-aware executable (lazily, per shapes).

    Calling it with concrete arrays looks up the executable cache under
    ``(fingerprint, mesh key, arg avals, donation, loop mode)`` and jits the
    traceable plan evaluation on a miss. ``trace_count`` exposes how many
    times the active executable has been traced (1 after warmup; 0 retraces
    across rounds is the hot-loop invariant).
    """

    def __init__(
        self,
        plan,
        *,
        mesh=None,
        placement_axes: Optional[Dict[str, Any]] = None,
        donate_argnums: Tuple[int, ...] = (),
        loops: str = "native",
    ):
        self.plan = plan
        self.mesh = mesh
        self.placement_axes = placement_axes
        self.donate_argnums = tuple(donate_argnums)
        self.loops = loops
        self.fingerprint = plan_fingerprint(plan)
        self._entry: Optional[_CacheEntry] = None

    def _entry_for(self, args) -> _CacheEntry:
        key = (
            self.fingerprint,
            _mesh_key(self.mesh, self.placement_axes),
            self.donate_argnums,
            self.loops,
            _aval_key(args),
        )
        entry = _EXEC_CACHE.get(key)
        if entry is None:
            tracer = _PlanTracer(
                loops=self.loops,
                constrain=_make_constrainer(
                    self.plan, self.mesh, self.placement_axes
                ),
            )
            plan = self.plan

            def fn(*flat_args):
                return tuple(tracer.run(plan, list(flat_args)))

            counter = TraceCounter()
            entry = _CacheEntry(
                fn=jax.jit(counter.wrap(fn), donate_argnums=self.donate_argnums),
                counter=counter,
            )
            _EXEC_CACHE[key] = entry
        self._entry = entry
        return entry

    def __call__(self, *args):
        return self._entry_for(args).fn(*args)

    def lower(self, *args):
        """AOT: ``compiled.lower(*specs).compile()`` (jax.stages passthrough)."""
        return self._entry_for(args).fn.lower(*args)

    @property
    def trace_count(self) -> int:
        return self._entry.counter.count if self._entry is not None else 0

    @property
    def num_stage_units(self) -> int:
        """Dispatch units after fusing adjacent local stages."""
        return len(fuse_stages(self.plan.stages))

    def donation_report(self):
        """Static donation/aliasing analysis for this plan's argnums.

        Answers, without compiling: which donated inputs alias an output,
        which donations are dropped (and why), and whether any stage reads
        a donated buffer after its alias target is produced. Returns a
        :class:`repro.analysis.AnalysisReport`.
        """
        from repro import analysis  # lazy: executor must not require analysis

        return analysis.donation_report(self)


def compile_plan(
    plan,
    *,
    mesh=None,
    placement_axes: Optional[Dict[str, Any]] = None,
    donate_argnums: Sequence[int] = (),
    loops: str = "native",
) -> CompiledPlan:
    """Lower a MapReducePlan into one donation-aware jitted executable.

    ``loops``: ``"native"`` (default — loop stages become ``lax.scan`` /
    ``lax.while_loop``, so carries update in place inside the executable),
    ``"unroll"`` (static-trip scans replayed iteration by iteration at trace
    time, exactly mirroring ``run_plan``'s op sequence), or ``"auto"``
    (unroll short scans, native otherwise). All modes are bitwise-equal to
    ``run_plan`` on CPU for the shipped programs.

    ``donate_argnums`` donates the given flat args (use for carried state:
    params / server state / pending deltas). ``mesh`` + ``placement_axes``
    ({placement name -> mesh axis}) install per-stage sharding constraints
    from the placement stack.
    """
    return CompiledPlan(
        plan,
        mesh=mesh,
        placement_axes=placement_axes,
        donate_argnums=tuple(donate_argnums),
        loops=loops,
    )


# ---------------------------------------------------------------------------
# elastic two-leg executor (per-placement-level cache split)
# ---------------------------------------------------------------------------


class ElasticHierarchicalRound:
    """Pod-hierarchical round compiled per placement LEVEL, elastically.

    The executable cache is split at the outermost placement boundary:

    * the **per-client leg** (broadcast -> client updates -> intra-pod
      ``reduce_mean@clients``) is compiled ONCE from the per-pod plan — its
      shapes never mention the pod count — and dispatched once per pod, the
      way a real two-fabric runtime ships one program to every pod;
    * the **cross-pod leg** (mean of the pod partials + server update) is a
      small executable keyed by the pod count.

    When a pod drops out mid-training the pod axis shrinks: the next
    :meth:`step` reuses the cached per-client executable unchanged (zero new
    traces — asserted in ``tests/test_executor.py``) and recompiles only the
    cross-pod leg.

    ``step(..., mesh=...)`` makes the split PHYSICAL: pass the current
    ``(pod, data)`` mesh (a degraded one after a dropout —
    ``repro.runtime.elastic.mesh_for_surviving_pods``) and

    * the server-side state (params + server state) is ``device_put``
      onto the mesh replicated — on a mesh CHANGE this is the elastic
      migration, counted in ``reshard_count`` / timed in
      ``mesh_migrate_ms``;
    * the stacked pod partials are ``device_put`` sharded over the mesh's
      outermost (pod) axis before the cross-pod executable consumes them —
      the simulated DCN hop;
    * the cross-pod executable cache is keyed by ``(avals, mesh key)``
      (device identity included), so each distinct surviving-pod mesh gets
      exactly one executable (``meshes_seen`` counts them);
    * the per-client leg stays pinned to ONE stable device for the whole
      run: its executable was traced once with single-device inputs, and a
      mesh-committed input would change the jit cache key and retrace it.
      Physically this models the per-pod program being dispatched to each
      pod's local slice unchanged — only the cross-pod reduction re-maps
      when the mesh shrinks.

    ``client_fn(params, pod_data) -> pod partials`` must be a flat DrJAX
    program over ``clients_per_pod`` groups (``@drjax.program(partition_size
    =clients_per_pod)``); ``cross_fn(params, server_state, *stacked
    partials) -> outputs`` is plain JAX over the ``(num_pods, ...)`` stacks.
    """

    def __init__(
        self,
        client_fn: Callable,
        cross_fn: Callable,
        *,
        clients_per_pod: int,
        loops: str = "native",
        donate_cross: bool = False,
    ):
        self.client_fn = client_fn
        self.cross_fn = cross_fn
        self.clients_per_pod = clients_per_pod
        self.loops = loops
        self.donate_cross = donate_cross
        self._client: Optional[CompiledPlan] = None
        self._client_out_tree = None
        self._cross_cache: Dict[Any, _CacheEntry] = {}
        # physical-mesh state (step(..., mesh=...))
        self._client_device = None  # stable home of the per-client leg
        self._active_mesh = None
        self._active_mesh_key = None
        self._mesh_keys_seen: set = set()
        self.reshard_count = 0
        self.mesh_migrate_ms = 0.0

    # -- per-client leg ------------------------------------------------------

    def _ensure_client(self, params, pod_slice):
        if self._client is not None:
            return
        from repro.core import build_plan  # local: keep module import light

        closed = jax.make_jaxpr(self.client_fn)(params, pod_slice)
        plan = build_plan(closed, self.clients_per_pod)
        self._client = compile_plan(plan, loops=self.loops)
        self._client_out_tree = jax.tree_util.tree_structure(
            jax.eval_shape(self.client_fn, params, pod_slice)
        )

    def _client_leg(self, params, pod_slice):
        self._ensure_client(params, pod_slice)
        flat = jax.tree_util.tree_leaves((params, pod_slice))
        outs = self._client(*flat)
        return jax.tree_util.tree_unflatten(self._client_out_tree, list(outs))

    # -- cross-pod leg -------------------------------------------------------

    def _cross_leg(self, params, server_state, partials):
        flat_key = (
            _aval_key(jax.tree_util.tree_leaves((params, server_state, partials))),
            self._active_mesh_key,
        )
        entry = self._cross_cache.get(flat_key)
        if entry is None:
            counter = TraceCounter()
            entry = _CacheEntry(
                fn=jax.jit(
                    counter.wrap(self.cross_fn),
                    donate_argnums=(0, 1) if self.donate_cross else (),
                ),
                counter=counter,
            )
            self._cross_cache[flat_key] = entry
        return entry.fn(params, server_state, partials)

    # -- physical mesh adoption ---------------------------------------------

    def _adopt_mesh(self, mesh, params, server_state):
        """Install ``mesh`` as the cross-pod leg's mesh; migrate state onto it.

        Every physical step replicates the server-side state onto the active
        mesh with ``device_put`` (a no-op view when it already lives there —
        this is also what re-commits numpy state after a checkpoint restore
        without splitting the executable cache). A transition between two
        live meshes is a RESHARD — the pod-dropout/regrowth re-mapping — and
        its state-migration wall time accumulates in ``mesh_migrate_ms``.
        """
        from repro.compat import shardings as _shardings

        key = _mesh_key(mesh, None)
        changed = key != self._active_mesh_key
        t0 = time.perf_counter() if changed else 0.0
        rep = _shardings.replicated_sharding(mesh)
        params = jax.device_put(params, rep)
        server_state = jax.device_put(server_state, rep)
        if changed:
            jax.block_until_ready((params, server_state))
            self.mesh_migrate_ms += (time.perf_counter() - t0) * 1e3
            if self._active_mesh_key is not None:
                self.reshard_count += 1
            self._active_mesh = mesh
            self._active_mesh_key = key
            self._mesh_keys_seen.add(key)
        return params, server_state

    # -- driver --------------------------------------------------------------

    def step(self, params, server_state, round_data, *, mesh=None):
        """One round: ``round_data`` leaves lead with (num_pods,
        clients_per_pod, ...); the pod count may change between calls.

        With ``mesh`` (the physical path) the mesh may also change between
        calls — the state migrates and only the cross-pod leg re-keys; see
        the class docstring for the invariants.
        """
        leaves = jax.tree_util.tree_leaves(round_data)
        if not leaves:
            raise ValueError("round_data must have at least one leaf")
        num_pods = leaves[0].shape[0]
        if mesh is not None:
            params, server_state = self._adopt_mesh(mesh, params, server_state)
            if self._client_device is None:
                self._client_device = jax.devices()[0]
            client_params = jax.device_put(params, self._client_device)
        else:
            client_params = params
        pod_outs = [
            self._client_leg(
                client_params,
                jax.tree_util.tree_map(lambda x: x[p], round_data),
            )
            for p in range(num_pods)
        ]
        partials = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *pod_outs
        )
        if mesh is not None:
            from repro.compat import shardings as _shardings

            # Ship the pod partials onto the mesh's outermost (pod) axis —
            # the cross-DCN hop — so the cross-pod executable consumes them
            # sharded one row per surviving pod.
            pod_sharding = _shardings.named_sharding(
                mesh, (mesh.axis_names[0],)
            )
            partials = jax.tree_util.tree_map(
                lambda x: jax.device_put(x, pod_sharding)
                if getattr(x, "ndim", 0) >= 1 and x.shape[0] == num_pods
                else x,
                partials,
            )
        return self._cross_leg(params, server_state, partials)

    # -- introspection (tested invariants) -----------------------------------

    @property
    def client_trace_count(self) -> int:
        return self._client.trace_count if self._client is not None else 0

    @property
    def cross_compile_count(self) -> int:
        return len(self._cross_cache)

    @property
    def meshes_seen(self) -> int:
        """Distinct physical meshes adopted so far (0 in logical mode)."""
        return len(self._mesh_keys_seen)
