"""User-facing DrJAX API.

Mirrors the paper's authoring surface (Snippets 1–4):

.. code-block:: python

    from repro.core import api as drjax

    @drjax.program(partition_size=3)
    def broadcast_double_and_sum(x):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a: 2 * a, y)
        return drjax.reduce_sum(z)

All ops are pytree-polymorphic: partitioned *structures* are pytrees whose
every leaf carries the leading group axis (paper Fig. 2).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from . import placement as placement_lib
from . import primitives as prims
from . import sharding as sharding_lib

__all__ = [
    "program",
    "placement_context",
    "broadcast",
    "map_fn",
    "reduce_sum",
    "reduce_mean",
    "reduce_max",
    "reduce_weighted_mean",
    "masked_reduce_mean",
    "partition_size",
    "current_context",
]

placement_context = placement_lib.placement_context
current_context = placement_lib.current_context


def program(
    fn: Optional[Callable] = None,
    *,
    partition_size: Optional[int] = None,
    placements: Optional[Mapping[str, int]] = None,
    partition_axes=None,
    mesh: Optional[jax.sharding.Mesh] = None,
    use_sharding_annotations: bool = True,
    use_spmd_axis_name: bool = True,
):
    """Decorator declaring a DrJAX program.

    Either ``partition_size=n`` (paper API) or ``placements={"clients": n}``
    (upstream drjax API) must be given. ``partition_axes`` names the mesh
    axis/axes the partition's leading array dimension shards over (e.g.
    ``"data"`` or ``("pod", "data")``); ``None`` means purely logical
    partitioning with no sharding constraints (fine on CPU / single device).

    ``use_sharding_annotations=False`` reproduces the paper's DrJAX-NS
    ablation (Fig. 6).
    """
    if fn is not None:  # used as bare @program — not allowed, size required
        raise TypeError(
            "drjax.program requires a partition size: use "
            "@drjax.program(partition_size=n)."
        )
    if placements is not None:
        if partition_size is not None:
            raise ValueError("Pass either partition_size or placements, not both.")
        if len(placements) != 1:
            raise ValueError(
                f"Exactly one placement is supported; got {list(placements)}."
            )
        (placement_name, size), = placements.items()
    elif partition_size is not None:
        placement_name, size = "clients", partition_size
    else:
        raise ValueError("partition_size (or placements) is required.")

    ctx = placement_lib.make_context(
        size,
        placement=placement_name,
        partition_axes=partition_axes,
        mesh=mesh,
        use_sharding_annotations=use_sharding_annotations,
        use_spmd_axis_name=use_spmd_axis_name,
    )

    def deco(f: Callable) -> Callable:
        @functools.wraps(f)
        def wrapped(*args, **kwargs):
            with placement_lib.placement_context(ctx):
                return f(*args, **kwargs)

        wrapped.drjax_context = ctx  # introspection hook (tests, interpreter)
        return wrapped

    return deco


# ---------------------------------------------------------------------------
# building blocks (pytree-polymorphic)
# ---------------------------------------------------------------------------


def broadcast(tree):
    """Replicate a non-partitioned structure to every group (paper §2, BB 1)."""
    return jax.tree_util.tree_map(prims.bind_broadcast, tree)


def reduce_sum(tree):
    """Sum a partitioned structure over its groups (paper §2, BB 3)."""
    return jax.tree_util.tree_map(prims.bind_reduce_sum, tree)


def reduce_mean(tree):
    """Average a partitioned structure over its groups (derived symbol)."""
    return jax.tree_util.tree_map(prims.bind_reduce_mean, tree)


def reduce_max(tree):
    """Max over groups (extension primitive; sub-gradient AD)."""
    return jax.tree_util.tree_map(prims.bind_reduce_max, tree)


def reduce_weighted_mean(tree, weights):
    """Weighted mean over groups: sum_i w_i x_i / sum_i w_i.

    ``weights`` is a partitioned vector of shape ``(n,)``. Fully
    differentiable in both ``tree`` and ``weights`` — this is the reduction
    whose weights Rush et al. (2023) *learn* in tandem with training
    (paper §6, self-tuning algorithms).

    When every weight is zero (e.g. a straggler mask that dropped the whole
    cohort) the reduction returns zeros rather than 0/0 = NaN, so a fully
    dropped round leaves the server params untouched instead of poisoning
    them.
    """
    weights = jnp.asarray(weights)
    denom = prims.bind_reduce_sum(weights)
    all_dropped = denom == 0
    safe_denom = jnp.where(all_dropped, jnp.ones_like(denom), denom)

    def leaf(x):
        w = weights.reshape((x.shape[0],) + (1,) * (x.ndim - 1))
        s = prims.bind_reduce_sum(x * w)
        return jnp.where(all_dropped, jnp.zeros_like(s), s / safe_denom)

    return jax.tree_util.tree_map(leaf, tree)


def masked_reduce_mean(tree, mask):
    """Mean over the groups with ``mask == 1`` (straggler-dropping reduce).

    Over-provisioning + deadline-dropping is the natural straggler mitigation
    under MapReduce semantics: sample ``n`` groups, reduce over whichever
    ``k <= n`` arrive. The mask enters as weights, so the reduction stays
    differentiable and stays within the DrJAX primitive set. An all-zero mask
    (every straggler dropped) yields zeros, not NaN.
    """
    return reduce_weighted_mean(tree, mask)


def map_fn(fn: Callable, tree):
    """Apply ``fn`` pointwise across the groups of a partition (paper §2, BB 2).

    ``tree`` is a partitioned structure; if it is a *tuple*, its elements are
    passed to ``fn`` as separate positional arguments (paper Snippet 4).

    Implemented as ``jax.vmap`` over the leading axis with
    ``spmd_axis_name=<partition mesh axes>`` — vmap's SPMD axis name is what
    installs the paper's *dynamic* sharding annotations on every intermediate
    of the mapped computation, which Fig. 6 shows to be load-bearing for weak
    scaling. The mapped computation itself is inlined into the jaxpr, exactly
    as in paper Snippet 5.
    """
    ctx = placement_lib.current_context()
    if isinstance(tree, tuple):
        f = lambda args: fn(*args)
    else:
        f = fn
    spmd = ctx.spmd_axis_name()
    mapped = jax.vmap(f, in_axes=0, out_axes=0, spmd_axis_name=spmd)
    out = mapped(tree)
    return sharding_lib.constrain_tree(out, ctx, partitioned=True)


def partition_size() -> int:
    """The number of groups in the ambient placement."""
    return placement_lib.current_context().partition_size
