"""User-facing DrJAX API.

Mirrors the paper's authoring surface (Snippets 1–4):

.. code-block:: python

    from repro.core import api as drjax

    @drjax.program(partition_size=3)
    def broadcast_double_and_sum(x):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a: 2 * a, y)
        return drjax.reduce_sum(z)

Placements nest (hierarchical MapReduce): declare an ordered stack and
address individual levels with ``placement=``:

.. code-block:: python

    @drjax.program(placements={"pods": 2, "clients": 4})
    def hier_round(x):
        y = drjax.broadcast(x)                       # server -> (2, 4, ...)
        z = drjax.map_fn(lambda a: 2 * a, y)         # per-client compute
        partial = drjax.reduce_mean(z, placement="clients")   # (2, ...)
        return drjax.reduce_mean(partial, placement="pods")   # server

With no ``placement=``, ``broadcast``/``reduce_*`` span the whole stack (one
primitive per level), so single-placement programs are the unchanged
degenerate case.

All ops are pytree-polymorphic: partitioned *structures* are pytrees whose
every leaf carries the leading group axes (paper Fig. 2).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from . import placement as placement_lib
from . import primitives as prims
from . import sharding as sharding_lib

__all__ = [
    "program",
    "placement_context",
    "broadcast",
    "map_fn",
    "reduce_sum",
    "reduce_mean",
    "reduce_max",
    "reduce_weighted_mean",
    "masked_reduce_mean",
    "stage_transfer",
    "stage_map",
    "partition_size",
    "current_context",
]

placement_context = placement_lib.placement_context
current_context = placement_lib.current_context


def program(
    fn: Optional[Callable] = None,
    *,
    partition_size: Optional[int] = None,
    placements: Optional[Mapping[str, int]] = None,
    partition_axes=None,
    placement_kinds: Optional[Mapping[str, str]] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    use_sharding_annotations: bool = True,
    use_spmd_axis_name: bool = True,
):
    """Decorator declaring a DrJAX program.

    Either ``partition_size=n`` (paper API, one "clients" placement) or
    ``placements={"pods": P, "clients": m}`` (an ordered stack, outermost
    first — one entry is the upstream drjax API) must be given.

    ``partition_axes`` names the mesh axis/axes each placement's group axis
    shards over: a bare spec for a single placement (e.g. ``"data"`` or
    ``("pod", "data")``), or a mapping ``{placement_name: axes}`` for a
    stack (e.g. ``{"pods": "pod", "clients": "data"}`` — pods over the DCN
    axis, clients over ICI). ``None`` means purely logical partitioning with
    no sharding constraints (fine on CPU / single device).

    ``placement_kinds`` marks levels of the stack as pipeline *stages*
    rather than replicas, e.g. ``placements={"stages": 4, "clients": 8},
    placement_kinds={"stages": "stages"}``. Stage-kind levels communicate
    via :func:`stage_transfer` / :func:`stage_map` instead of
    broadcast/reduce. Unnamed levels default to ``"replicas"`` (today's
    behavior, unchanged).

    ``use_sharding_annotations=False`` reproduces the paper's DrJAX-NS
    ablation (Fig. 6).
    """
    if fn is not None:  # used as bare @program — not allowed, size required
        raise TypeError(
            "drjax.program requires a partition size: use "
            "@drjax.program(partition_size=n)."
        )
    if placements is not None and partition_size is not None:
        raise ValueError("Pass either partition_size or placements, not both.")
    if placements is None and partition_size is None:
        raise ValueError("partition_size (or placements) is required.")

    ctx = placement_lib.make_context(
        partition_size,
        placements=placements,
        partition_axes=partition_axes,
        placement_kinds=placement_kinds,
        mesh=mesh,
        use_sharding_annotations=use_sharding_annotations,
        use_spmd_axis_name=use_spmd_axis_name,
    )

    def deco(f: Callable) -> Callable:
        @functools.wraps(f)
        def wrapped(*args, **kwargs):
            with placement_lib.placement_context(ctx):
                return f(*args, **kwargs)

        wrapped.drjax_context = ctx  # introspection hook (tests, interpreter)
        return wrapped

    return deco


# ---------------------------------------------------------------------------
# building blocks (pytree-polymorphic)
# ---------------------------------------------------------------------------


def _ctx() -> placement_lib.PlacementContext:
    return placement_lib.current_context()


def _require_replica_stack(ctx: placement_lib.PlacementContext, op: str):
    """Default-span collectives only make sense on an all-replica stack."""
    stages = [n for n, k in zip(ctx.names, ctx.kinds) if k == "stages"]
    if stages:
        raise ValueError(
            f"{op} with no placement= spans the whole stack, but level(s) "
            f"{stages} are stage-kind (pipeline stages do not "
            f"broadcast/reduce — use stage_transfer/stage_map). Address a "
            f"replica-kind placement explicitly with placement=<name>."
        )


def broadcast(tree, placement: Optional[str] = None):
    """Replicate a structure to every group (paper §2, BB 1).

    With ``placement=p`` (stack index i) this is ONE broadcast primitive:
    depth-i operand → depth-(i+1) result. With no placement it spans the
    whole stack — server value → fully partitioned, one primitive per level
    (a single-placement program binds exactly one, as in the paper).
    """
    ctx = _ctx()
    if placement is None:
        _require_replica_stack(ctx, "broadcast")
        chain = ctx.names  # outermost first: server -> ... -> innermost
    else:
        chain = (placement,)

    def leaf(x):
        for name in chain:
            x = prims.bind_broadcast(x, placement=name)
        return x

    return jax.tree_util.tree_map(leaf, tree)


def _reduce_tree(tree, binder, placement: Optional[str]):
    ctx = _ctx()
    if placement is None:
        _require_replica_stack(ctx, "reduce")
        chain = tuple(reversed(ctx.names))  # innermost first: -> server
    else:
        chain = (placement,)

    def leaf(x):
        for name in chain:
            x = binder(x, placement=name)
        return x

    return jax.tree_util.tree_map(leaf, tree)


def reduce_sum(tree, placement: Optional[str] = None):
    """Sum a partitioned structure over its groups (paper §2, BB 3).

    ``placement=p`` reduces that one level (depth i+1 → depth i); the
    default reduces the whole stack down to the server, innermost level
    first — on a nested stack this is automatically the hierarchical
    (two-stage) reduction."""
    return _reduce_tree(tree, prims.bind_reduce_sum, placement)


def reduce_mean(tree, placement: Optional[str] = None):
    """Average a partitioned structure over its groups (derived symbol).

    The stack-spanning default composes per-level means (equal group sizes
    make the mean-of-means the global mean)."""
    return _reduce_tree(tree, prims.bind_reduce_mean, placement)


def reduce_max(tree, placement: Optional[str] = None):
    """Max over groups (extension primitive; sub-gradient AD)."""
    return _reduce_tree(tree, prims.bind_reduce_max, placement)


def reduce_weighted_mean(tree, weights, placement: Optional[str] = None):
    """Weighted mean over groups: sum_i w_i x_i / sum_i w_i.

    ``weights`` is a partitioned array with one entry per group: shape
    ``(n,)`` for the flat API, or the stack-prefix shape (e.g. ``(P, m)``)
    when reducing a nested stack / an inner placement. Fully differentiable
    in both ``tree`` and ``weights`` — this is the reduction whose weights
    Rush et al. (2023) *learn* in tandem with training (paper §6,
    self-tuning algorithms).

    When every weight is zero (e.g. a straggler mask that dropped the whole
    cohort) the reduction returns zeros rather than 0/0 = NaN, so a fully
    dropped round leaves the server params untouched instead of poisoning
    them.
    """
    ctx = _ctx()
    weights = jnp.asarray(weights)
    if placement is None:
        _require_replica_stack(ctx, "reduce_weighted_mean")
        chain = tuple(reversed(ctx.names))
        depth_in, depth_out = ctx.depth, 0
    else:
        i = ctx.index_of(placement)
        chain = (placement,)
        depth_in, depth_out = i + 1, i
    expected = ctx.sizes[:depth_in]
    if weights.shape != expected:
        raise ValueError(
            f"reduce_weighted_mean: weights have shape {weights.shape}, but "
            f"the reduction over placement(s) {list(ctx.names[:depth_in])} "
            f"needs one weight per group: expected shape {expected}."
        )

    def rsum(x):
        for name in chain:
            x = prims.bind_reduce_sum(x, placement=name)
        return x

    denom = rsum(weights)
    all_dropped = denom == 0
    safe_denom = jnp.where(all_dropped, jnp.ones_like(denom), denom)

    def leaf(x):
        if x.ndim < depth_in or x.shape[:depth_in] != expected:
            raise ValueError(
                f"reduce_weighted_mean: weights of shape {weights.shape} do "
                f"not match a leaf of shape {x.shape}: the leaf's leading "
                f"{'axis' if depth_in == 1 else f'{depth_in} axes'} must be "
                f"the group axes {expected} (one entry per group of "
                f"placement(s) {list(ctx.names[:depth_in])})."
            )
        w = weights.reshape(expected + (1,) * (x.ndim - depth_in))
        s = rsum(x * w)
        dropped = all_dropped.reshape(
            all_dropped.shape + (1,) * (s.ndim - depth_out)
        )
        denom_b = safe_denom.reshape(
            safe_denom.shape + (1,) * (s.ndim - depth_out)
        )
        return jnp.where(dropped, jnp.zeros_like(s), s / denom_b)

    return jax.tree_util.tree_map(leaf, tree)


def masked_reduce_mean(tree, mask, placement: Optional[str] = None):
    """Mean over the groups with ``mask == 1`` (straggler-dropping reduce).

    Over-provisioning + deadline-dropping is the natural straggler mitigation
    under MapReduce semantics: sample ``n`` groups, reduce over whichever
    ``k <= n`` arrive. The mask enters as weights, so the reduction stays
    differentiable and stays within the DrJAX primitive set. An all-zero mask
    (every straggler dropped) yields zeros, not NaN.
    """
    return reduce_weighted_mean(tree, mask, placement)


def _fused_spmd_names(ctx: placement_lib.PlacementContext):
    """The combined ``spmd_axis_name`` for one vmap spanning the whole stack.

    Returns ``(ok, names)``: fusable when every level contributes mesh axes
    (the collapsed group axis shards over their concatenation, outermost
    first — the same device layout as the nested form) or when no level does
    (purely logical). A mix is not expressible as one vmap annotation, so
    the caller falls back to nested vmaps.
    """
    per_level = [ctx.spmd_axis_name_for(name) for name in ctx.names]
    if all(n is None for n in per_level):
        return True, None
    if any(n is None for n in per_level):
        return False, None
    names = []
    for n in per_level:
        names.extend(n if isinstance(n, (tuple, list)) else (n,))
    return True, tuple(names)


def map_fn(fn: Callable, tree, placement: Optional[str] = None,
           fuse: Optional[bool] = None):
    """Apply ``fn`` pointwise across the groups of a partition (paper §2, BB 2).

    ``tree`` is a partitioned structure; if it is a *tuple*, its elements are
    passed to ``fn`` as separate positional arguments (paper Snippet 4).

    Implemented as ``jax.vmap`` over the addressed placement's axis with
    that placement's ``spmd_axis_name`` — vmap's SPMD axis name is what
    installs the paper's *dynamic* sharding annotations on every intermediate
    of the mapped computation, which Fig. 6 shows to be load-bearing for weak
    scaling. With no ``placement``, the map spans every level of the stack:
    the group axes are collapsed into one and a SINGLE vmap runs over the
    collapsed axis with the levels' spmd axis names combined, so GSPMD sees
    one sharded loop nest instead of ``depth`` nested ones (``fn`` still sees
    one group's slice). ``fuse=False`` forces the nested per-level vmaps
    (bitwise-identical results); the fusion also falls back to them when the
    levels' mesh-axis annotations cannot be merged into one. The mapped
    computation itself is inlined into the jaxpr, exactly as in paper
    Snippet 5.
    """
    ctx = placement_lib.current_context()
    if isinstance(tree, tuple):
        f = lambda args: fn(*args)
    else:
        f = fn
    if placement is None:
        depth = ctx.depth
        fusable, fused_names = (
            _fused_spmd_names(ctx) if depth >= 2 and fuse is not False
            else (False, None)
        )
        if fusable:
            sizes = tuple(ctx.sizes)
            total = ctx.total_size()

            def collapse(x):
                if x.ndim < depth or x.shape[:depth] != sizes:
                    raise ValueError(
                        f"map_fn: a mapped leaf of shape {x.shape} does not "
                        f"carry the stack's group axes {sizes} as its "
                        "leading axes."
                    )
                return x.reshape((total,) + x.shape[depth:])

            fv = jax.vmap(f, in_axes=0, out_axes=0,
                          spmd_axis_name=fused_names)
            out = fv(jax.tree_util.tree_map(collapse, tree))
            out = jax.tree_util.tree_map(
                lambda x: x.reshape(sizes + x.shape[1:]), out
            )
            return sharding_lib.constrain_tree(
                out, ctx, partitioned=True, depth=depth
            )
        # Nested form: wrap innermost level first so the outermost
        # placement's vmap is the outermost transform; each level annotates
        # with its own mesh axes.
        for name in reversed(ctx.names):
            f = jax.vmap(
                f, in_axes=0, out_axes=0,
                spmd_axis_name=ctx.spmd_axis_name_for(name),
            )
    else:
        i = ctx.index_of(placement)
        depth = i + 1
        f = jax.vmap(
            f, in_axes=i, out_axes=i,
            spmd_axis_name=ctx.spmd_axis_name_for(placement),
        )
    out = f(tree)
    return sharding_lib.constrain_tree(out, ctx, partitioned=True, depth=depth)


# ---------------------------------------------------------------------------
# pipeline-stage building blocks (stage-kind placements)
# ---------------------------------------------------------------------------


def _stage_placement_name(
    ctx: placement_lib.PlacementContext, placement: Optional[str]
) -> str:
    """Resolve the addressed stage-kind placement (unique default)."""
    if placement is not None:
        pl = ctx.get(placement)
        if pl.kind != "stages":
            raise ValueError(
                f"placement {placement!r} is {pl.kind!r}-kind, but this op "
                "requires a stage-kind placement (declare it with "
                "placement_kinds={" + f"{placement!r}: 'stages'" + "})."
            )
        return placement
    stages = ctx.stage_names()
    if not stages:
        raise ValueError(
            "no stage-kind placement in the ambient stack: declare one with "
            "placement_kinds={<name>: 'stages'}."
        )
    if len(stages) > 1:
        raise ValueError(
            f"multiple stage-kind placements {stages}: address one "
            "explicitly with placement=<name>."
        )
    return stages[0]


def stage_transfer(tree, placement: Optional[str] = None, *,
                   shift: int = 1, wrap: bool = False):
    """Shift a stage-partitioned structure to neighboring stages.

    ``out[..., j, ...] = x[..., j - shift, ...]`` along the addressed
    stage-kind placement's group axis — stage ``j``'s activations move to
    stage ``j + shift`` (the forward pipeline hand-off for ``shift=1``).
    Vacated boundary stages receive zeros unless ``wrap=True`` (ring).
    Linear, so the transpose is the reverse transfer (``-shift``): the
    backward pipeline schedule falls out of AD. Lowers to a
    collective-permute between stage shards when the stage level pins a
    mesh axis.
    """
    ctx = _ctx()
    name = _stage_placement_name(ctx, placement)
    return jax.tree_util.tree_map(
        lambda x: prims.bind_stage_transfer(
            x, placement=name, shift=shift, wrap=wrap
        ),
        tree,
    )


def stage_map(fns, tree, placement: Optional[str] = None):
    """Apply per-stage functions across a stage-partitioned structure.

    ``fns`` is either one callable (applied at every stage — this is just
    :func:`map_fn` over the stage placement) or a sequence with one callable
    per stage (heterogeneous pipeline stages: stage ``s`` runs ``fns[s]`` on
    its slice). As with :func:`map_fn`, a *tuple* ``tree`` passes its
    elements as separate positional arguments. Results are re-stacked along
    the stage axis and re-constrained to the stage level's sharding.
    """
    ctx = _ctx()
    name = _stage_placement_name(ctx, placement)
    if callable(fns):
        return map_fn(fns, tree, placement=name)
    fns = tuple(fns)
    i = ctx.index_of(name)
    size = ctx.get(name).size
    if len(fns) != size:
        raise ValueError(
            f"stage_map: got {len(fns)} stage functions for placement "
            f"{name!r} of {size} stages (pass one callable to apply it at "
            "every stage)."
        )

    def run_stage(s: int):
        fn = fns[s]
        f = (lambda args: fn(*args)) if isinstance(tree, tuple) else fn
        # Levels outside the stage axis stay mapped: wrap innermost first so
        # the outermost placement's vmap is the outermost transform.
        for lvl in range(i - 1, -1, -1):
            f = jax.vmap(
                f, in_axes=0, out_axes=0,
                spmd_axis_name=ctx.spmd_axis_name_for(ctx.names[lvl]),
            )
        sliced = jax.tree_util.tree_map(
            lambda x: x[(slice(None),) * i + (s,)], tree
        )
        return f(sliced)

    outs = [run_stage(s) for s in range(size)]
    out = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=i), *outs
    )
    return sharding_lib.constrain_tree(out, ctx, partitioned=True, depth=i + 1)


def partition_size(placement: Optional[str] = None) -> int:
    """Number of groups: one placement's size, or (default) the total number
    of innermost groups across the whole ambient stack."""
    ctx = placement_lib.current_context()
    if placement is None:
        return ctx.total_size()
    return ctx.get(placement).size
