"""Sharding annotations for DrJAX values.

The paper's key systems finding (Fig. 6) is that *explicit* sharding
annotations on the partitioned values — installed by the primitives themselves
— are required for GSPMD to produce weak-scaling code. This module centralizes
those annotations.

Partitioned values are arrays with a leading "group" axis (paper Fig. 1). We
shard that leading axis over the mesh axes named in the placement context
(e.g. ``("pod", "data")`` on the production mesh) and leave the remaining axes
unconstrained so GSPMD can propagate model-parallel shardings from the
parameters through the mapped computation.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

from . import placement as placement_lib


_U = P.UNCONSTRAINED


def partition_spec(ctx: placement_lib.PlacementContext, ndim: int) -> Optional[P]:
    """PartitionSpec sharding the leading (partition) axis of an ndim array.

    Only the partition axis is pinned; trailing dims stay UNCONSTRAINED so
    GSPMD can propagate model-parallel shardings through the mapped
    computation (the paper's composition of partition-, model- and
    within-partition parallelism)."""
    axes = ctx.axes_tuple()
    if not axes:
        return None
    leading = axes if len(axes) > 1 else axes[0]
    return P(leading, *([_U] * (ndim - 1)))


def constrain_partitioned(x, ctx: placement_lib.PlacementContext):
    """Apply the static sharding annotation to a partitioned array (leaf)."""
    if not ctx.use_sharding_annotations:
        return x
    if ctx.mesh is None:
        return x
    if x.ndim == 0:
        return x
    spec = partition_spec(ctx, x.ndim)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, compat.named_sharding(ctx.mesh, spec)
    )


def constrain_replicated(x, ctx: placement_lib.PlacementContext):
    """Annotate a non-partitioned (server/singleton) array: replicated over
    the partition axes, open elsewhere (GSPMD may keep it model-sharded)."""
    if not ctx.use_sharding_annotations or ctx.mesh is None:
        return x
    axes = ctx.axes_tuple()
    if not axes or x.ndim == 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, compat.named_sharding(ctx.mesh, P(*([_U] * x.ndim)))
    )


def constrain_tree(tree, ctx: placement_lib.PlacementContext, *, partitioned: bool):
    f = constrain_partitioned if partitioned else constrain_replicated
    return jax.tree_util.tree_map(lambda x: f(x, ctx), tree)
