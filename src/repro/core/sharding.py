"""Sharding annotations for DrJAX values.

The paper's key systems finding (Fig. 6) is that *explicit* sharding
annotations on the partitioned values — installed by the primitives themselves
— are required for GSPMD to produce weak-scaling code. This module centralizes
those annotations.

Partitioned values are arrays whose leading axes are the group axes of a
placement-stack prefix (paper Fig. 1; depth k == k leading group axes). Each
placement pins its *own* mesh axes — on a multi-pod mesh the pods axis shards
over the slow DCN ``"pod"`` axis while the clients axis shards over ICI
``"data"`` — and the remaining array dims stay unconstrained so GSPMD can
propagate model-parallel shardings from the parameters through the mapped
computation (the paper's composition of partition-, model- and
within-partition parallelism).

Placement *kinds* change nothing here: a stage-kind level pins its group
axis onto its own mesh axes (conventionally ``"stage"``) exactly like a
replica level, which is what makes ``stage_transfer``'s shifted write lower
to a collective-permute between stage shards rather than a data reshuffle —
the per-stage sharding constraints of the 1F1B schedule are just
``constrain_partitioned(..., depth=i+1)`` at the stage level's depth.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

from . import placement as placement_lib


_U = P.UNCONSTRAINED


def partition_spec(
    ctx: placement_lib.PlacementContext,
    ndim: int,
    depth: Optional[int] = None,
) -> Optional[P]:
    """PartitionSpec for an ndim array partitioned at ``depth`` placements.

    The ``depth`` leading group axes each pin their own placement's mesh
    axes; trailing dims stay UNCONSTRAINED so GSPMD can propagate
    model-parallel shardings through the mapped computation. Placements with
    no mesh axes contribute a replicated (None) entry for their group axis.
    Returns None when nothing would be constrained."""
    if depth is None:
        depth = ctx.depth
    depth = min(depth, ndim)
    entries = []
    for pl in ctx.placements[:depth]:
        axes = pl.axes_tuple()
        if not axes:
            entries.append(None)
        else:
            entries.append(axes if len(axes) > 1 else axes[0])
    if all(e is None for e in entries):
        return None
    return P(*entries, *([_U] * (ndim - depth)))


def constrain_partitioned(
    x, ctx: placement_lib.PlacementContext, depth: Optional[int] = None
):
    """Apply the static sharding annotation to a partitioned array (leaf)."""
    if not ctx.use_sharding_annotations:
        return x
    if ctx.mesh is None:
        return x
    if x.ndim == 0:
        return x
    spec = partition_spec(ctx, x.ndim, depth)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, compat.named_sharding(ctx.mesh, spec)
    )


def constrain_replicated(x, ctx: placement_lib.PlacementContext):
    """Annotate a non-partitioned (server/singleton) array: replicated.

    A server-placed value is one copy shared by every group, so it must be
    *explicitly* replicated over the partition mesh axes — an
    all-UNCONSTRAINED spec constrains nothing and lets GSPMD leave a
    partition axis on a post-reduce value. PartitionSpec cannot express
    "replicated over these axes, open over those", so the annotation pins
    full replication (the paper's server placement: server state lives
    replicated on every device)."""
    if not ctx.use_sharding_annotations or ctx.mesh is None:
        return x
    if not any(pl.axes_tuple() for pl in ctx.placements) or x.ndim == 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, compat.named_sharding(ctx.mesh, P())
    )


def constrain_tree(
    tree,
    ctx: placement_lib.PlacementContext,
    *,
    partitioned: bool,
    depth: Optional[int] = None,
):
    if partitioned:
        return jax.tree_util.tree_map(
            lambda x: constrain_partitioned(x, ctx, depth), tree
        )
    return jax.tree_util.tree_map(lambda x: constrain_replicated(x, ctx), tree)
