"""Hierarchical (two-stage) reductions — paper §6 future work, implemented.

At multi-pod scale the reduction crosses two very different interconnects:
ICI within a pod (~50 GB/s/link) and DCN across pods (often 10-100× slower).
A flat ``reduce_mean`` over n groups moves every group's contribution across
the slow leg. The hierarchical form:

    stage 1 (within pod):  n groups → P pod-partials        (fast ICI)
    stage 2 (cross pod):   P partials → 1, optionally compressed (slow DCN)

cuts cross-pod bytes by n/P before compression (×4 more with int8). Both
stages are REAL DrJAX reduce primitives addressed at different levels of a
placement stack — ``reduce_mean(placement="clients")`` then
``reduce_mean(placement="pods")`` — so each stage carries its own placement's
sharding annotations (pods pin the DCN axis, clients the ICI axis), MapReduce
AD applies per stage (the derivative of a hierarchical reduction is a
hierarchical broadcast, automatically), and the §5 interpreter stages the
reduction as two placement-tagged REDUCE shuffles.

Under a genuinely nested ``@drjax.program(placements={"pods": P,
"clients": m})`` the two stages bind directly. Under the flat single-
placement API, the (n, ...) value is regrouped to (P, n/P, ...) and the same
two primitives bind inside a derived two-level stack — the one remaining
reshape is pure local compute at the pod boundary.
"""

from __future__ import annotations

import math
import os
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import compression

from . import api
from . import placement as placement_lib
from . import primitives as prims

_SUPER = "pods"

# Kill switch for the fused reduce+compress fast path (ROADMAP conventions):
# set REPRO_NO_FUSED_REDUCE=1 to force the generic two-primitive composition
# even for recognized compressors. An explicit ``use_fused=True`` overrides.
_NO_FUSED_ENV = "REPRO_NO_FUSED_REDUCE"


def _axes_if_divisible(axes, groups: int, mesh):
    """Keep a derived placement's mesh axes only if its group count can
    shard over them (devices | groups); otherwise leave the level logical.

    With no mesh in the context, constraints are never emitted, so the axes
    are kept as documentation. Axes missing from the mesh are also kept —
    the later sharding constraint fails loudly, which beats hiding a typo.
    """
    if axes is None or mesh is None:
        return axes
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    if not axes_t:
        return None
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    devices = 1
    for a in axes_t:
        if a not in mesh_sizes:
            return axes
        devices *= mesh_sizes[a]
    return axes if groups % devices == 0 else None


def _fusable(tree, ctx, compress_fn, use_fused: Optional[bool]) -> bool:
    """Should this reduction take the fused reduce+compress fast path?

    The fast path engages when the compressor is *recognized* — it carries
    the ``drjax_fused_compress = "int8"`` tag (``compression.int8_roundtrip``
    does) — and every leaf is a floating array carrying the stack's group
    axes. ``use_fused=False`` (or ``REPRO_NO_FUSED_REDUCE=1``) forces the
    generic two-primitive composition; ``use_fused=True`` insists and raises
    if the compressor cannot be fused.
    """
    tag = getattr(compress_fn, "drjax_fused_compress", None)
    if use_fused is False:
        return False
    if tag != "int8":
        if use_fused is True:
            raise ValueError(
                "use_fused=True requires a fusable compress_fn (one tagged "
                f"drjax_fused_compress='int8'); got {compress_fn!r}"
            )
        return False
    if use_fused is None and os.environ.get(_NO_FUSED_ENV, "") not in ("", "0"):
        return False
    leaves = jax.tree_util.tree_leaves(tree)
    depth = ctx.depth
    sizes = tuple(ctx.sizes)
    for leaf in leaves:
        if not jnp.issubdtype(jnp.result_type(leaf), jnp.floating):
            return False
        if jnp.shape(leaf)[:depth] != sizes:
            return False
    return bool(leaves)


def _staged_reduce(tree, ctx, compress_fn, use_fused: Optional[bool]):
    """Bind the two-stage reduction under the ambient (nested) context.

    Fast path: flat-pack the tree (one ``(*groups, R, 256)`` buffer per
    dtype), bind ``reduce_mean@innermost`` tagged ``compress="int8"`` — a
    single eqn whose execution is the one-pass Pallas reduce+compress kernel
    on TPU (fused jnp oracle elsewhere) — then the plain outer reduces, and
    unpack. The program still stages as placement-tagged REDUCEs, so
    ``build_plan``/``to_beam`` see the same communication structure as the
    generic composition.
    """
    inner = ctx.names[-1]
    if _fusable(tree, ctx, compress_fn, use_fused):
        bufs, spec = compression.flat_pack(
            tree, lead_ndim=ctx.depth, cols=compression.PACK_COLS
        )
        outs = {}
        for key, buf in bufs.items():
            v = prims.bind_reduce_mean(buf, placement=inner, compress="int8")
            for name in reversed(ctx.names[:-1]):
                v = prims.bind_reduce_mean(v, placement=name)
            outs[key] = v
        return compression.flat_unpack(outs, spec, lead_ndim=0)
    partials = api.reduce_mean(tree, placement=inner)
    if compress_fn is not None:
        partials = compress_fn(partials)
    out = partials
    for name in reversed(ctx.names[:-1]):
        out = api.reduce_mean(out, placement=name)
    return out


def hierarchical_reduce_mean(
    tree,
    num_supergroups: Optional[int] = None,
    compress_fn: Optional[Callable] = None,
    use_fused: Optional[bool] = None,
):
    """Two-stage mean over a partitioned structure.

    ``num_supergroups`` is the number of slow-link domains (pods). Under the
    flat API it is required and must divide the partition size; under a
    nested placement stack it is inferred from the stack (and validated if
    passed). ``compress_fn`` (e.g. ``repro.compression.int8_roundtrip``) is
    applied to the per-pod partial means — the value that crosses the slow
    leg.

    When ``compress_fn`` is recognized as the int8 wire format, the intra-pod
    leg runs the fused single-pass reduce+compress kernel instead of the
    reduce→quantize→dequantize chain (``use_fused``: None = auto, False =
    force the generic composition, True = insist). Derivatives are identical
    either way — the roundtrip is straight-through under MapReduce AD.
    """
    ctx = placement_lib.current_context()

    if ctx.depth >= 2:
        # Genuinely nested placements: the stack already separates the fast
        # and slow legs — bind the per-level primitives directly.
        outer_total = math.prod(ctx.sizes[:-1])
        if num_supergroups is not None and num_supergroups != outer_total:
            raise ValueError(
                f"num_supergroups={num_supergroups} contradicts the ambient "
                f"placement stack {dict(zip(ctx.names, ctx.sizes))}, which "
                f"has {outer_total} slow-link domain(s)"
            )
        return _staged_reduce(tree, ctx, compress_fn, use_fused)

    # Flat single-placement API: regroup (n, ...) -> (P, n/P, ...) and run the
    # same two primitives inside a derived {pods, <placement>} stack.
    n = ctx.partition_size
    if num_supergroups is None:
        raise ValueError(
            "num_supergroups is required under a single-placement context"
        )
    if n % num_supergroups != 0:
        raise ValueError(
            f"num_supergroups={num_supergroups} must divide partition "
            f"size {n}"
        )
    per = n // num_supergroups
    inner_name = ctx.placement
    super_name = _SUPER if inner_name != _SUPER else "superpods"
    axes = ctx.axes_tuple()
    # The outermost mesh axis carries the slow (cross-pod) leg; whatever
    # remains stays with the per-pod groups. Each derived level only pins
    # its axis when its group count is divisible by that axis's device
    # count (the paper's m | n rule) — P pod partials over an 8-way data
    # axis would otherwise fail sharding at trace time.
    super_axes = _axes_if_divisible(
        axes[0] if axes else None, num_supergroups, ctx.mesh
    )
    inner_axes = _axes_if_divisible(
        axes[1:] if len(axes) > 1 else None, per, ctx.mesh
    )
    nested = placement_lib.PlacementContext(
        placements=(
            placement_lib.Placement(super_name, num_supergroups, super_axes),
            placement_lib.Placement(inner_name, per, inner_axes),
        ),
        mesh=ctx.mesh,
        use_sharding_annotations=ctx.use_sharding_annotations,
        use_spmd_axis_name=ctx.use_spmd_axis_name,
    )

    regrouped = jax.tree_util.tree_map(
        lambda leaf: leaf.reshape(
            (num_supergroups, per) + leaf.shape[1:]
        ),
        tree,
    )
    with placement_lib.placement_context(nested):
        # stage 1: mean within each supergroup (fast leg) — a real reduce
        # primitive, so the partials carry the pod placement's sharding —
        # then stage 2: mean across supergroups (slow leg). Recognized
        # compressors take the fused reduce+compress path inside.
        return _staged_reduce(regrouped, nested, compress_fn, use_fused)


def int8_wire_ratio(block: int = 256) -> float:
    """Wire bytes of the packed int8 format as a fraction of f32 bytes.

    The packed format (``repro.compression``, PACK_COLS-block scheme; also
    ``models/tpcomm.int8_wire_bytes``) ships 1 byte per value plus one f32
    scale per ``block`` values: ``(1 + 4/block) / 4`` of the f32 payload —
    NOT the naive 0.25. For the default 256-block that is ~0.2539.
    """
    return (1.0 + 4.0 / block) / 4.0


def cross_pod_bytes(param_bytes: float, n: int, num_supergroups: int,
                    compress_ratio: float = 1.0,
                    compress: "str | None" = None) -> dict:
    """Napkin model: bytes crossing the slow (DCN) leg per round.

    ``compress="int8"`` applies the *actual* packed wire ratio
    (:func:`int8_wire_ratio`: payload + per-256-block f32 scales) instead of
    a hand-supplied ``compress_ratio`` — use it to match what the fused
    reduce+compress path really sends (the static analyzer's
    ``plan.comm_cost()`` models the same format from the IR; the two are
    pinned against each other in tests). ``compress_ratio`` remains for
    custom schemes and is ignored when ``compress`` is given.
    """
    if compress is not None:
        if compress != "int8":
            raise ValueError(f"unknown compress scheme: {compress!r}")
        compress_ratio = int8_wire_ratio()
    flat = n * param_bytes  # flat all-reduce moves every group's delta
    hier = num_supergroups * param_bytes * compress_ratio
    return {
        "flat_bytes": flat,
        "hierarchical_bytes": hier,
        "reduction_factor": flat / max(hier, 1e-9),
    }
