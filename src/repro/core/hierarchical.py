"""Hierarchical (two-stage) reductions — paper §6 future work, implemented.

At multi-pod scale the reduction crosses two very different interconnects:
ICI within a pod (~50 GB/s/link) and DCN across pods (often 10-100× slower).
A flat ``reduce_mean`` over n groups moves every group's contribution across
the slow leg. The hierarchical form:

    stage 1 (within pod):  n groups → P pod-partials        (fast ICI)
    stage 2 (cross pod):   P partials → 1, optionally compressed (slow DCN)

cuts cross-pod bytes by n/P before compression (×4 more with int8). Both
stages are REAL DrJAX reduce primitives addressed at different levels of a
placement stack — ``reduce_mean(placement="clients")`` then
``reduce_mean(placement="pods")`` — so each stage carries its own placement's
sharding annotations (pods pin the DCN axis, clients the ICI axis), MapReduce
AD applies per stage (the derivative of a hierarchical reduction is a
hierarchical broadcast, automatically), and the §5 interpreter stages the
reduction as two placement-tagged REDUCE shuffles.

Under a genuinely nested ``@drjax.program(placements={"pods": P,
"clients": m})`` the two stages bind directly. Under the flat single-
placement API, the (n, ...) value is regrouped to (P, n/P, ...) and the same
two primitives bind inside a derived two-level stack — the one remaining
reshape is pure local compute at the pod boundary.
"""

from __future__ import annotations

import math
from typing import Callable, Optional

import jax

from . import api
from . import placement as placement_lib

_SUPER = "pods"


def _axes_if_divisible(axes, groups: int, mesh):
    """Keep a derived placement's mesh axes only if its group count can
    shard over them (devices | groups); otherwise leave the level logical.

    With no mesh in the context, constraints are never emitted, so the axes
    are kept as documentation. Axes missing from the mesh are also kept —
    the later sharding constraint fails loudly, which beats hiding a typo.
    """
    if axes is None or mesh is None:
        return axes
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    if not axes_t:
        return None
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    devices = 1
    for a in axes_t:
        if a not in mesh_sizes:
            return axes
        devices *= mesh_sizes[a]
    return axes if groups % devices == 0 else None


def hierarchical_reduce_mean(
    tree,
    num_supergroups: Optional[int] = None,
    compress_fn: Optional[Callable] = None,
):
    """Two-stage mean over a partitioned structure.

    ``num_supergroups`` is the number of slow-link domains (pods). Under the
    flat API it is required and must divide the partition size; under a
    nested placement stack it is inferred from the stack (and validated if
    passed). ``compress_fn`` (e.g. ``repro.compression.int8_roundtrip``) is
    applied to the per-pod partial means — the value that crosses the slow
    leg.
    """
    ctx = placement_lib.current_context()

    if ctx.depth >= 2:
        # Genuinely nested placements: the stack already separates the fast
        # and slow legs — bind the per-level primitives directly.
        outer_total = math.prod(ctx.sizes[:-1])
        if num_supergroups is not None and num_supergroups != outer_total:
            raise ValueError(
                f"num_supergroups={num_supergroups} contradicts the ambient "
                f"placement stack {dict(zip(ctx.names, ctx.sizes))}, which "
                f"has {outer_total} slow-link domain(s)"
            )
        partials = api.reduce_mean(tree, placement=ctx.names[-1])
        if compress_fn is not None:
            partials = compress_fn(partials)
        out = partials
        for name in reversed(ctx.names[:-1]):
            out = api.reduce_mean(out, placement=name)
        return out

    # Flat single-placement API: regroup (n, ...) -> (P, n/P, ...) and run the
    # same two primitives inside a derived {pods, <placement>} stack.
    n = ctx.partition_size
    if num_supergroups is None:
        raise ValueError(
            "num_supergroups is required under a single-placement context"
        )
    if n % num_supergroups != 0:
        raise ValueError(
            f"num_supergroups={num_supergroups} must divide partition "
            f"size {n}"
        )
    per = n // num_supergroups
    inner_name = ctx.placement
    super_name = _SUPER if inner_name != _SUPER else "superpods"
    axes = ctx.axes_tuple()
    # The outermost mesh axis carries the slow (cross-pod) leg; whatever
    # remains stays with the per-pod groups. Each derived level only pins
    # its axis when its group count is divisible by that axis's device
    # count (the paper's m | n rule) — P pod partials over an 8-way data
    # axis would otherwise fail sharding at trace time.
    super_axes = _axes_if_divisible(
        axes[0] if axes else None, num_supergroups, ctx.mesh
    )
    inner_axes = _axes_if_divisible(
        axes[1:] if len(axes) > 1 else None, per, ctx.mesh
    )
    nested = placement_lib.PlacementContext(
        placements=(
            placement_lib.Placement(super_name, num_supergroups, super_axes),
            placement_lib.Placement(inner_name, per, inner_axes),
        ),
        mesh=ctx.mesh,
        use_sharding_annotations=ctx.use_sharding_annotations,
        use_spmd_axis_name=ctx.use_spmd_axis_name,
    )

    regrouped = jax.tree_util.tree_map(
        lambda leaf: leaf.reshape(
            (num_supergroups, per) + leaf.shape[1:]
        ),
        tree,
    )
    with placement_lib.placement_context(nested):
        # stage 1: mean within each supergroup (fast leg) — a real reduce
        # primitive, so the partials carry the pod placement's sharding.
        partials = api.reduce_mean(regrouped, placement=inner_name)
        if compress_fn is not None:
            partials = compress_fn(partials)
        # stage 2: mean across supergroups (slow leg).
        return api.reduce_mean(partials, placement=super_name)


def cross_pod_bytes(param_bytes: float, n: int, num_supergroups: int,
                    compress_ratio: float = 1.0) -> dict:
    """Napkin model: bytes crossing the slow (DCN) leg per round."""
    flat = n * param_bytes  # flat all-reduce moves every group's delta
    hier = num_supergroups * param_bytes * compress_ratio
    return {
        "flat_bytes": flat,
        "hierarchical_bytes": hier,
        "reduction_factor": flat / max(hier, 1e-9),
    }
