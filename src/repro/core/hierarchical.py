"""Hierarchical (two-stage) reductions — paper §6 future work, implemented.

At multi-pod scale the reduction crosses two very different interconnects:
ICI within a pod (~50 GB/s/link) and DCN across pods (often 10-100× slower).
A flat ``reduce_mean`` over n groups moves every group's contribution across
the slow leg. The hierarchical form:

    stage 1 (within pod):  n groups → P pod-partials        (fast ICI)
    stage 2 (cross pod):   P partials → 1, optionally compressed (slow DCN)

cuts cross-pod bytes by n/P before compression (×4 more with int8). Both
stages are expressed with the SAME DrJAX building blocks — the partitioned
value is reshaped (n, ...) → (P, n/P, ...), stage 1 is an intra-group mean
over axis 1 under the pod placement, stage 2 a ``reduce_mean`` over pods —
so MapReduce AD and the §5 interpreter still apply (the derivative of a
hierarchical reduction is a hierarchical broadcast, automatically).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import api
from . import placement as placement_lib


def hierarchical_reduce_mean(
    tree,
    num_supergroups: int,
    compress_fn: Optional[Callable] = None,
):
    """Two-stage mean over a partitioned structure.

    ``num_supergroups`` is the number of slow-link domains (pods); must
    divide the partition size. ``compress_fn`` (e.g.
    ``repro.compression.int8_roundtrip``) is applied to the per-pod partial
    means — the value that crosses the slow leg.
    """
    ctx = placement_lib.current_context()
    n = ctx.partition_size
    if n % num_supergroups != 0:
        raise ValueError(
            f"num_supergroups={num_supergroups} must divide partition "
            f"size {n}"
        )
    per = n // num_supergroups

    def stage1(leaf):
        # (n, ...) -> (P, ...): mean within each supergroup (fast leg).
        # Accumulate in f32 but return in the leaf dtype so the output dtype
        # matches a flat reduce_mean (no silent f32 upcast escaping).
        shaped = leaf.reshape((num_supergroups, per) + leaf.shape[1:])
        return jnp.mean(shaped.astype(jnp.float32), axis=1).astype(leaf.dtype)

    partials = jax.tree_util.tree_map(stage1, tree)
    if compress_fn is not None:
        partials = compress_fn(partials)

    # stage 2: mean across supergroups under a pod-level placement (slow leg)
    pod_axes = ctx.axes_tuple()
    pod_axis = pod_axes[0] if pod_axes else None
    with placement_lib.placement_context(
        placement_lib.make_context(
            num_supergroups,
            placement=f"{ctx.placement}_pods",
            partition_axes=pod_axis,
            mesh=ctx.mesh,
            use_sharding_annotations=ctx.use_sharding_annotations,
        )
    ):
        return api.reduce_mean(partials)


def cross_pod_bytes(param_bytes: float, n: int, num_supergroups: int,
                    compress_ratio: float = 1.0) -> dict:
    """Napkin model: bytes crossing the slow (DCN) leg per round."""
    flat = n * param_bytes  # flat all-reduce moves every group's delta
    hier = num_supergroups * param_bytes * compress_ratio
    return {
        "flat_bytes": flat,
        "hierarchical_bytes": hier,
        "reduction_factor": flat / max(hier, 1e-9),
    }
