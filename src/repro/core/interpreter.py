"""Control-flow-aware jaxpr interpreter: DrJAX programs → MapReduce plans.

Paper §5: because the building blocks are *primitives*, they survive into the
jaxpr. A custom interpreter can therefore recover the communication structure
of the program — which values are partitioned, where broadcasts and reductions
happen — and translate it to other platforms (Apache Beam, federated-learning
systems) where "all cross-machine communication is explicit, and the
processing in-between communication is entirely local".

Real DrJAX programs hide structure inside higher-order primitives: users wrap
programs in ``jit`` (one opaque ``pjit`` eqn), training loops live in
``lax.scan``, and branching in ``lax.cond``. This interpreter therefore walks
*into* control flow:

* call-like eqns (``pjit``, ``closed_call``, ``remat``, ``custom_jvp_call``,
  …) whose sub-jaxpr contains DrJAX communication are **inlined** via variable
  substitution — a jitted DrJAX program yields the same plan as the unjitted
  one;
* a ``scan``/``while`` whose body communicates becomes a :class:`LoopStage`
  holding a sub-plan and a trip count, so per-round communication is explicit
  in ``to_text()``/``to_beam()``;
* a ``cond`` whose branches communicate becomes a :class:`CondStage` with one
  sub-plan per branch;
* control flow with *no* communication inside stays an opaque local eqn (it is
  purely local compute, exactly what a Map worker would run).

Placement is tracked on a **placement lattice**: every value carries the
stack prefix of named placements whose group axes lead it (``()`` = server,
``("pods",)`` = pod-partitioned, ``("pods", "clients")`` = fully
partitioned). DrJAX eqns *move* values on the lattice — the addressed
placement travels in the primitive params, so ``REDUCE@clients`` and
``REDUCE@pods`` are distinct, placement-tagged stages and a hierarchical
reduction visibly stages as two shuffles. Local eqns join their inputs'
placements (longest prefix wins); loop carries are solved to a fixed point
over the lattice (a carry that *climbs* the lattice after one iteration
keeps the joined placement for the whole loop).

This module provides:

* :func:`build_plan` — segment a ``ClosedJaxpr`` into an ordered list of
  stages: ``ServerCompute``/``GroupCompute`` (:class:`LocalCompute`),
  :class:`Broadcast`, :class:`Reduce`, :class:`LoopStage`, :class:`CondStage`.
* emitters — ``plan.to_text()`` (federated-system style, recursive) and
  ``plan.to_beam()`` (an Apache Beam pipeline whose every referenced name is
  defined and whose local stages call the *real* callables from
  ``plan.stage_fns()``).
* :func:`run_plan` — a reference *plan executor* that runs the staged control
  flow (loop sub-plans iterated, cond branches selected). Equality with
  direct execution is the correctness test for the translation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core as jex_core
from jax._src import core as _src_core

_COMM = {
    "drjax_broadcast": "broadcast",
    "drjax_reduce_sum": "reduce_sum",
    "drjax_reduce_mean": "reduce_mean",
    "drjax_reduce_max": "reduce_max",
    "drjax_stage_transfer": "stage_transfer",
}

# A placement-set on the lattice: the stack prefix of placement names whose
# group axes lead a value. () is the server.
PlacementSet = Tuple[str, ...]


def _join(a: PlacementSet, b: PlacementSet) -> PlacementSet:
    """Lattice join: the deeper of two stack prefixes.

    Well-formed programs only ever join comparable prefixes; if two
    incomparable chains meet (e.g. across a flat/nested regrouping
    boundary), the deeper one wins — what matters downstream is how many
    group axes lead the value."""
    return a if len(a) >= len(b) else b


def _normalize_placements(spec) -> Tuple[Tuple[str, int, str], ...]:
    """Accept an int (one "clients" placement), an ordered mapping
    name -> size, a PlacementContext, or a (name, size[, kind]) sequence.
    Returns (name, size, kind) triples, kind defaulting to "replicas"."""
    if isinstance(spec, (int, np.integer)):
        return (("clients", int(spec), "replicas"),)
    if hasattr(spec, "placements"):  # PlacementContext
        return tuple(
            (p.name, p.size, getattr(p, "kind", "replicas"))
            for p in spec.placements
        )
    if isinstance(spec, Mapping):
        return tuple((str(n), int(s), "replicas") for n, s in spec.items())
    out = []
    for entry in spec:
        entry = tuple(entry)
        kind = str(entry[2]) if len(entry) > 2 else "replicas"
        out.append((str(entry[0]), int(entry[1]), kind))
    return tuple(out)


def _eqn_placement(eqn) -> Tuple[Tuple[str, ...], int]:
    """(stack names, addressed index) of a DrJAX eqn, from its params."""
    pctx = eqn.params.get("pctx")
    if pctx is None:  # defensive: a hand-built eqn without context
        return ("clients",), 0
    return pctx.names, pctx.index_of(eqn.params.get("placement"))

# Param keys under which call-like primitives stash their sub-jaxpr.
_CALL_JAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _is_literal(a) -> bool:
    return isinstance(a, jex_core.Literal)


def _is_dropvar(v) -> bool:
    return isinstance(v, _src_core.DropVar)


def _eqn_subjaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, jex_core.ClosedJaxpr):
            yield v
        elif isinstance(v, jex_core.Jaxpr):
            yield jex_core.ClosedJaxpr(v, ())
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jex_core.ClosedJaxpr):
                    yield item
                elif isinstance(item, jex_core.Jaxpr):
                    yield jex_core.ClosedJaxpr(item, ())


def _contains_comm(jaxpr) -> bool:
    """Does this (open) jaxpr bind a DrJAX primitive, at any nesting depth?"""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in _COMM:
            return True
        for sub in _eqn_subjaxprs(eqn):
            if _contains_comm(sub.jaxpr):
                return True
    return False


def _call_subjaxpr(eqn) -> Optional[Any]:
    """The sub-jaxpr of a call-like eqn (pjit/closed_call/remat/custom_*).

    Returns a ``ClosedJaxpr`` or ``None`` if the eqn is not call-like (or is a
    control-flow primitive, which gets its own stage kind instead).
    """
    if eqn.primitive.name in ("scan", "while", "cond"):
        return None
    for key in _CALL_JAXPR_KEYS:
        v = eqn.params.get(key)
        if isinstance(v, jex_core.ClosedJaxpr):
            return v
        if isinstance(v, jex_core.Jaxpr):
            return jex_core.ClosedJaxpr(v, ())
    return None


def _fresh_var(aval):
    """A new Var with the given aval, across JAX Var-constructor vintages."""
    try:
        return _src_core.Var("", aval)  # 0.4.3x: Var(suffix, aval)
    except TypeError:
        try:
            return _src_core.Var(aval)  # newer: Var(aval)
        except TypeError:
            return _src_core.Var(0, "", aval)  # oldest: Var(count, suffix, aval)


def _rewrite_eqn(eqn, resolve):
    """Rebuild an eqn with its invars resolved through the substitution."""
    new_invars = [resolve(a) for a in eqn.invars]
    if all(a is b for a, b in zip(new_invars, eqn.invars)):
        return eqn
    try:
        return eqn.replace(invars=new_invars)
    except AttributeError:  # very old JaxprEqn without .replace
        return _src_core.new_jaxpr_eqn(
            new_invars, eqn.outvars, eqn.primitive, eqn.params, eqn.effects,
            eqn.source_info,
        )


# ---------------------------------------------------------------------------
# plan IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stage:
    """Base class for plan stages."""


@dataclasses.dataclass
class LocalCompute(Stage):
    """A maximal run of non-communication eqns at a single placement."""

    at_groups: bool  # True: runs on every group; False: runs on the server
    eqns: List[Any] = dataclasses.field(default_factory=list)

    @property
    def kind(self) -> str:
        return "GROUP_COMPUTE" if self.at_groups else "SERVER_COMPUTE"


@dataclasses.dataclass
class Broadcast(Stage):
    """``broadcast@placement``: one level down the placement stack.

    ``placement`` is the addressed placement (the level whose group axis the
    broadcast inserts); ``source`` is the placement the operand lives at —
    ``"server"`` for the outermost level, else the next-outer placement."""

    eqn: Any = None
    kind: str = "BROADCAST"
    placement: str = "clients"
    source: str = "server"


@dataclasses.dataclass
class Reduce(Stage):
    """``reduce_*@placement``: one level up the placement stack.

    ``placement`` is the addressed placement (whose group axis the reduce
    removes); ``dest`` is where the result lands — ``"server"`` for the
    outermost level, else the next-outer placement."""

    op: str = "reduce_sum"
    eqn: Any = None
    kind: str = "REDUCE"
    placement: str = "clients"
    dest: str = "server"


@dataclasses.dataclass
class Transfer(Stage):
    """``stage_transfer@placement``: neighbor exchange along a stage level.

    ``placement`` is the addressed stage-kind placement. Each stage ships
    its slice ``shift`` neighbors down the pipeline (ICI traffic between
    adjacent stage shards); boundary slots are zero-filled unless ``wrap``.
    Unlike Broadcast/Reduce this stage does not move on the lattice: operand
    and result are both partitioned at the stage level's depth."""

    eqn: Any = None
    kind: str = "TRANSFER"
    placement: str = "stages"
    shift: int = 1
    wrap: bool = False


@dataclasses.dataclass
class LoopStage(Stage):
    """A scan/while whose body communicates: a sub-plan run per iteration.

    ``trip_count`` is the scan length, or ``None`` for a data-dependent
    ``while``. The body sub-plan's invars follow the loop binder convention
    (consts ++ carry [++ xs-slice for scan]).
    """

    eqn: Any = None
    body_plan: Optional["MapReducePlan"] = None
    trip_count: Optional[int] = None
    loop_kind: str = "scan"  # "scan" | "while"
    # while only: the predicate as a sub-plan, so communication inside the
    # loop condition (e.g. an adaptive-stopping reduce) is explicit too.
    cond_plan: Optional["MapReducePlan"] = None
    kind: str = "LOOP"


@dataclasses.dataclass
class CondStage(Stage):
    """A lax.cond whose branches communicate: one sub-plan per branch."""

    eqn: Any = None
    branch_plans: List["MapReducePlan"] = dataclasses.field(default_factory=list)
    kind: str = "COND"


@dataclasses.dataclass
class MapReducePlan:
    jaxpr: Any  # ClosedJaxpr
    partition_size: int  # total innermost groups (product over the stack)
    stages: List[Stage]
    # Lattice depth of each invar/outvar: the number of leading group axes
    # (0 = server). Bools compare equal to 0/1, so single-placement callers
    # keep seeing the legacy True/False surface.
    partitioned_invars: Tuple[int, ...]
    partitioned_outvars: Tuple[int, ...] = ()
    # The plan's placement stack, outermost first.
    placements: Tuple[Tuple[str, int], ...] = ()
    # Kind per placement level ("replicas" | "stages"), parallel to
    # ``placements`` (kept separate so legacy (name, size) consumers and
    # fingerprints of kind-free plans are untouched).
    placement_kinds: Tuple[str, ...] = ()
    # Full placement-sets (name prefixes) per invar/outvar.
    invar_placements: Tuple[PlacementSet, ...] = ()
    outvar_placements: Tuple[PlacementSet, ...] = ()
    # Values for constvars pulled in from inlined sub-jaxprs.
    extra_consts: Dict[Any, Any] = dataclasses.field(default_factory=dict)
    # jaxpr.outvars resolved through the inlining substitution: reading these
    # from the executor env yields the plan outputs.
    out_atoms: Tuple[Any, ...] = ()

    def __post_init__(self):
        if not self.out_atoms:
            self.out_atoms = tuple(self.jaxpr.jaxpr.outvars)
        if not self.placements:
            self.placements = (("clients", self.partition_size),)
        if not self.placement_kinds:
            self.placement_kinds = tuple("replicas" for _ in self.placements)
        if not self.invar_placements:
            names = tuple(n for n, _ in self.placements)
            self.invar_placements = tuple(
                names[: int(d)] for d in self.partitioned_invars
            )
        if not self.partitioned_outvars:
            self.partitioned_outvars = tuple(0 for _ in self.out_atoms)
        if not self.outvar_placements:
            names = tuple(n for n, _ in self.placements)
            self.outvar_placements = tuple(
                names[: int(d)] for d in self.partitioned_outvars
            )

    @property
    def placement_sizes(self) -> Tuple[int, ...]:
        return tuple(s for _, s in self.placements)

    # -- const environment --------------------------------------------------

    def const_env(self) -> Dict[Any, Any]:
        env = dict(zip(self.jaxpr.jaxpr.constvars, self.jaxpr.consts))
        env.update(self.extra_consts)
        return env

    def beam_consts(self) -> List[Any]:
        """Constant values for ``build_pipeline(..., consts=...)``.

        The list order matches the ``consts[i]`` indices in :meth:`to_beam`
        output (all plans depth-first, each plan's const env in order, first
        occurrence wins — the same dedup the emitter's index table uses).
        """
        seen: Dict[Any, Any] = {}
        for p in _all_plans(self):
            for atom, val in p.const_env().items():
                if atom not in seen:
                    seen[atom] = val
        return list(seen.values())

    # -- stage naming / traversal -------------------------------------------

    def named_stages(self, _prefix: str = ""):
        """Yield ``(name, stage, owner_plan)`` depth-first.

        Top-level stages are ``stage_0, stage_1, …``; a loop body's stages are
        ``stage_2_0, …``; cond branches ``stage_3_b0_0, …``.
        """
        for i, s in enumerate(self.stages):
            name = f"stage_{_prefix}{i}"
            yield name, s, self
            if isinstance(s, LoopStage):
                if s.cond_plan is not None:
                    yield from s.cond_plan.named_stages(f"{_prefix}{i}_c_")
                if s.body_plan is not None:
                    yield from s.body_plan.named_stages(f"{_prefix}{i}_")
            elif isinstance(s, CondStage):
                for b, bp in enumerate(s.branch_plans):
                    yield from bp.named_stages(f"{_prefix}{i}_b{b}_")

    # -- dataflow (per-stage inputs/outputs) ---------------------------------

    def stage_io(self) -> List[Tuple[Stage, List[Any], List[Any]]]:
        """For each top-level stage: (stage, input_atoms, output_vars).

        ``input_atoms`` are the non-literal atoms the stage reads that it does
        not itself define (in first-read order). ``output_vars`` are the vars
        it defines that a later stage reads or that are plan outputs.
        """
        reads: List[List[Any]] = []
        writes: List[List[Any]] = []
        for s in self.stages:
            reads.append(_stage_reads(s))
            writes.append(_stage_writes(s))
        out = []
        final = set(a for a in self.out_atoms if not _is_literal(a))
        for i, s in enumerate(self.stages):
            later = set()
            for r in reads[i + 1:]:
                later.update(r)
            outputs = [w for w in writes[i] if w in later or w in final]
            out.append((s, reads[i], outputs))
        return out

    def stage_fns(self) -> Dict[str, Callable]:
        """Real Python callables for every LocalCompute stage (jaxpr slicing).

        Each callable takes the stage's input atoms (see :meth:`stage_io`) as
        positional arguments — partitioned inputs stacked along the leading
        group axis — evaluates the stage's sliced eqns eagerly, and returns
        the stage's outputs as a tuple. Constants are closed over. Keys match
        :meth:`named_stages`.
        """
        fns: Dict[str, Callable] = {}
        io_cache: Dict[int, Dict[int, Tuple[List[Any], List[Any]]]] = {}
        const_cache: Dict[int, Dict[Any, Any]] = {}
        for name, stage, owner in self.named_stages():
            if not isinstance(stage, LocalCompute):
                continue
            key = id(owner)
            if key not in io_cache:
                io_cache[key] = {
                    id(s): (ins, outs) for s, ins, outs in owner.stage_io()
                }
                const_cache[key] = owner.const_env()
            ins, outs = io_cache[key][id(stage)]
            consts = const_cache[key]
            ins = [a for a in ins if a not in consts]
            fns[name] = _make_stage_fn(stage, ins, outs, consts)
        return fns

    # -- compiled execution --------------------------------------------------

    def compile(
        self,
        *,
        mesh=None,
        placement_axes=None,
        donate_argnums: Sequence[int] = (),
        loops: str = "native",
    ):
        """Lower the whole plan into ONE donation-aware jitted executable.

        Returns a :class:`repro.runtime.executor.CompiledPlan`: loop stages
        become ``lax.scan``/``lax.while_loop``, cond stages ``lax.switch``,
        adjacent local stages fuse, and executables are cached by
        ``(plan fingerprint, mesh shape, arg shapes/dtypes)``. Bitwise-equal
        to :func:`run_plan` on CPU (the correctness oracle); ``run_plan``
        stays the eager fallback. See the executor module for the donation
        rule and the elastic per-placement-level cache split.
        """
        from repro.runtime import executor as _executor  # lazy: no core->runtime cycle

        return _executor.compile_plan(
            self,
            mesh=mesh,
            placement_axes=placement_axes,
            donate_argnums=donate_argnums,
            loops=loops,
        )

    # -- emitters ----------------------------------------------------------

    def to_text(self) -> str:
        pp = _VarNamer()
        if len(self.placements) > 1 or "stages" in self.placement_kinds:
            header = (
                "MapReducePlan(placements="
                + "/".join(
                    f"{n}:{s}" + ("[stages]" if k == "stages" else "")
                    for (n, s), k in zip(self.placements, self.placement_kinds)
                )
                + ")"
            )
        else:
            header = f"MapReducePlan(partition_size={self.partition_size})"

        def place_tag(pl: PlacementSet) -> str:
            if not pl:
                return "SERVER"
            if len(self.placements) == 1 and len(pl) == 1:
                return "GROUPS"
            return "/".join(pl)

        lines = [
            header,
            "  inputs: "
            + ", ".join(
                f"{pp(v)}:{v.aval.str_short()} @{place_tag(pl)}"
                for v, pl in zip(
                    self.jaxpr.jaxpr.invars, self.invar_placements
                )
            ),
        ]
        lines.extend(_stage_text_lines(self.stages, indent=2, pp=pp))
        outs = ", ".join(pp(v) for v in self.jaxpr.jaxpr.outvars)
        lines.append(f"  outputs: {outs}")
        return "\n".join(lines)

    def to_beam(self) -> str:
        """An Apache Beam pipeline for this plan.

        Every referenced name is defined before use; local stages call the
        real callables from :meth:`stage_fns` (passed in as ``fns``).
        Partitioned values are keyed PCollections ``(group_id, value)``;
        server values are singleton PCollections; broadcasts become named
        side inputs. Loops with a static trip count unroll at pipeline
        construction time.
        """
        return _BeamEmitter(self).emit()

    # -- static analysis -----------------------------------------------------

    def analyze(
        self,
        *,
        donate_argnums: Sequence[int] = (),
        cross_validate: bool = False,
        comm_cost: bool = True,
    ):
        """Run every static-analysis pass over this plan without executing it.

        Returns a :class:`repro.analysis.AnalysisReport`: placement safety
        (the full-pass generalization of :meth:`check_locality`), donation/
        aliasing for the given ``donate_argnums``, retrace hazards over the
        captured consts, and the per-stage communication-cost model
        (``report.comm_cost``). ``report.ok`` is True iff no pass found an
        error; ``report.raise_if_errors()`` is the assert-style surface the
        oracle suite uses. ``cross_validate=True`` additionally checks the
        comm model against ``compat.cost_analysis`` on standalone-compiled
        reduce eqns (slow: one compile per comm stage).
        """
        from repro import analysis as _analysis  # lazy: no core->analysis cycle

        return _analysis.analyze_plan(
            self,
            donate_argnums=donate_argnums,
            cross_validate=cross_validate,
            comm_cost=comm_cost,
        )

    def comm_cost(self):
        """Static per-stage wire-byte model (DCN/ICI split, compress tags).

        Returns a :class:`repro.analysis.commcost.CommCostReport`; see
        ``report.dcn_bytes`` / ``report.ici_bytes`` / ``report.per_stage``.
        """
        from repro import analysis as _analysis  # lazy: no core->analysis cycle

        return _analysis.estimate_comm_cost(self)

    def subplans(self) -> List["MapReducePlan"]:
        """This plan and every nested sub-plan, depth-first in stage order."""
        return list(_all_plans(self))

    # -- structural checks --------------------------------------------------

    def communication_stages(self, recursive: bool = False) -> List[Stage]:
        out = []
        for name, s, _ in self.named_stages():
            if isinstance(s, (Broadcast, Reduce, Transfer)):
                if recursive or "_" not in name[len("stage_"):]:
                    out.append(s)
        return out

    def check_locality(self) -> None:
        """No communication primitive may hide inside a local stage.

        Checks *at any depth*: an opaque eqn whose sub-jaxpr communicates
        (e.g. a higher-order primitive the builder does not know how to
        stage, like ``custom_linear_solve``) fails loudly here instead of
        being silently mislabeled local compute.
        """
        for _, s, _ in self.named_stages():
            if isinstance(s, LocalCompute):
                for e in s.eqns:
                    if e.primitive.name in _COMM or any(
                        _contains_comm(sub.jaxpr)
                        for sub in _eqn_subjaxprs(e)
                    ):
                        raise AssertionError(
                            f"communication primitive inside {s.kind} stage "
                            f"(eqn {e.primitive.name}): this control-flow "
                            f"structure is not representable as a MapReduce "
                            f"plan yet"
                        )


def _stage_reads(stage: Stage) -> List[Any]:
    """Non-literal atoms a stage reads but does not define (first-read order)."""
    if isinstance(stage, LocalCompute):
        seen, defined, reads = set(), set(), []
        for eqn in stage.eqns:
            for a in eqn.invars:
                if _is_literal(a) or a in defined or a in seen:
                    continue
                seen.add(a)
                reads.append(a)
            defined.update(o for o in eqn.outvars if not _is_dropvar(o))
        return reads
    seen, reads = set(), []
    for a in stage.eqn.invars:
        if _is_literal(a) or a in seen:
            continue
        seen.add(a)
        reads.append(a)
    return reads


def _stage_writes(stage: Stage) -> List[Any]:
    if isinstance(stage, LocalCompute):
        return [o for e in stage.eqns for o in e.outvars if not _is_dropvar(o)]
    return [o for o in stage.eqn.outvars if not _is_dropvar(o)]


def _make_stage_fn(stage, ins, outs, consts):
    # Consts are hoisted into the closure ONCE (beam_consts-style): per call
    # we only bind the stage inputs, instead of re-binding every captured
    # constant into a fresh env — on the compiled path this also means the
    # constants are staged into the executable once, not re-staged per round.
    const_env = dict(consts)

    def fn(*vals):
        if len(vals) != len(ins):
            raise TypeError(
                f"stage fn expects {len(ins)} inputs, got {len(vals)}"
            )
        env = dict(zip(ins, vals))

        def read(a):
            if _is_literal(a):
                return a.val
            if a in env:
                return env[a]
            return const_env[a]

        for eqn in stage.eqns:
            results = _eval_eqn(eqn, read)
            for o, val in zip(eqn.outvars, results):
                if not _is_dropvar(o):
                    env[o] = val
        return tuple(read(o) for o in outs)

    fn.input_vars = list(ins)
    fn.output_vars = list(outs)
    return fn


class _VarNamer:
    """Stable short names (a, b, …, aa, …) for jaxpr atoms in to_text()."""

    def __init__(self):
        self._names: Dict[Any, str] = {}

    def __call__(self, atom) -> str:
        if _is_literal(atom):
            return repr(np.asarray(atom.val).tolist())
        if atom not in self._names:
            i = len(self._names)
            name = ""
            while True:
                name = chr(ord("a") + i % 26) + name
                i = i // 26 - 1
                if i < 0:
                    break
            self._names[atom] = name
        return self._names[atom]


def _stage_text_lines(
    stages: Sequence[Stage], indent: int, pp: Optional[_VarNamer] = None
) -> List[str]:
    pp = pp or _VarNamer()
    pad = " " * indent
    lines: List[str] = []
    for i, s in enumerate(stages):
        if isinstance(s, LocalCompute):
            ops = ", ".join(e.primitive.name for e in s.eqns)
            lines.append(f"{pad}stage {i}: {s.kind} [{ops}]")
        elif isinstance(s, Broadcast):
            route = (
                "server->groups"
                if s.source == "server"
                else f"{s.source}->{s.placement}"
            )
            lines.append(
                f"{pad}stage {i}: BROADCAST {route} @{s.placement} "
                f"({pp(s.eqn.invars[0])} -> {pp(s.eqn.outvars[0])})"
            )
        elif isinstance(s, Reduce):
            route = (
                "groups->server"
                if s.dest == "server"
                else f"{s.placement}->{s.dest}"
            )
            lines.append(
                f"{pad}stage {i}: {s.op.upper()} {route} @{s.placement} "
                f"({pp(s.eqn.invars[0])} -> {pp(s.eqn.outvars[0])})"
            )
        elif isinstance(s, Transfer):
            shift = f"{s.shift:+d}" + (" wrap" if s.wrap else "")
            lines.append(
                f"{pad}stage {i}: TRANSFER shift={shift} @{s.placement} "
                f"({pp(s.eqn.invars[0])} -> {pp(s.eqn.outvars[0])})"
            )
        elif isinstance(s, LoopStage):
            trip = "?" if s.trip_count is None else str(s.trip_count)
            lines.append(
                f"{pad}stage {i}: LOOP[{s.loop_kind}] trip_count={trip}:"
            )
            if s.cond_plan is not None and s.cond_plan.stages:
                lines.append(f"{pad}  cond:")
                lines.extend(
                    _stage_text_lines(s.cond_plan.stages, indent + 4, pp)
                )
                lines.append(f"{pad}  body:")
            lines.extend(
                _stage_text_lines(s.body_plan.stages, indent + 4, pp)
            )
        elif isinstance(s, CondStage):
            lines.append(
                f"{pad}stage {i}: COND over {len(s.branch_plans)} branches:"
            )
            for b, bp in enumerate(s.branch_plans):
                lines.append(f"{pad}  branch {b}:")
                lines.extend(_stage_text_lines(bp.stages, indent + 4, pp))
    return lines


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------


def trace(fn: Callable, *example_args) -> Any:
    """ClosedJaxpr of ``fn`` (which must already carry its drjax context)."""
    return jax.make_jaxpr(fn)(*example_args)


def _placement_depth(shape, sizes: Tuple[int, ...]) -> int:
    """Largest k such that the k leading dims match the k outermost
    placement sizes (the lattice-depth heuristic for undeclared inputs)."""
    k = 0
    while k < len(sizes) and k < len(shape) and shape[k] == sizes[k]:
        k += 1
    return k


def build_plan(
    closed: Any,
    partition_size,
    partitioned_invars: Optional[Sequence[Any]] = None,
) -> MapReducePlan:
    """Segment a jaxpr into MapReduce stages (recursing into control flow).

    ``partition_size`` is the placement spec: an int (the paper's flat API —
    one "clients" placement), an ordered mapping ``{"pods": P, "clients": m}``
    (outermost first), a ``PlacementContext``, or a (name, size) sequence.

    ``partitioned_invars[i]`` declares input i's position on the placement
    lattice: a bool (legacy: False = server, True = fully partitioned), an
    int depth (number of leading group axes), or a placement-name prefix
    tuple. If omitted, an input's depth is the longest prefix of placement
    sizes matching its leading dims — right for all examples here, but
    callers with ambiguous shapes should pass it explicitly.
    """
    triples = _normalize_placements(partition_size)
    placements = tuple((n, s) for n, s, _ in triples)
    kinds = tuple(k for _, _, k in triples)
    names = tuple(n for n, _ in placements)
    sizes = tuple(s for _, s in placements)
    total = math.prod(sizes)

    def norm_part(entry) -> PlacementSet:
        if isinstance(entry, tuple):
            return entry
        if entry is True:
            return names
        if entry is False or entry is None:
            return ()
        return names[: int(entry)]

    jaxpr = closed.jaxpr
    if partitioned_invars is None:
        invar_placements = tuple(
            names[: _placement_depth(v.aval.shape, sizes)]
            for v in jaxpr.invars
        )
    else:
        invar_placements = tuple(norm_part(e) for e in partitioned_invars)

    placed: Dict[Any, PlacementSet] = {}  # defining var -> placement prefix
    subst: Dict[Any, Any] = {}  # call-boundary var -> defining atom
    extra_consts: Dict[Any, Any] = {}
    stages: List[Stage] = []

    for v, p in zip(jaxpr.invars, invar_placements):
        placed[v] = p
    for v in jaxpr.constvars:
        placed[v] = ()

    def resolve(a):
        while not _is_literal(a) and a in subst:
            a = subst[a]
        return a

    def is_part(a) -> PlacementSet:
        a = resolve(a)
        if _is_literal(a):
            return ()
        return placed.get(a, ())

    def append_local(eqn, at_groups: bool):
        if (
            stages
            and isinstance(stages[-1], LocalCompute)
            and stages[-1].at_groups == at_groups
        ):
            stages[-1].eqns.append(eqn)
        else:
            stages.append(LocalCompute(at_groups=at_groups, eqns=[eqn]))

    def inline_call(eqn, sub):
        inner = sub.jaxpr
        for cv, cval in zip(inner.constvars, sub.consts):
            extra_consts[cv] = cval
            placed[cv] = ()
        for iv, outer in zip(inner.invars, eqn.invars):
            subst[iv] = resolve(outer)
        # Alpha-rename every var the body defines: jit caches one jaxpr per
        # function, so the same sub-jaxpr (same Var objects) can be inlined
        # at several call sites — without fresh outvars the second inline
        # would overwrite the first's values in the executor env.
        renamed = []
        for ie in inner.eqns:
            new_outvars = []
            for o in ie.outvars:
                if _is_dropvar(o):
                    new_outvars.append(o)
                else:
                    fresh = _fresh_var(o.aval)
                    subst[o] = fresh
                    new_outvars.append(fresh)
            renamed.append(ie.replace(outvars=new_outvars))
        emit(renamed)
        for outer_o, inner_o in zip(eqn.outvars, inner.outvars):
            if _is_dropvar(outer_o):
                continue
            subst[outer_o] = resolve(inner_o)

    def emit_scan(eqn):
        params = eqn.params
        nc, ncar = params["num_consts"], params["num_carry"]
        body = params["jaxpr"]  # ClosedJaxpr
        consts_p = [is_part(a) for a in eqn.invars[:nc]]
        carry_p = [is_part(a) for a in eqn.invars[nc : nc + ncar]]
        # xs binders see one slice per step: the scan axis is gone, so the
        # lattice-depth heuristic applies to the *sliced* aval.
        xs_p = [
            names[: _placement_depth(b.aval.shape, sizes)]
            for b in body.jaxpr.invars[nc + ncar :]
        ]
        # Fixed point over the carry on the placement lattice: a carry that
        # climbs the lattice after one iteration keeps the joined placement
        # for the whole loop.
        body_plan = None
        for _ in range(ncar + 1):
            body_plan = build_plan(
                body, triples,
                partitioned_invars=consts_p + carry_p + xs_p,
            )
            out_p = list(body_plan.outvar_placements[:ncar])
            new_carry = [_join(a, b) for a, b in zip(carry_p, out_p)]
            if new_carry == carry_p:
                break
            carry_p = new_carry
        stages.append(
            LoopStage(
                eqn=_rewrite_eqn(eqn, resolve),
                body_plan=body_plan,
                trip_count=params["length"],
                loop_kind="scan",
            )
        )
        outs_p = body_plan.outvar_placements
        # carry outputs keep the fixed-point placement; stacked ys are
        # server-placed: the new time axis leads, so the group axes (if any)
        # are no longer the leading axes and downstream consumption of the
        # whole (T, ...) stack happens at the server/driver.
        num_ys = len(eqn.outvars) - ncar
        for o, p in zip(
            eqn.outvars, list(outs_p[:ncar]) + [()] * num_ys
        ):
            if not _is_dropvar(o):
                placed[o] = p

    def emit_while(eqn):
        params = eqn.params
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        body = params["body_jaxpr"]  # ClosedJaxpr
        cond_consts_p = [is_part(a) for a in eqn.invars[:cn]]
        body_consts_p = [is_part(a) for a in eqn.invars[cn : cn + bn]]
        carry_p = [is_part(a) for a in eqn.invars[cn + bn :]]
        body_plan = None
        for _ in range(len(carry_p) + 1):
            body_plan = build_plan(
                body, triples,
                partitioned_invars=body_consts_p + carry_p,
            )
            out_p = list(body_plan.outvar_placements)
            new_carry = [_join(a, b) for a, b in zip(carry_p, out_p)]
            if new_carry == carry_p:
                break
            carry_p = new_carry
        # The predicate runs once per iteration too: plan it so communication
        # inside the cond (adaptive stopping) shows up as explicit stages.
        cond_plan = build_plan(
            params["cond_jaxpr"], triples,
            partitioned_invars=cond_consts_p + carry_p,
        )
        stages.append(
            LoopStage(
                eqn=_rewrite_eqn(eqn, resolve),
                body_plan=body_plan,
                trip_count=None,
                loop_kind="while",
                cond_plan=cond_plan,
            )
        )
        for o, p in zip(eqn.outvars, carry_p):
            if not _is_dropvar(o):
                placed[o] = p

    def emit_cond(eqn):
        branches = eqn.params["branches"]
        ops_p = [is_part(a) for a in eqn.invars[1:]]
        branch_plans = [
            build_plan(b, triples, partitioned_invars=ops_p)
            for b in branches
        ]
        stages.append(
            CondStage(
                eqn=_rewrite_eqn(eqn, resolve), branch_plans=branch_plans
            )
        )
        for i, o in enumerate(eqn.outvars):
            if not _is_dropvar(o):
                p = ()
                for bp in branch_plans:
                    p = _join(p, bp.outvar_placements[i])
                placed[o] = p

    def emit(eqns):
        for eqn in eqns:
            name = eqn.primitive.name
            has_comm = any(
                _contains_comm(sub.jaxpr) for sub in _eqn_subjaxprs(eqn)
            )
            if name == "drjax_broadcast":
                enames, i = _eqn_placement(eqn)
                in_pl = is_part(eqn.invars[0])
                # A broadcast at level i expects a depth-i operand; a deeper
                # operand on the SAME name chain would duplicate a level the
                # value already has — the result leaves the prefix lattice.
                if len(in_pl) > i and in_pl[: i + 1] == enames[: i + 1]:
                    raise ValueError(
                        f"broadcast@{enames[i]} over a value already "
                        f"partitioned at {in_pl}: only the next level of a "
                        f"value's placement prefix can be broadcast"
                    )
                stages.append(
                    Broadcast(
                        eqn=_rewrite_eqn(eqn, resolve),
                        placement=enames[i],
                        source=enames[i - 1] if i > 0 else "server",
                    )
                )
                for o in eqn.outvars:
                    if not _is_dropvar(o):
                        placed[o] = enames[: i + 1]
            elif name == "drjax_stage_transfer":
                enames, i = _eqn_placement(eqn)
                stages.append(
                    Transfer(
                        eqn=_rewrite_eqn(eqn, resolve),
                        placement=enames[i],
                        shift=int(eqn.params.get("shift", 1)),
                        wrap=bool(eqn.params.get("wrap", False)),
                    )
                )
                # No lattice movement: a transfer permutes values among the
                # stage groups, so the result stays at the stage level's
                # depth (i + 1 leading group axes).
                for o in eqn.outvars:
                    if not _is_dropvar(o):
                        placed[o] = enames[: i + 1]
            elif name in _COMM:
                enames, i = _eqn_placement(eqn)
                in_pl = is_part(eqn.invars[0])
                # Reducing an OUTER level of a deeper value (e.g.
                # reduce@pods of a (pods, clients) value) would yield
                # "clients without pods" — not a stack prefix, so neither
                # this lattice nor the Beam keying can represent it. Fail
                # loudly instead of emitting a wrong pipeline.
                if len(in_pl) > i + 1 and in_pl[: i + 1] == enames[: i + 1]:
                    raise ValueError(
                        f"{_COMM[name]}@{enames[i]} reduces an outer level "
                        f"of a value partitioned at {in_pl}: only the "
                        f"innermost level of a value's placement prefix can "
                        f"be reduced (reduce {in_pl[-1]!r} first)"
                    )
                stages.append(
                    Reduce(
                        op=_COMM[name],
                        eqn=_rewrite_eqn(eqn, resolve),
                        placement=enames[i],
                        dest=enames[i - 1] if i > 0 else "server",
                    )
                )
                for o in eqn.outvars:
                    if not _is_dropvar(o):
                        placed[o] = enames[:i]
            elif name == "scan" and has_comm:
                emit_scan(eqn)
            elif name == "while" and has_comm:
                emit_while(eqn)
            elif name == "cond" and has_comm:
                emit_cond(eqn)
            elif has_comm and (sub := _call_subjaxpr(eqn)) is not None and len(
                sub.jaxpr.invars
            ) == len(eqn.invars):
                inline_call(eqn, sub)
            else:
                eqn2 = _rewrite_eqn(eqn, resolve)
                p = ()
                for a in eqn.invars:
                    p = _join(p, is_part(a))
                for o in eqn.outvars:
                    if not _is_dropvar(o):
                        placed[o] = p
                append_local(eqn2, bool(p))

    emit(jaxpr.eqns)

    out_atoms = tuple(resolve(v) for v in jaxpr.outvars)
    outvar_placements = tuple(is_part(a) for a in jaxpr.outvars)
    plan = MapReducePlan(
        jaxpr=closed,
        partition_size=total,
        stages=stages,
        partitioned_invars=tuple(len(p) for p in invar_placements),
        partitioned_outvars=tuple(len(p) for p in outvar_placements),
        placements=placements,
        placement_kinds=kinds,
        invar_placements=invar_placements,
        outvar_placements=outvar_placements,
        extra_consts=extra_consts,
        out_atoms=out_atoms,
    )
    plan.check_locality()
    return plan


# ---------------------------------------------------------------------------
# reference plan executor (mini federated runtime)
# ---------------------------------------------------------------------------


def _eval_eqn(eqn, read):
    """Evaluate one jaxpr eqn eagerly."""
    invals = [read(v) for v in eqn.invars]
    subfuns, params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **params)
    return out if eqn.primitive.multiple_results else [out]


def run_plan(plan: MapReducePlan, *args):
    """Execute the plan stage by stage, honoring staged control flow.

    Partitioned values live as stacked arrays but are only *created* by
    Broadcast stages and only *consumed across groups* by Reduce stages;
    ``check_locality`` guarantees every GROUP_COMPUTE stage is group-elementwise
    (it came from a vmap body). Loop stages iterate their body sub-plan
    (scan semantics: consts ++ carry ++ xs-slices, stacked ys); cond stages
    select and run one branch sub-plan. This mirrors how a federated/Beam
    backend would run the plan: local stages per group, explicit communication
    between, with the driver owning control flow.
    """
    return _execute_plan(plan, list(args))


def _execute_plan(plan: MapReducePlan, args: List[Any]) -> List[Any]:
    jaxpr = plan.jaxpr.jaxpr
    env: Dict[Any, Any] = {}

    def read(a):
        if _is_literal(a):
            return a.val
        return env[a]

    def write(v, val):
        if not _is_dropvar(v):
            env[v] = val

    for v, val in zip(jaxpr.constvars, plan.jaxpr.consts):
        write(v, val)
    for v, val in plan.extra_consts.items():
        write(v, val)
    for v, val in zip(jaxpr.invars, args):
        write(v, val)

    for stage in plan.stages:
        if isinstance(stage, (Broadcast, Reduce, Transfer)):
            eqn = stage.eqn
            for o, val in zip(eqn.outvars, _eval_eqn(eqn, read)):
                write(o, val)
        elif isinstance(stage, LocalCompute):
            for eqn in stage.eqns:
                for o, val in zip(eqn.outvars, _eval_eqn(eqn, read)):
                    write(o, val)
        elif isinstance(stage, LoopStage):
            _run_loop_stage(stage, read, write)
        elif isinstance(stage, CondStage):
            _run_cond_stage(stage, read, write)
        else:  # pragma: no cover - future stage kinds
            raise TypeError(f"unknown stage kind: {stage!r}")

    return [read(a) for a in plan.out_atoms]


def _run_loop_stage(stage: LoopStage, read, write):
    eqn = stage.eqn
    params = eqn.params
    if stage.loop_kind == "scan":
        nc, ncar = params["num_consts"], params["num_carry"]
        length = params["length"]
        reverse = params.get("reverse", False)
        invals = [read(a) for a in eqn.invars]
        consts = invals[:nc]
        carry = list(invals[nc : nc + ncar])
        xs = invals[nc + ncar :]
        num_ys = len(eqn.outvars) - ncar
        ys: List[Tuple[Any, ...]] = []
        indices = range(length - 1, -1, -1) if reverse else range(length)
        for i in indices:
            xi = [x[i] for x in xs]
            outs = _execute_plan(stage.body_plan, consts + carry + xi)
            carry = list(outs[:ncar])
            ys.append(tuple(outs[ncar:]))
        if reverse:
            ys.reverse()
        if length == 0:
            stacked = [
                jnp.zeros(v.aval.shape, v.aval.dtype)
                for v in eqn.outvars[ncar:]
            ]
        else:
            stacked = [
                jnp.stack([ys[t][j] for t in range(length)])
                for j in range(num_ys)
            ]
        for o, val in zip(eqn.outvars, carry + stacked):
            write(o, val)
    else:  # while
        cn, bn = params["cond_nconsts"], params["body_nconsts"]
        invals = [read(a) for a in eqn.invars]
        cond_consts = invals[:cn]
        body_consts = invals[cn : cn + bn]
        carry = list(invals[cn + bn :])

        def pred(carry):
            if stage.cond_plan is not None:
                return bool(
                    _execute_plan(stage.cond_plan, cond_consts + carry)[0]
                )
            cond_jaxpr = params["cond_jaxpr"]
            return bool(
                _src_core.eval_jaxpr(
                    cond_jaxpr.jaxpr, cond_jaxpr.consts, *cond_consts, *carry
                )[0]
            )

        while pred(carry):
            carry = list(_execute_plan(stage.body_plan, body_consts + carry))
        for o, val in zip(eqn.outvars, carry):
            write(o, val)


def _run_cond_stage(stage: CondStage, read, write):
    eqn = stage.eqn
    idx = int(read(eqn.invars[0]))
    idx = min(max(idx, 0), len(stage.branch_plans) - 1)
    ops = [read(a) for a in eqn.invars[1:]]
    outs = _execute_plan(stage.branch_plans[idx], ops)
    for o, val in zip(eqn.outvars, outs):
        write(o, val)


def count_primitives(closed: Any) -> Dict[str, int]:
    """Histogram of DrJAX primitives in a jaxpr (recursing into sub-jaxprs)."""
    counts: Dict[str, int] = {}

    def visit(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _COMM:
                counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
            for sub in _eqn_subjaxprs(eqn):
                visit(sub.jaxpr)

    visit(closed.jaxpr)
    return counts


# ---------------------------------------------------------------------------
# Apache Beam emitter
# ---------------------------------------------------------------------------


_BEAM_PREAMBLE = """\
# Apache Beam pipeline generated from a MapReducePlan.
# `fns` are the real Python callables sliced out of the jaxpr:
#   fns = plan.stage_fns()
# Partitioned values are keyed PCollections of (group_id, value); server
# values are singleton PCollections; broadcasts are named side inputs.
# Group stages apply the sliced (group-batched) jaxpr to a 1-row stack per
# element; this assumes the sliced eqns are polymorphic in the leading axis
# (true for vmap-produced elementwise bodies).
import apache_beam as beam
import numpy as np


def _reduce_sum(vals):
  return np.sum(np.stack(list(vals)), axis=0)


def _reduce_mean(vals):
  vs = np.stack(list(vals))
  return np.sum(vs, axis=0) / vs.shape[0]


def _reduce_max(vals):
  return np.max(np.stack(list(vals)), axis=0)


def _lift(v, k):
  # One group's element -> a rank-(k + v.ndim) stack slice: group stages
  # apply the sliced (group-batched) jaxpr, which expects k leading group
  # axes (one per placement level of the value).
  v = np.asarray(v)
  return v.reshape((1,) * k + v.shape)


def _unkey(rows, shape):
  # (key_tuple, value) pairs -> one stacked array with the placement-stack
  # axes restored (row-major over the sorted key tuples).
  arr = np.stack([v for _, v in sorted(rows)])
  return arr.reshape(tuple(shape) + arr.shape[1:])


def _stage_shift(v, axis, shift, wrap):
  # stage_transfer on a stacked (non-keyed) value: roll the stage axis,
  # zero-filling the slots the shift vacated unless wrapping.
  out = np.roll(np.asarray(v), shift, axis=axis)
  if not wrap and shift != 0:
    idx = [slice(None)] * out.ndim
    idx[axis] = slice(0, shift) if shift > 0 else slice(shift, None)
    out[tuple(idx)] = 0
  return out
"""


class _BeamEmitter:
    """Emit a Beam pipeline where every referenced name is defined."""

    def __init__(self, plan: MapReducePlan):
        self.plan = plan
        self.lines: List[str] = []
        self.names: Dict[Any, str] = {}  # atom -> python identifier
        self.kinds: Dict[str, str] = {}  # identifier -> plain|server|group|side
        self._n = 0
        self._labels = 0
        self._indent = 1
        self._loop_vars: List[str] = []
        # broadcast output name -> (pre-broadcast source name, source kind);
        # lets a reduce over a broadcast re-materialize the n replicas
        self.side_src: Dict[str, Tuple[str, str]] = {}
        # Nested plans key partitioned PCollections by placement-path
        # TUPLES (g0, g1, ...); flat plans keep legacy int keys.
        self.nested = len(plan.placements) > 1
        # identifier -> number of key levels for "group"-kind values
        self.depths: Dict[str, int] = {}
        # consts[i] indices, matching plan.beam_consts()
        self._const_index: Dict[Any, int] = {}
        for p in _all_plans(plan):
            for atom in p.const_env():
                self._const_index.setdefault(atom, len(self._const_index))

    # -- low-level helpers ---------------------------------------------------

    def line(self, text: str):
        self.lines.append("  " * self._indent + text)

    def fresh(self, prefix: str = "t") -> str:
        self._n += 1
        return f"{prefix}{self._n}"

    def label(self) -> str:
        """A unique beam step label expression (f-string inside loops)."""
        self._labels += 1
        base = f"S{self._labels}"
        if self._loop_vars:
            suffix = "_".join("{%s}" % v for v in self._loop_vars)
            return f"f'{base}_{suffix}'"
        return f"'{base}'"

    def assign(self, name: str, rhs: str, kind: str, comment: str = ""):
        tail = f"  # {comment}" if comment else ""
        self.line(f"{name} = {rhs}{tail}")
        self.kinds[name] = kind

    # -- naming --------------------------------------------------------------

    def name_of(self, atom, plan: MapReducePlan) -> str:
        """Identifier for an atom, materializing literals/consts on demand."""
        if _is_literal(atom):
            name = self.fresh("lit")
            self.assign(name, _literal_src(atom.val), "plain", "literal")
            return name
        if atom in self.names:
            return self.names[atom]
        if atom in self._const_index:
            name = self.fresh("c")
            self.assign(
                name, f"np.asarray(consts[{self._const_index[atom]}])",
                "plain", "captured constant (see plan.beam_consts())",
            )
            self.names[atom] = name
            return name
        # An atom we never saw defined: surface it as an explicit hole rather
        # than emitting a dangling reference.
        name = self.fresh("undef")
        self.assign(name, "None", "plain", f"unbound atom {atom} (bug?)")
        self.names[atom] = name
        return name

    def bind(self, atom, name: str):
        self.names[atom] = name

    # -- conversions ---------------------------------------------------------

    def to_group(self, name: str) -> str:
        """Convert a server/plain value (stacked rows) into a keyed PColl."""
        kind = self.kinds.get(name, "plain")
        if kind == "group":
            return name
        out = self.fresh("g")
        n0 = self.plan.placement_sizes[0]
        if kind == "plain":
            if self.nested:
                self.assign(
                    out,
                    f"p | {self.label()} >> beam.Create("
                    f"[((j,), {name}[j]) for j in range({n0})])",
                    "group", "key by group (placement path)",
                )
            else:
                self.assign(
                    out,
                    f"p | {self.label()} >> beam.Create(list(enumerate({name})))",
                    "group", "key by group",
                )
        elif kind == "server":
            if self.nested:
                self.assign(
                    out,
                    f"{name} | {self.label()} >> "
                    f"beam.FlatMap(lambda v: [((j,), v[j]) for j in range({n0})])",
                    "group", "key by group (placement path)",
                )
            else:
                self.assign(
                    out,
                    f"{name} | {self.label()} >> "
                    f"beam.FlatMap(lambda v: list(enumerate(v)))",
                    "group", "key by group",
                )
        else:  # side input object: no pipeline handle; leave a typed hole
            self.assign(out, f"{name}", "group", "side input reused per group")
        self.depths[out] = 1
        return out

    def to_server(self, name: str) -> str:
        kind = self.kinds.get(name, "plain")
        if kind in ("server", "plain", "side"):
            return name
        out = self.fresh("s")
        depth = self.depths.get(name, 1)
        if self.nested or depth > 1:
            sizes = self.plan.placement_sizes[:depth]
            self.assign(
                out,
                f"{name} | {self.label()} >> beam.combiners.ToList() "
                f"| {self.label()} >> "
                f"beam.Map(lambda rows: _unkey(rows, {tuple(sizes)!r}))",
                "server", "collect groups to a stacked server value",
            )
        else:
            self.assign(
                out,
                f"{name} | {self.label()} >> beam.combiners.ToList() "
                f"| {self.label()} >> "
                f"beam.Map(lambda rows: np.stack([v for _, v in sorted(rows)]))",
                "server", "collect groups to a stacked server value",
            )
        return out

    # -- emission ------------------------------------------------------------

    def emit(self) -> str:
        plan = self.plan
        self.lines = _BEAM_PREAMBLE.splitlines()
        self.lines.append("")
        self.lines.append("")
        self.lines.append("def build_pipeline(p, args, fns, consts=()):")
        n = plan.partition_size
        if self.nested:
            all_sizes = tuple(plan.placement_sizes)
            self.assign(
                "groups",
                f"p | 'Groups' >> beam.Create("
                f"[(idx, ()) for idx in np.ndindex(*{all_sizes!r})])",
                "group", "one element per innermost group (placement path)",
            )
            self.depths["groups"] = len(all_sizes)
        else:
            self.assign(
                "groups",
                f"p | 'Groups' >> beam.Create([(g, ()) for g in range({n})])",
                "group", "one element per group",
            )
            self.depths["groups"] = 1
        for i, (v, part) in enumerate(
            zip(plan.jaxpr.jaxpr.invars, plan.partitioned_invars)
        ):
            name = self.fresh("in_")
            k = int(part)
            if k and (self.nested or k > 1):
                sizes = tuple(plan.placement_sizes[:k])
                self.assign(
                    name,
                    f"p | {self.label()} >> beam.Create("
                    f"[(idx, args[{i}][idx]) for idx in "
                    f"np.ndindex(*{sizes!r})])",
                    "group",
                    f"plan input {i} @{'/'.join(plan.invar_placements[i])}",
                )
                self.depths[name] = k
            elif k:
                self.assign(
                    name,
                    f"p | {self.label()} >> "
                    f"beam.Create(list(enumerate(args[{i}])))",
                    "group", f"plan input {i} @GROUPS",
                )
                self.depths[name] = 1
            else:
                self.assign(
                    name,
                    f"p | {self.label()} >> beam.Create([args[{i}]])",
                    "server", f"plan input {i} @SERVER",
                )
            self.bind(v, name)
        self.emit_plan_stages(plan, prefix="")
        outs = [self.name_of(a, plan) for a in plan.out_atoms]
        self.line(f"return [{', '.join(outs)}]")
        return "\n".join(self.lines)

    def emit_plan_stages(self, plan: MapReducePlan, prefix: str):
        for i, (stage, reads, outs) in enumerate(plan.stage_io()):
            sname = f"stage_{prefix}{i}"
            if isinstance(stage, Broadcast):
                self.emit_broadcast(stage, plan)
            elif isinstance(stage, Reduce):
                self.emit_reduce(stage, plan)
            elif isinstance(stage, Transfer):
                self.emit_transfer(stage, plan)
            elif isinstance(stage, LocalCompute):
                self.emit_local(stage, plan, sname, outs)
            elif isinstance(stage, LoopStage):
                self.emit_loop(stage, plan, f"{prefix}{i}", outs)
            elif isinstance(stage, CondStage):
                self.emit_cond(stage, plan, f"{prefix}{i}")

    def _stage_placement(self, stage) -> Tuple[int, int]:
        """(addressed stack index, addressed placement size) of a comm eqn."""
        pctx = stage.eqn.params.get("pctx")
        if pctx is None:
            return 0, self.plan.partition_size
        i = pctx.index_of(stage.eqn.params.get("placement"))
        return i, pctx.placements[i].size

    def emit_broadcast(self, stage: Broadcast, plan):
        src = self.name_of(stage.eqn.invars[0], plan)
        out = self.fresh("bc")
        i, size = self._stage_placement(stage)
        kind = self.kinds.get(src, "plain")
        if self.nested or i > 0:
            # Nested stacks materialize keyed PCollections (placement-path
            # tuple keys) instead of side inputs, so a later broadcast@inner
            # can extend the key and a reduce@inner can shorten it.
            tag = f"BROADCAST {stage.source}->{stage.placement}"
            if kind == "group":
                self.assign(
                    out,
                    f"{src} | {self.label()} >> beam.FlatMap("
                    f"lambda kv: [(kv[0] + (j,), kv[1]) "
                    f"for j in range({size})])",
                    "group", f"{tag} (extend placement path)",
                )
                self.depths[out] = self.depths.get(src, 1) + 1
            elif kind == "server":
                self.assign(
                    out,
                    f"p | {self.label()} >> beam.Create("
                    f"[(j,) for j in range({size})]) "
                    f"| {self.label()} >> beam.Map("
                    f"lambda k, _v: ((k,) if not isinstance(k, tuple) "
                    f"else k, _v), beam.pvalue.AsSingleton({src}))",
                    "group", f"{tag} (materialized per group)",
                )
                self.depths[out] = 1
            else:  # plain python value
                self.assign(
                    out,
                    f"p | {self.label()} >> beam.Create("
                    f"[((j,), {src}) for j in range({size})])",
                    "group", f"{tag} (materialized per group)",
                )
                self.depths[out] = 1
            self.bind(stage.eqn.outvars[0], out)
            return
        if kind == "server":
            self.assign(
                out, f"beam.pvalue.AsSingleton({src})", "side",
                "BROADCAST server->groups (side input)",
            )
            self.side_src[out] = (src, "server")
        else:  # plain python value: replicating it is free
            self.assign(out, src, "plain", "BROADCAST (replicated value)")
            self.side_src[out] = (src, "plain")
        self.bind(stage.eqn.outvars[0], out)

    def emit_reduce(self, stage: Reduce, plan):
        src = self.name_of(stage.eqn.invars[0], plan)
        combiner = f"_{stage.op}"
        out = self.fresh("r")
        kind = self.kinds.get(src, "plain")
        i, n = self._stage_placement(stage)
        if kind == "group":
            depth = self.depths.get(src, 1)
            if depth >= 2:
                # An inner-placement reduce: shorten the placement path by
                # one level and combine per remaining key — one shuffle per
                # stage, so a hierarchical reduce stages as two shuffles.
                self.assign(
                    out,
                    f"{src} | {self.label()} >> beam.Map("
                    f"lambda kv: (kv[0][:-1], kv[1])) "
                    f"| {self.label()} >> beam.CombinePerKey({combiner})",
                    "group",
                    f"{stage.op.upper()} {stage.placement}->{stage.dest} "
                    f"(combine per {stage.dest})",
                )
                self.depths[out] = depth - 1
                self.bind(stage.eqn.outvars[0], out)
                return
            if i + 1 != depth:
                self.line(
                    f"# NOTE: {stage.op}@{stage.placement} crosses a "
                    f"placement-regrouping boundary (value tracked at "
                    f"depth {depth}, eqn addresses level {i}); the global "
                    f"combine below approximates the per-{stage.dest} stage"
                )
        if src in self.side_src:
            # reducing a broadcast directly: combine n replicas of the
            # pre-broadcast server value (AsSingleton objects aren't listable)
            base, bkind = self.side_src[src]
            if bkind == "server":
                self.assign(
                    out,
                    f"{base} | {self.label()} >> "
                    f"beam.Map(lambda v: {combiner}([v] * {n}))",
                    "server", f"{stage.op.upper()} over {n} broadcast replicas",
                )
            else:
                self.assign(
                    out, f"{combiner}([{base}] * {n})", "plain",
                    f"{stage.op.upper()} over {n} broadcast replicas",
                )
        elif kind == "group":
            self.assign(
                out,
                f"{src} | {self.label()} >> beam.Values() "
                f"| {self.label()} >> beam.CombineGlobally({combiner})",
                "server", f"{stage.op.upper()} groups->server",
            )
        else:  # stacked plain/server value: reduce locally
            self.assign(
                out, f"{combiner}(list({src}))", "plain",
                f"{stage.op.upper()} over a stacked local value",
            )
        self.bind(stage.eqn.outvars[0], out)

    def emit_transfer(self, stage: Transfer, plan):
        src = self.name_of(stage.eqn.invars[0], plan)
        out = self.fresh("tx")
        i, size = self._stage_placement(stage)
        shift, wrap = stage.shift, stage.wrap
        kind = self.kinds.get(src, "plain")
        tag = f"TRANSFER shift={shift:+d} @{stage.placement}"
        if kind not in ("group",):
            # Stacked driver/server value: the shift is a local permutation.
            if kind == "server":
                self.assign(
                    out,
                    f"{src} | {self.label()} >> beam.Map("
                    f"lambda v: _stage_shift(v, {i}, {shift}, {wrap}))",
                    "server", tag,
                )
            else:
                self.assign(
                    out, f"_stage_shift({src}, {i}, {shift}, {wrap})",
                    "plain", tag,
                )
            self.bind(stage.eqn.outvars[0], out)
            return
        depth = self.depths.get(src, 1)
        tuple_keys = self.nested or depth > 1
        if tuple_keys:
            rekey = (
                f"lambda kv: (kv[0][:{i}] + ((kv[0][{i}] + {shift})"
                + (f" % {size}" if wrap else "")
                + f",) + kv[0][{i + 1}:], kv[1])"
            )
            in_range = f"lambda kv: 0 <= kv[0][{i}] < {size}"
        else:
            rekey = (
                f"lambda kv: ((kv[0] + {shift})"
                + (f" % {size}" if wrap else "")
                + ", kv[1])"
            )
            in_range = f"lambda kv: 0 <= kv[0] < {size}"
        if wrap:
            self.assign(
                out,
                f"{src} | {self.label()} >> beam.Map({rekey})",
                "group", f"{tag} (rotate stage keys)",
            )
        else:
            # Re-key each element to its destination stage, dropping the
            # ones that fall off the pipeline edge, and inject zero elements
            # for the vacated entry stages.
            moved = self.fresh("mv")
            self.assign(
                moved,
                f"{src} | {self.label()} >> beam.Map({rekey}) "
                f"| {self.label()} >> beam.Filter({in_range})",
                "group", f"{tag} (shift stage keys)",
            )
            aval = stage.eqn.outvars[0].aval
            elem_shape = tuple(aval.shape[depth:])
            zeros_expr = (
                f"np.zeros({elem_shape!r}, np.dtype({str(aval.dtype)!r}))"
            )
            if shift > 0:
                vac = f"range({min(shift, size)})"
            else:
                vac = f"range({max(size + shift, 0)}, {size})"
            if tuple_keys:
                sizes = tuple(self.plan.placement_sizes[:depth])
                keys = (
                    f"[k0 + (j,) + k1 for k0 in np.ndindex(*{sizes[:i]!r}) "
                    f"for j in {vac} "
                    f"for k1 in np.ndindex(*{sizes[i + 1:]!r})]"
                )
            else:
                keys = f"[j for j in {vac}]"
            zeros = self.fresh("zf")
            self.assign(
                zeros,
                f"p | {self.label()} >> beam.Create("
                f"[(k, {zeros_expr}) for k in {keys}])",
                "group", f"{tag} (zero-fill vacated stages)",
            )
            self.assign(
                out,
                f"({moved}, {zeros}) | {self.label()} >> beam.Flatten()",
                "group", tag,
            )
        self.depths[out] = depth
        self.bind(stage.eqn.outvars[0], out)

    def emit_local(self, stage: LocalCompute, plan, sname: str, outs):
        consts = plan.const_env()
        ins = [a for a in _stage_reads(stage) if a not in consts]
        in_names = [self.name_of(a, plan) for a in ins]
        raw = self.fresh("o")
        if stage.at_groups:
            self.emit_group_stage(sname, in_names, raw)
            k = self.depths.get(raw, 1)
            if k > 1:
                # the stage fn returned k leading singleton group axes —
                # strip all of them when projecting this group's element
                unwrap = repr((0,) * k)
                project = (
                    "lambda kv, _j={j}: (kv[0], kv[1][_j][" + unwrap + "])"
                )
            else:
                project = "lambda kv, _j={j}: (kv[0], kv[1][_j][0])"
        else:
            self.emit_server_stage(sname, in_names, raw)
            project = "lambda _t, _j={j}: _t[_j]"
        for j, o in enumerate(outs):
            name = self.fresh("t")
            if self.kinds[raw] == "plain":
                self.assign(name, f"{raw}[{j}]", "plain")
            else:
                self.assign(
                    name,
                    f"{raw} | {self.label()} >> "
                    f"beam.Map({project.format(j=j)})",
                    self.kinds[raw],
                )
                self.depths[name] = self.depths.get(raw, 1)
            self.bind(o, name)

    def emit_server_stage(self, sname: str, in_names: List[str], raw: str):
        kinds = [self.kinds.get(n, "plain") for n in in_names]
        if "server" not in kinds:
            # every input is a driver-side value: call the stage fn directly
            args = ", ".join(in_names)
            self.assign(
                raw, f"fns['{sname}']({args})", "plain",
                f"SERVER_COMPUTE {sname} (driver-side)",
            )
            return
        main_idx = kinds.index("server")
        params, extras = ["_v"], []
        exprs: List[str] = [""] * len(in_names)
        exprs[main_idx] = "_v"
        for i, (n, k) in enumerate(zip(in_names, kinds)):
            if i == main_idx:
                continue
            pname = f"_a{i}"
            params.append(pname)
            exprs[i] = pname
            extras.append(
                f"beam.pvalue.AsSingleton({n})" if k == "server" else n
            )
        lam = (
            f"lambda {', '.join(params)}: fns['{sname}']({', '.join(exprs)})"
        )
        extra = (", " + ", ".join(extras)) if extras else ""
        self.assign(
            raw,
            f"{in_names[main_idx]} | {self.label()} >> beam.Map({lam}{extra})",
            "server", f"SERVER_COMPUTE {sname}",
        )

    def emit_group_stage(self, sname: str, in_names: List[str], raw: str):
        kinds = [self.kinds.get(n, "plain") for n in in_names]
        gdepths = [
            self.depths.get(n, 1) if k == "group" else 0
            for n, k in zip(in_names, kinds)
        ]
        if self.nested or any(d > 1 for d in gdepths):
            self._emit_group_stage_nested(
                sname, in_names, kinds, gdepths, raw
            )
            return
        main = next(
            (n for n, k in zip(in_names, kinds) if k == "group"), None
        )
        if main is None:
            main = "groups"
        params, extras, exprs = ["kv"], [], []
        used_main = False
        for n, k in zip(in_names, kinds):
            if n == main and not used_main:
                used_main = True
                exprs.append("np.stack([kv[1]])")
            elif k == "group":
                pname = f"_d{len(params)}"
                params.append(pname)
                exprs.append(f"np.stack([{pname}[kv[0]]])")
                extras.append(f"beam.pvalue.AsDict({n})")
            elif k == "server":
                pname = f"_s{len(params)}"
                params.append(pname)
                exprs.append(pname)
                extras.append(f"beam.pvalue.AsSingleton({n})")
            else:  # side input object or plain value: pass through
                pname = f"_x{len(params)}"
                params.append(pname)
                exprs.append(pname)
                extras.append(n)
        lam = (
            f"lambda {', '.join(params)}: "
            f"(kv[0], fns['{sname}']({', '.join(exprs)}))"
        )
        extra = (", " + ", ".join(extras)) if extras else ""
        self.assign(
            raw,
            f"{main} | {self.label()} >> beam.Map({lam}{extra})",
            "group", f"GROUP_COMPUTE {sname} (per group)",
        )
        self.depths[raw] = 1

    def _emit_group_stage_nested(
        self, sname: str, in_names, kinds, gdepths, raw: str
    ):
        """Placement-path (tuple-keyed) variant of a group stage.

        The Map is keyed on the deepest group input; shallower group inputs
        are joined by their key *prefix* (kv[0][:depth]) — a pod-partitioned
        side value joins every client of that pod. Each group element is
        lifted to its own number of leading singleton group axes before the
        sliced (group-batched) stage fn sees it."""
        main, main_depth = None, 0
        for n, k, d in zip(in_names, kinds, gdepths):
            if k == "group" and d > main_depth:
                main, main_depth = n, d
        if main is None:
            main = "groups"
            main_depth = self.depths.get("groups", 1)
        params, extras, exprs = ["kv"], [], []
        used_main = False
        for n, k, d in zip(in_names, kinds, gdepths):
            if n == main and not used_main:
                used_main = True
                exprs.append(f"_lift(kv[1], {main_depth})")
            elif k == "group":
                pname = f"_d{len(params)}"
                params.append(pname)
                exprs.append(f"_lift({pname}[kv[0][:{d}]], {d})")
                extras.append(f"beam.pvalue.AsDict({n})")
            elif k == "server":
                pname = f"_s{len(params)}"
                params.append(pname)
                exprs.append(pname)
                extras.append(f"beam.pvalue.AsSingleton({n})")
            else:  # side input object or plain value: pass through
                pname = f"_x{len(params)}"
                params.append(pname)
                exprs.append(pname)
                extras.append(n)
        lam = (
            f"lambda {', '.join(params)}: "
            f"(kv[0], fns['{sname}']({', '.join(exprs)}))"
        )
        extra = (", " + ", ".join(extras)) if extras else ""
        self.assign(
            raw,
            f"{main} | {self.label()} >> beam.Map({lam}{extra})",
            "group", f"GROUP_COMPUTE {sname} (per placement path)",
        )
        self.depths[raw] = main_depth

    def emit_loop(self, stage: LoopStage, plan, path: str, outs):
        eqn = stage.eqn
        body = stage.body_plan
        loop_var = f"i{path.replace('_', '')}"
        if stage.loop_kind == "scan":
            nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
            trip = stage.trip_count
            const_atoms = eqn.invars[:nc]
            carry_atoms = eqn.invars[nc : nc + ncar]
            xs_atoms = eqn.invars[nc + ncar :]
            carry_names = []
            for j, a in enumerate(carry_atoms):
                nm = self.fresh(f"carry{path}_")
                src = self.name_of(a, plan)
                self.assign(nm, src, self.kinds.get(src, "plain"),
                            f"loop {path} carry init")
                carry_names.append(nm)
            ys_names = []
            for j in range(len(eqn.outvars) - ncar):
                nm = self.fresh(f"ys{path}_")
                self.line(f"{nm} = []  # (iteration, value) pairs")
                self.kinds[nm] = "plain"
                ys_names.append(nm)
            iter_expr = (
                f"reversed(range({trip}))"
                if eqn.params.get("reverse", False)
                else f"range({trip})"
            )
            self.line(
                f"for {loop_var} in {iter_expr}:  "
                f"# LOOP[scan] {path}: one communication round per iteration"
            )
            self._indent += 1
            self._loop_vars.append(loop_var)
            binding_save = dict(self.names)
            # bind body invars: consts, carry, xs slices. Lambdas index with
            # a default arg (_i=loop_var) — Beam runs them after the
            # construction loop, when the loop variable holds its final value.
            for b, a in zip(body.jaxpr.jaxpr.invars[:nc], const_atoms):
                self.bind(b, self.name_of(a, plan))
            for b, nm in zip(
                body.jaxpr.jaxpr.invars[nc : nc + ncar], carry_names
            ):
                self.bind(b, nm)
            xs_binders = body.jaxpr.jaxpr.invars[nc + ncar :]
            xs_parts = body.partitioned_invars[nc + ncar :]
            for b, a, part in zip(xs_binders, xs_atoms, xs_parts):
                xs_name = self.name_of(a, plan)
                slice_nm = self.fresh("x")
                if self.kinds.get(xs_name) == "group":
                    self.assign(
                        slice_nm,
                        f"{xs_name} | {self.label()} >> beam.Map("
                        f"lambda kv, _i={loop_var}: (kv[0], kv[1][_i]))",
                        "group", "xs slice for this iteration",
                    )
                elif self.kinds.get(xs_name) == "server":
                    self.assign(
                        slice_nm,
                        f"{xs_name} | {self.label()} >> "
                        f"beam.Map(lambda v, _i={loop_var}: v[_i])",
                        "server", "xs slice for this iteration",
                    )
                else:
                    self.assign(
                        slice_nm, f"{xs_name}[{loop_var}]", "plain",
                        "xs slice for this iteration",
                    )
                # a slice that the body treats as partitioned must arrive as
                # a keyed per-group PCollection, not a stacked server value
                if part and self.kinds.get(slice_nm) != "group":
                    slice_nm = self.to_group(slice_nm)
                self.bind(b, slice_nm)
            # reconcile carry placement: body may expect partitioned carries
            for b, nm, part in zip(
                body.jaxpr.jaxpr.invars[nc : nc + ncar],
                carry_names,
                body.partitioned_invars[nc : nc + ncar],
            ):
                if part and self.kinds.get(self.names[b]) != "group":
                    self.bind(b, self.to_group(self.names[b]))
            self.emit_plan_stages(body, prefix=f"{path}_")
            new_carries = [self.name_of(a, body) for a in body.out_atoms[:ncar]]
            for nm, new in zip(carry_names, new_carries):
                self.assign(nm, new, self.kinds.get(new, "plain"),
                            "carry update")
            ys_kinds = []
            for nm, a in zip(ys_names, body.out_atoms[ncar:]):
                val = self.name_of(a, body)
                if self.kinds.get(val) == "group":
                    # a partitioned per-iteration output: collect the groups
                    # into one stacked (n, ...) server value before tagging
                    val = self.to_server(val)
                k = self.kinds.get(val, "plain")
                ys_kinds.append(k)
                if k == "server":
                    self.line(
                        f"{nm}.append({val} | {self.label()} >> "
                        f"beam.Map(lambda v, _i={loop_var}: (_i, v)))"
                    )
                else:
                    self.line(f"{nm}.append(({loop_var}, {val}))")
            self._loop_vars.pop()
            self._indent -= 1
            self.names = binding_save
            for o, nm in zip(eqn.outvars[:ncar], carry_names):
                if not _is_dropvar(o):
                    self.bind(o, nm)
            outs_set = set(outs)
            for o, nm, k in zip(eqn.outvars[ncar:], ys_names, ys_kinds):
                if _is_dropvar(o):
                    continue
                if o in outs_set and k == "server":
                    st = self.fresh("t")
                    self.assign(
                        st,
                        f"(tuple({nm}) | {self.label()} >> beam.Flatten() "
                        f"| {self.label()} >> beam.combiners.ToList() "
                        f"| {self.label()} >> beam.Map(lambda rows: "
                        f"np.stack([v for _, v in sorted(rows)])))",
                        "server", "stack per-iteration outputs",
                    )
                    self.bind(o, st)
                elif o in outs_set and k == "plain":
                    st = self.fresh("t")
                    self.assign(
                        st, f"np.stack([v for _, v in sorted({nm})])",
                        "plain", "stack per-iteration outputs",
                    )
                    self.bind(o, st)
                else:
                    self.bind(o, nm)
        else:  # while: Beam pipelines are static — driver must unroll
            cn, bn = eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
            body_consts = eqn.invars[cn : cn + bn]
            carry_atoms = eqn.invars[cn + bn :]
            carry_names = []
            for a in carry_atoms:
                nm = self.fresh(f"carry{path}_")
                src = self.name_of(a, plan)
                self.assign(nm, src, self.kinds.get(src, "plain"),
                            f"while {path} carry init")
                carry_names.append(nm)
            iters = f"num_iters_{path}"
            self.line(
                f"{iters} = 1  # LOOP[while] {path}: dynamic trip count — "
                f"resolve at driver time and rebuild"
            )
            self.line(f"for {loop_var} in range({iters}):")
            self._indent += 1
            self._loop_vars.append(loop_var)
            binding_save = dict(self.names)
            for b, a in zip(body.jaxpr.jaxpr.invars[:bn], body_consts):
                self.bind(b, self.name_of(a, plan))
            for b, nm in zip(body.jaxpr.jaxpr.invars[bn:], carry_names):
                self.bind(b, nm)
            self.emit_plan_stages(body, prefix=f"{path}_")
            new_carries = [self.name_of(a, body) for a in body.out_atoms]
            for nm, new in zip(carry_names, new_carries):
                self.assign(nm, new, self.kinds.get(new, "plain"),
                            "carry update")
            self._loop_vars.pop()
            self._indent -= 1
            self.names = binding_save
            for o, nm in zip(eqn.outvars, carry_names):
                if not _is_dropvar(o):
                    self.bind(o, nm)

    def emit_cond(self, stage: CondStage, plan, path: str):
        eqn = stage.eqn
        idx = self.name_of(eqn.invars[0], plan)
        self.line(
            f"# COND {path}: branch index lives in {idx}; a real driver "
            f"materializes it and builds one branch"
        )
        ops = eqn.invars[1:]
        branch_outs: List[List[str]] = []
        for b, bp in enumerate(stage.branch_plans):
            self.line(f"# -- branch {b} --")
            binding_save = dict(self.names)
            for binder, a in zip(bp.jaxpr.jaxpr.invars, ops):
                self.bind(binder, self.name_of(a, plan))
            self.emit_plan_stages(bp, prefix=f"{path}_b{b}_")
            branch_outs.append([self.name_of(a, bp) for a in bp.out_atoms])
            self.names = binding_save
        for j, o in enumerate(eqn.outvars):
            if _is_dropvar(o):
                continue
            nm = self.fresh("t")
            picks = ", ".join(outs[j] for outs in branch_outs)
            self.assign(
                nm, f"[{picks}][int(np.asarray({idx}))] "
                    f"if not isinstance({idx}, beam.pvalue.PCollection) "
                    f"else [{picks}][0]",
                self.kinds.get(branch_outs[0][j], "plain"),
                "cond output (select branch)",
            )
            self.bind(o, nm)


def _all_plans(plan: MapReducePlan):
    """Yield a plan and all its sub-plans, depth-first in stage order."""
    yield plan
    for s in plan.stages:
        if isinstance(s, LoopStage):
            if s.cond_plan is not None:
                yield from _all_plans(s.cond_plan)
            if s.body_plan is not None:
                yield from _all_plans(s.body_plan)
        elif isinstance(s, CondStage):
            for bp in s.branch_plans:
                yield from _all_plans(bp)


def _literal_src(val) -> str:
    arr = np.asarray(val)
    kind = arr.dtype.kind
    if arr.ndim == 0:
        if kind == "b":
            return str(bool(arr))
        if kind in "iu":
            return f"np.{arr.dtype}({int(arr)})"
        if kind == "f":
            return f"np.{arr.dtype}({float(arr)!r})"
        if kind == "c":
            return f"np.{arr.dtype}({complex(arr)!r})"
        # ml_dtypes scalars (bfloat16, float8_*): plain numpy has no such
        # constructor, so emit the nearest float32 value
        return f"np.float32({float(arr)!r})  # was {arr.dtype}"
    if kind in "bfciu":
        return f"np.asarray({arr.tolist()!r}, dtype=np.{arr.dtype})"
    return (
        f"np.asarray({np.asarray(arr, np.float32).tolist()!r}, "
        f"dtype=np.float32)  # was {arr.dtype}"
    )
