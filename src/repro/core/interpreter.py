"""Custom jaxpr interpreter: DrJAX programs → portable MapReduce plans.

Paper §5: because the building blocks are *primitives*, they survive into the
jaxpr. A custom interpreter can therefore recover the communication structure
of the program — which values are partitioned, where broadcasts and reductions
happen — and translate it to other platforms (Apache Beam, federated-learning
systems) where "all cross-machine communication is explicit, and the
processing in-between communication is entirely local".

This module provides:

* :func:`build_plan` — walk a ``ClosedJaxpr`` and segment it into an ordered
  list of stages: ``ServerCompute``, ``Broadcast``, ``GroupCompute``,
  ``Reduce``.
* emitters — ``plan.to_text()`` (federated-system style) and
  ``plan.to_beam()`` (Apache Beam pipeline pseudocode).
* :func:`run_plan` — a reference *plan executor* that runs the plan stage by
  stage, keeping partitioned values as per-group lists and only ever moving
  data at Broadcast/Reduce stages. Equality with direct execution is the
  correctness test for the translation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core as jex_core
from jax._src import core as _src_core

from . import primitives as prims

_COMM = {
    "drjax_broadcast": "broadcast",
    "drjax_reduce_sum": "reduce_sum",
    "drjax_reduce_mean": "reduce_mean",
    "drjax_reduce_max": "reduce_max",
}

_REDUCERS = {"reduce_sum", "reduce_mean", "reduce_max"}


# ---------------------------------------------------------------------------
# plan IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stage:
    """Base class for plan stages."""


@dataclasses.dataclass
class LocalCompute(Stage):
    """A maximal run of non-communication eqns at a single placement."""

    at_groups: bool  # True: runs on every group; False: runs on the server
    eqns: List[Any] = dataclasses.field(default_factory=list)

    @property
    def kind(self) -> str:
        return "GROUP_COMPUTE" if self.at_groups else "SERVER_COMPUTE"


@dataclasses.dataclass
class Broadcast(Stage):
    eqn: Any = None
    kind: str = "BROADCAST"


@dataclasses.dataclass
class Reduce(Stage):
    op: str = "reduce_sum"
    eqn: Any = None
    kind: str = "REDUCE"


@dataclasses.dataclass
class MapReducePlan:
    jaxpr: Any  # ClosedJaxpr
    partition_size: int
    stages: List[Stage]
    partitioned_invars: Tuple[bool, ...]

    # -- emitters ----------------------------------------------------------

    def to_text(self) -> str:
        lines = [
            f"MapReducePlan(partition_size={self.partition_size})",
            f"  inputs: "
            + ", ".join(
                f"{v} @{'GROUPS' if p else 'SERVER'}"
                for v, p in zip(self.jaxpr.jaxpr.invars, self.partitioned_invars)
            ),
        ]
        for i, s in enumerate(self.stages):
            if isinstance(s, LocalCompute):
                ops = ", ".join(e.primitive.name for e in s.eqns)
                lines.append(f"  stage {i}: {s.kind} [{ops}]")
            elif isinstance(s, Broadcast):
                lines.append(
                    f"  stage {i}: BROADCAST server->groups "
                    f"({s.eqn.invars[0]} -> {s.eqn.outvars[0]})"
                )
            elif isinstance(s, Reduce):
                lines.append(
                    f"  stage {i}: {s.op.upper()} groups->server "
                    f"({s.eqn.invars[0]} -> {s.eqn.outvars[0]})"
                )
        outs = ", ".join(str(v) for v in self.jaxpr.jaxpr.outvars)
        lines.append(f"  outputs: {outs}")
        return "\n".join(lines)

    def to_beam(self) -> str:
        """Apache-Beam-flavored pipeline pseudocode for this plan."""
        lines = [
            "with beam.Pipeline() as p:",
            f"  groups = p | beam.Create(range({self.partition_size}))",
        ]
        step = 0
        for s in self.stages:
            if isinstance(s, Broadcast):
                lines.append(
                    f"  bcast_{step} = server_values  # side input, replicated"
                )
            elif isinstance(s, LocalCompute) and s.at_groups:
                lines.append(
                    f"  groups = groups | 'Map{step}' >> "
                    f"beam.Map(stage_{step}_fn, side_inputs=bcast)"
                )
            elif isinstance(s, LocalCompute):
                lines.append(
                    f"  server_values = apply(stage_{step}_fn, server_values)"
                )
            elif isinstance(s, Reduce):
                combiner = {
                    "reduce_sum": "sum",
                    "reduce_mean": "beam.combiners.MeanCombineFn()",
                    "reduce_max": "max",
                }[s.op]
                lines.append(
                    f"  server_values = groups | 'Combine{step}' >> "
                    f"beam.CombineGlobally({combiner})"
                )
            step += 1
        return "\n".join(lines)

    # -- structural checks --------------------------------------------------

    def communication_stages(self) -> List[Stage]:
        return [s for s in self.stages if isinstance(s, (Broadcast, Reduce))]

    def check_locality(self) -> None:
        """No communication primitive may appear inside a local stage."""
        for s in self.stages:
            if isinstance(s, LocalCompute):
                for e in s.eqns:
                    if e.primitive.name in _COMM:
                        raise AssertionError(
                            f"communication primitive {e.primitive.name} "
                            f"inside {s.kind} stage"
                        )


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------


def trace(fn: Callable, *example_args) -> Any:
    """ClosedJaxpr of ``fn`` (which must already carry its drjax context)."""
    return jax.make_jaxpr(fn)(*example_args)


def _eqn_subjaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, jex_core.ClosedJaxpr):
            yield v
        elif isinstance(v, jex_core.Jaxpr):
            yield jex_core.ClosedJaxpr(v, ())


def build_plan(
    closed: Any,
    partition_size: int,
    partitioned_invars: Optional[Sequence[bool]] = None,
) -> MapReducePlan:
    """Segment a jaxpr into MapReduce stages.

    ``partitioned_invars[i]`` declares whether input i is a partitioned value
    (leading group axis). If omitted, an input is assumed partitioned iff its
    leading dimension equals ``partition_size`` — right for all examples here,
    but callers with ambiguous shapes should pass it explicitly.
    """
    jaxpr = closed.jaxpr
    if partitioned_invars is None:
        partitioned_invars = tuple(
            bool(v.aval.shape) and v.aval.shape[0] == partition_size
            for v in jaxpr.invars
        )
    partitioned_invars = tuple(partitioned_invars)

    placed: Dict[Any, bool] = {}  # var -> is_partitioned
    for v, p in zip(jaxpr.invars, partitioned_invars):
        placed[v] = p
    for v in jaxpr.constvars:
        placed[v] = False

    def var_partitioned(v) -> bool:
        if isinstance(v, jex_core.Literal):
            return False
        return placed.get(v, False)

    stages: List[Stage] = []

    def append_local(eqn, at_groups: bool):
        if (
            stages
            and isinstance(stages[-1], LocalCompute)
            and stages[-1].at_groups == at_groups
        ):
            stages[-1].eqns.append(eqn)
        else:
            stages.append(LocalCompute(at_groups=at_groups, eqns=[eqn]))

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "drjax_broadcast":
            stages.append(Broadcast(eqn=eqn))
            for o in eqn.outvars:
                placed[o] = True
        elif name in _COMM:
            stages.append(Reduce(op=_COMM[name], eqn=eqn))
            for o in eqn.outvars:
                placed[o] = False
        else:
            at_groups = any(var_partitioned(v) for v in eqn.invars)
            for o in eqn.outvars:
                placed[o] = at_groups
            append_local(eqn, at_groups)

    plan = MapReducePlan(
        jaxpr=closed,
        partition_size=partition_size,
        stages=stages,
        partitioned_invars=partitioned_invars,
    )
    plan.check_locality()
    return plan


# ---------------------------------------------------------------------------
# reference plan executor (mini federated runtime)
# ---------------------------------------------------------------------------


def _eval_eqn(eqn, read):
    """Evaluate one jaxpr eqn eagerly."""
    invals = [read(v) for v in eqn.invars]
    subfuns, params = eqn.primitive.get_bind_params(eqn.params)
    out = eqn.primitive.bind(*subfuns, *invals, **params)
    return out if eqn.primitive.multiple_results else [out]


def run_plan(plan: MapReducePlan, *args):
    """Execute the plan stage by stage.

    Partitioned values live as stacked arrays but are only *created* by
    Broadcast stages and only *consumed across groups* by Reduce stages;
    ``check_locality`` guarantees every GROUP_COMPUTE stage is group-elementwise
    (it came from a vmap body). This mirrors how a federated/Beam backend would
    run the plan: local stages per group, explicit communication between.
    """
    jaxpr = plan.jaxpr.jaxpr
    env: Dict[Any, Any] = {}

    def read(v):
        if isinstance(v, jex_core.Literal):
            return v.val
        return env[v]

    def write(v, val):
        env[v] = val

    for v, val in zip(jaxpr.constvars, plan.jaxpr.consts):
        write(v, val)
    for v, val in zip(jaxpr.invars, args):
        write(v, val)

    for stage in plan.stages:
        if isinstance(stage, (Broadcast, Reduce)):
            eqn = stage.eqn
            outs = _eval_eqn(eqn, read)
            for o, val in zip(eqn.outvars, outs):
                write(o, val)
        else:
            for eqn in stage.eqns:
                outs = _eval_eqn(eqn, read)
                for o, val in zip(eqn.outvars, outs):
                    if not isinstance(o, _src_core.DropVar):
                        write(o, val)

    return [read(v) for v in jaxpr.outvars]


def count_primitives(closed: Any) -> Dict[str, int]:
    """Histogram of DrJAX primitives in a jaxpr (recursing into sub-jaxprs)."""
    counts: Dict[str, int] = {}

    def visit(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _COMM:
                counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
            for sub in _eqn_subjaxprs(eqn):
                visit(sub.jaxpr)

    visit(closed.jaxpr)
    return counts
