"""DrJAX MapReduce building blocks as JAX primitives.

Embeds ``broadcast``, ``reduce_sum``, ``reduce_mean`` (and a ``reduce_max``
extension) as first-class :class:`jax.extend.core.Primitive` symbols, exactly
as the paper describes (§3 "Implementation"):

* **impl / abstract-eval / MLIR lowering** — the primitives are entirely
  replaced by plain XLA ops by the time JAX dispatches to a runtime, so DrJAX
  programs are ordinary pjit-able programs.
* **JVP + transpose rules** — the derivative of a DrJAX primitive is again a
  DrJAX primitive (MapReduce AD, Rush et al. 2023): ``broadcast`` and
  ``reduce_sum`` are each other's transposes; ``reduce_mean`` transposes to a
  scaled ``broadcast``.
* **batching rules** — primitives survive ``jax.vmap``, so outer-loop
  transforms (hyperparameter sweeps, per-example clipping) compose.
* **sharding annotations** — each primitive's lowering constrains the leading
  (partition) axis onto the mesh axes in the ambient
  :class:`~repro.core.placement.PlacementContext` (static annotations). The
  context travels in the primitive *params*, so annotations survive into
  transpose rules that fire outside the user's trace (e.g. inside
  ``jax.grad``'s backward pass).

Partitioned values are arrays with a leading group axis (paper Fig. 1); all
primitives here operate on single arrays and are mapped over pytrees by
:mod:`repro.core.api`.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import core
from jax.extend.core import Primitive
from jax.interpreters import ad, batching, mlir

from . import placement as placement_lib
from . import sharding as sharding_lib

__all__ = [
    "broadcast_p",
    "reduce_sum_p",
    "reduce_mean_p",
    "reduce_max_p",
    "bind_broadcast",
    "bind_reduce_sum",
    "bind_reduce_mean",
    "bind_reduce_max",
    "DRJAX_PRIMITIVES",
    "COMMUNICATION_PRIMITIVES",
]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _check_partitioned(x_aval, pctx: placement_lib.PlacementContext, prim: str):
    if x_aval.ndim < 1:
        raise ValueError(
            f"drjax.{prim} expects a partitioned array with a leading group "
            f"axis; got a scalar."
        )
    if x_aval.shape[0] != pctx.partition_size:
        raise ValueError(
            f"drjax.{prim}: leading axis ({x_aval.shape[0]}) does not match "
            f"the partition size ({pctx.partition_size}) of placement "
            f"'{pctx.placement}'. Partitioned values must carry one leading "
            f"entry per group."
        )


# ---------------------------------------------------------------------------
# broadcast
# ---------------------------------------------------------------------------

broadcast_p = Primitive("drjax_broadcast")


def _broadcast_impl(x, *, pctx: placement_lib.PlacementContext):
    out = jnp.broadcast_to(x[None], (pctx.partition_size,) + x.shape)
    return sharding_lib.constrain_partitioned(out, pctx)


def _broadcast_abstract(x, *, pctx):
    return core.ShapedArray((pctx.partition_size,) + x.shape, x.dtype)


broadcast_p.def_impl(_broadcast_impl)
broadcast_p.def_abstract_eval(_broadcast_abstract)
mlir.register_lowering(
    broadcast_p, mlir.lower_fun(_broadcast_impl, multiple_results=False)
)


def _broadcast_jvp(primals, tangents, *, pctx):
    (x,), (t,) = primals, tangents
    out = broadcast_p.bind(x, pctx=pctx)
    if isinstance(t, ad.Zero):
        t_out = ad.Zero(core.get_aval(out).to_tangent_aval())
    else:
        t_out = broadcast_p.bind(t, pctx=pctx)
    return out, t_out


ad.primitive_jvps[broadcast_p] = _broadcast_jvp


def _broadcast_transpose(ct, x, *, pctx):
    # d(broadcast)^T = reduce_sum  (MapReduce AD closure; Rush et al. 2023)
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    return (reduce_sum_p.bind(ct, pctx=pctx),)


ad.primitive_transposes[broadcast_p] = _broadcast_transpose


def _broadcast_batch(args, dims, *, pctx):
    (x,), (d,) = args, dims
    out = broadcast_p.bind(x, pctx=pctx)
    if d is batching.not_mapped:
        return out, batching.not_mapped
    # broadcast prepends the partition axis, pushing the batch dim right by 1.
    return out, d + 1


batching.primitive_batchers[broadcast_p] = _broadcast_batch


# ---------------------------------------------------------------------------
# reductions (shared machinery)
# ---------------------------------------------------------------------------


def _make_reduction(name: str, reduce_fn, jvp_linear: bool):
    p = Primitive(f"drjax_{name}")

    def impl(x, *, pctx: placement_lib.PlacementContext):
        out = reduce_fn(x, pctx)
        return sharding_lib.constrain_replicated(out, pctx)

    def abstract(x, *, pctx):
        _check_partitioned(x, pctx, name)
        return core.ShapedArray(x.shape[1:], x.dtype)

    p.def_impl(impl)
    p.def_abstract_eval(abstract)
    mlir.register_lowering(p, mlir.lower_fun(impl, multiple_results=False))

    def batch(args, dims, *, pctx):
        (x,), (d,) = args, dims
        if d is batching.not_mapped:
            return p.bind(x, pctx=pctx), batching.not_mapped
        # Logical operand: (n, *rest); physical batch dim at d. Move the batch
        # axis to the end so the partition axis stays leading, preserving the
        # primitive (and hence jaxpr interpretability) under vmap.
        x = jnp.moveaxis(x, d, x.ndim - 1)
        out = p.bind(x, pctx=pctx)
        return out, out.ndim - 1

    batching.primitive_batchers[p] = batch
    return p


reduce_sum_p = _make_reduction(
    "reduce_sum", lambda x, pctx: jnp.sum(x, axis=0), jvp_linear=True
)
reduce_mean_p = _make_reduction(
    "reduce_mean", lambda x, pctx: jnp.sum(x, axis=0) / pctx.partition_size,
    jvp_linear=True,
)
reduce_max_p = _make_reduction(
    "reduce_max", lambda x, pctx: jnp.max(x, axis=0), jvp_linear=False
)


def _linear_reduction_jvp(p):
    def jvp(primals, tangents, *, pctx):
        (x,), (t,) = primals, tangents
        out = p.bind(x, pctx=pctx)
        if isinstance(t, ad.Zero):
            t_out = ad.Zero(core.get_aval(out).to_tangent_aval())
        else:
            t_out = p.bind(t, pctx=pctx)
        return out, t_out

    return jvp


ad.primitive_jvps[reduce_sum_p] = _linear_reduction_jvp(reduce_sum_p)
ad.primitive_jvps[reduce_mean_p] = _linear_reduction_jvp(reduce_mean_p)


def _reduce_sum_transpose(ct, x, *, pctx):
    # d(reduce_sum)^T = broadcast
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    return (broadcast_p.bind(ct, pctx=pctx),)


def _reduce_mean_transpose(ct, x, *, pctx):
    # d(reduce_mean)^T = broadcast / n
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    return (broadcast_p.bind(ct / pctx.partition_size, pctx=pctx),)


ad.primitive_transposes[reduce_sum_p] = _reduce_sum_transpose
ad.primitive_transposes[reduce_mean_p] = _reduce_mean_transpose


def _reduce_max_jvp(primals, tangents, *, pctx):
    """Sub-gradient JVP for the (non-linear) max reduction.

    The tangent flows from the arg-max group. Expressed with reduce_sum of a
    masked tangent so that reverse-mode stays inside the DrJAX primitive set
    (the mask is constant wrt differentiation).
    """
    (x,), (t,) = primals, tangents
    out = reduce_max_p.bind(x, pctx=pctx)
    if isinstance(t, ad.Zero):
        return out, ad.Zero(core.get_aval(out).to_tangent_aval())
    hit = (x == out[None]).astype(x.dtype)
    hit = hit / jnp.maximum(jnp.sum(hit, axis=0, keepdims=True), 1)
    t_out = reduce_sum_p.bind(hit * t, pctx=pctx)
    return out, t_out


ad.primitive_jvps[reduce_max_p] = _reduce_max_jvp


# ---------------------------------------------------------------------------
# user-facing single-leaf binders
# ---------------------------------------------------------------------------


def _ctx() -> placement_lib.PlacementContext:
    return placement_lib.current_context()


def bind_broadcast(x):
    x = jnp.asarray(x)
    return broadcast_p.bind(x, pctx=_ctx())


def bind_reduce_sum(x):
    return reduce_sum_p.bind(x, pctx=_ctx())


def bind_reduce_mean(x):
    return reduce_mean_p.bind(x, pctx=_ctx())


def bind_reduce_max(x):
    return reduce_max_p.bind(x, pctx=_ctx())


DRJAX_PRIMITIVES: Tuple[Primitive, ...] = (
    broadcast_p,
    reduce_sum_p,
    reduce_mean_p,
    reduce_max_p,
)

# Primitives that imply cross-group communication when interpreted onto a
# distributed system (used by the jaxpr interpreter, paper §5).
COMMUNICATION_PRIMITIVES = frozenset(p.name for p in DRJAX_PRIMITIVES)
