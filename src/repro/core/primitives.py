"""DrJAX MapReduce building blocks as JAX primitives.

Embeds ``broadcast``, ``reduce_sum``, ``reduce_mean`` (and a ``reduce_max``
extension) as first-class :class:`jax.extend.core.Primitive` symbols, exactly
as the paper describes (§3 "Implementation"):

* **impl / abstract-eval / MLIR lowering** — the primitives are entirely
  replaced by plain XLA ops by the time JAX dispatches to a runtime, so DrJAX
  programs are ordinary pjit-able programs.
* **JVP + transpose rules** — the derivative of a DrJAX primitive is again a
  DrJAX primitive (MapReduce AD, Rush et al. 2023): ``broadcast`` and
  ``reduce_sum`` are each other's transposes; ``reduce_mean`` transposes to a
  scaled ``broadcast``.
* **batching rules** — primitives survive ``jax.vmap``, so outer-loop
  transforms (hyperparameter sweeps, per-example clipping) compose.
* **sharding annotations** — each primitive's lowering constrains the leading
  (partition) axes onto the mesh axes of the ambient
  :class:`~repro.core.placement.PlacementContext` (static annotations). The
  context travels in the primitive *params*, so annotations survive into
  transpose rules that fire outside the user's trace (e.g. inside
  ``jax.grad``'s backward pass).

Every primitive is *placement-addressed*: it binds with a ``placement``
param naming one level of the placement stack (default: innermost). For a
placement at stack index ``i``, ``broadcast`` takes a value partitioned at
the ``i`` outer placements (depth i) and inserts that placement's group axis
at position ``i`` (depth i+1); ``reduce_*`` removes it. The bound placement
travels in the params alongside the context, so AD transposes
(broadcast-at-p ↔ reduce_sum-at-p) and batching stay placement-correct.

Partitioned values are arrays whose leading axes are the group axes of a
stack *prefix* (paper Fig. 1; depth k == k leading group axes); all
primitives here operate on single arrays and are mapped over pytrees by
:mod:`repro.core.api`.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import core
from jax.extend.core import Primitive
from jax.interpreters import ad, batching, mlir

from . import placement as placement_lib
from . import sharding as sharding_lib

__all__ = [
    "broadcast_p",
    "reduce_sum_p",
    "reduce_mean_p",
    "reduce_max_p",
    "stage_transfer_p",
    "bind_broadcast",
    "bind_reduce_sum",
    "bind_reduce_mean",
    "bind_reduce_max",
    "bind_stage_transfer",
    "DRJAX_PRIMITIVES",
    "COMMUNICATION_PRIMITIVES",
]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _resolve(
    pctx: placement_lib.PlacementContext, placement: Optional[str]
) -> Tuple[placement_lib.Placement, int]:
    """The addressed placement and its stack index (None = innermost)."""
    idx = pctx.index_of(placement)
    return pctx.placements[idx], idx


def _check_operand_depth(
    x_aval, pctx: placement_lib.PlacementContext, depth: int, prim: str
):
    """Operand must carry the ``depth`` outermost placements' group axes."""
    if x_aval.ndim < depth:
        raise ValueError(
            f"drjax.{prim} at placement "
            f"'{pctx.placements[depth - 1].name}' expects a value partitioned "
            f"at the {depth} outer placement(s) "
            f"{list(pctx.names[:depth])}; got a "
            f"{'scalar' if x_aval.ndim == 0 else f'rank-{x_aval.ndim} array'}."
        )
    for j in range(depth):
        pl = pctx.placements[j]
        if x_aval.shape[j] != pl.size:
            raise ValueError(
                f"drjax.{prim}: axis {j} ({x_aval.shape[j]}) does not match "
                f"the partition size ({pl.size}) of placement "
                f"'{pl.name}'. Partitioned values must carry one leading "
                f"entry per group at every placement of the stack prefix."
            )


def _check_kind(pl: placement_lib.Placement, prim: str, expect: str):
    """Replica collectives only address replica-kind placements; transfer
    only stage-kind ones (wrong-kind communication, rejected at trace time)."""
    if pl.kind != expect:
        other = ("stage_transfer/stage_map" if expect == "replicas"
                 else "broadcast/reduce")
        raise ValueError(
            f"drjax.{prim} cannot address placement '{pl.name}' of kind "
            f"'{pl.kind}' (expects a '{expect}'-kind placement; "
            f"'{pl.kind}' levels communicate via {other})."
        )


# ---------------------------------------------------------------------------
# broadcast
# ---------------------------------------------------------------------------

broadcast_p = Primitive("drjax_broadcast")


def _broadcast_impl(
    x, *, pctx: placement_lib.PlacementContext, placement: Optional[str] = None
):
    pl, i = _resolve(pctx, placement)
    _check_kind(pl, "broadcast", "replicas")  # eager binds skip abstract
    out = jnp.broadcast_to(
        jnp.expand_dims(x, i), x.shape[:i] + (pl.size,) + x.shape[i:]
    )
    return sharding_lib.constrain_partitioned(out, pctx, depth=i + 1)


def _broadcast_abstract(x, *, pctx, placement=None):
    pl, i = _resolve(pctx, placement)
    _check_kind(pl, "broadcast", "replicas")
    _check_operand_depth(x, pctx, i, "broadcast")
    return core.ShapedArray(
        x.shape[:i] + (pl.size,) + x.shape[i:], x.dtype
    )


broadcast_p.def_impl(_broadcast_impl)
broadcast_p.def_abstract_eval(_broadcast_abstract)
mlir.register_lowering(
    broadcast_p, mlir.lower_fun(_broadcast_impl, multiple_results=False)
)


def _broadcast_jvp(primals, tangents, *, pctx, placement=None):
    (x,), (t,) = primals, tangents
    out = broadcast_p.bind(x, pctx=pctx, placement=placement)
    if isinstance(t, ad.Zero):
        t_out = ad.Zero(core.get_aval(out).to_tangent_aval())
    else:
        t_out = broadcast_p.bind(t, pctx=pctx, placement=placement)
    return out, t_out


ad.primitive_jvps[broadcast_p] = _broadcast_jvp


def _broadcast_transpose(ct, x, *, pctx, placement=None):
    # d(broadcast@p)^T = reduce_sum@p  (MapReduce AD closure; Rush et al. 2023)
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    return (reduce_sum_p.bind(ct, pctx=pctx, placement=placement),)


ad.primitive_transposes[broadcast_p] = _broadcast_transpose


def _broadcast_batch(args, dims, *, pctx, placement=None):
    (x,), (d,) = args, dims
    if d is batching.not_mapped:
        return broadcast_p.bind(x, pctx=pctx, placement=placement), d
    # Move the batch axis to the end so the placement-prefix axes stay
    # leading (the addressed placement inserts its axis among them),
    # preserving the primitive under vmap.
    x = jnp.moveaxis(x, d, x.ndim - 1)
    out = broadcast_p.bind(x, pctx=pctx, placement=placement)
    return out, out.ndim - 1


batching.primitive_batchers[broadcast_p] = _broadcast_batch


# ---------------------------------------------------------------------------
# reductions (shared machinery)
# ---------------------------------------------------------------------------


def _fused_compress_reduce(x, i, name: str, compress: str, qaxis: int):
    """Execute a ``compress``-tagged reduction: the fused single-pass
    reduce+roundtrip (Pallas kernel on TPU, fused jnp oracle elsewhere —
    see ``repro.kernels.ops.reduce_compress_roundtrip``)."""
    if name != "reduce_mean" or compress != "int8":
        raise NotImplementedError(
            f"drjax.{name}: fused compress={compress!r} is only implemented "
            "for reduce_mean with int8 (the hierarchical fast path)."
        )
    from repro.kernels import ops as kernel_ops  # lazy: keep core import-light

    return kernel_ops.reduce_compress_roundtrip(x, axis=i, qaxis=qaxis)


def _make_reduction(name: str, reduce_fn):
    p = Primitive(f"drjax_{name}")

    def impl(x, *, pctx: placement_lib.PlacementContext, placement=None,
             compress=None, qaxis=-1):
        pl, i = _resolve(pctx, placement)
        _check_kind(pl, name, "replicas")  # eager binds skip abstract
        if compress is not None:
            out = _fused_compress_reduce(x, i, name, compress, qaxis)
        else:
            out = reduce_fn(x, pl, i)
        if i == 0:
            return sharding_lib.constrain_replicated(out, pctx)
        return sharding_lib.constrain_partitioned(out, pctx, depth=i)

    def abstract(x, *, pctx, placement=None, compress=None, qaxis=-1):
        pl, i = _resolve(pctx, placement)
        _check_kind(pl, name, "replicas")
        _check_operand_depth(x, pctx, i + 1, name)
        return core.ShapedArray(x.shape[:i] + x.shape[i + 1 :], x.dtype)

    p.def_impl(impl)
    p.def_abstract_eval(abstract)
    mlir.register_lowering(p, mlir.lower_fun(impl, multiple_results=False))

    def batch(args, dims, *, pctx, placement=None, compress=None, qaxis=-1):
        (x,), (d,) = args, dims
        if d is batching.not_mapped:
            extra = {} if compress is None else {"compress": compress,
                                                 "qaxis": qaxis}
            return p.bind(x, pctx=pctx, placement=placement, **extra), d
        extra = {} if compress is None else {
            # The batch axis lands at the end (below), so a from-the-end
            # quantization axis shifts one step deeper; a from-the-front one
            # is untouched.
            "compress": compress,
            "qaxis": qaxis - 1 if qaxis < 0 else qaxis,
        }
        # Logical operand: (sizes-prefix, *rest); physical batch dim at d.
        # Move the batch axis to the end so the partition axes stay leading,
        # preserving the primitive (and hence jaxpr interpretability) under
        # vmap.
        x = jnp.moveaxis(x, d, x.ndim - 1)
        out = p.bind(x, pctx=pctx, placement=placement, **extra)
        return out, out.ndim - 1

    batching.primitive_batchers[p] = batch
    return p


reduce_sum_p = _make_reduction(
    "reduce_sum", lambda x, pl, i: jnp.sum(x, axis=i)
)
reduce_mean_p = _make_reduction(
    "reduce_mean", lambda x, pl, i: jnp.sum(x, axis=i) / pl.size
)
reduce_max_p = _make_reduction(
    "reduce_max", lambda x, pl, i: jnp.max(x, axis=i)
)


def _linear_reduction_jvp(p):
    def jvp(primals, tangents, *, pctx, placement=None, **fused):
        # ``fused`` carries compress/qaxis on the int8 fast-path eqn. The
        # primal keeps them (fused execution); the tangent drops them: the
        # roundtrip is straight-through under MapReduce AD, so d(fused
        # reduce_mean@p) == d(reduce_mean@p) and grad matches the unfused
        # composition exactly.
        (x,), (t,) = primals, tangents
        out = p.bind(x, pctx=pctx, placement=placement, **fused)
        if isinstance(t, ad.Zero):
            t_out = ad.Zero(core.get_aval(out).to_tangent_aval())
        else:
            t_out = p.bind(t, pctx=pctx, placement=placement)
        return out, t_out

    return jvp


ad.primitive_jvps[reduce_sum_p] = _linear_reduction_jvp(reduce_sum_p)
ad.primitive_jvps[reduce_mean_p] = _linear_reduction_jvp(reduce_mean_p)


def _reduce_sum_transpose(ct, x, *, pctx, placement=None, **fused):
    # d(reduce_sum@p)^T = broadcast@p
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    return (broadcast_p.bind(ct, pctx=pctx, placement=placement),)


def _reduce_mean_transpose(ct, x, *, pctx, placement=None, **fused):
    # d(reduce_mean@p)^T = broadcast@p / size(p). A compress-tagged eqn
    # transposes identically: the int8 roundtrip is straight-through.
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    pl, _ = _resolve(pctx, placement)
    return (broadcast_p.bind(ct / pl.size, pctx=pctx, placement=placement),)


ad.primitive_transposes[reduce_sum_p] = _reduce_sum_transpose
ad.primitive_transposes[reduce_mean_p] = _reduce_mean_transpose


def _reduce_max_jvp(primals, tangents, *, pctx, placement=None):
    """Sub-gradient JVP for the (non-linear) max reduction.

    The tangent flows from the arg-max group. Expressed with reduce_sum of a
    masked tangent so that reverse-mode stays inside the DrJAX primitive set
    (the mask is constant wrt differentiation).
    """
    (x,), (t,) = primals, tangents
    _, i = _resolve(pctx, placement)
    out = reduce_max_p.bind(x, pctx=pctx, placement=placement)
    if isinstance(t, ad.Zero):
        return out, ad.Zero(core.get_aval(out).to_tangent_aval())
    hit = (x == jnp.expand_dims(out, i)).astype(x.dtype)
    hit = hit / jnp.maximum(jnp.sum(hit, axis=i, keepdims=True), 1)
    t_out = reduce_sum_p.bind(hit * t, pctx=pctx, placement=placement)
    return out, t_out


ad.primitive_jvps[reduce_max_p] = _reduce_max_jvp


# ---------------------------------------------------------------------------
# stage_transfer (stage-kind placements: pipeline neighbor exchange)
# ---------------------------------------------------------------------------

stage_transfer_p = Primitive("drjax_stage_transfer")


def _stage_transfer_impl(
    x, *, pctx: placement_lib.PlacementContext, placement=None,
    shift: int = 1, wrap: bool = False,
):
    pl, i = _resolve(pctx, placement)
    _check_kind(pl, "stage_transfer", "stages")  # eager binds skip abstract
    # out[..., s, ...] = x[..., s - shift, ...]: every stage ships its slice
    # to its (shift)-th neighbor. With wrap=False the boundary slots are
    # zero-filled — the linear map whose transpose is the reverse shift, so
    # MapReduce AD yields the backward pipeline for free. Under a mesh the
    # depth-(i+1) constraint keeps the stage axis pinned, and GSPMD lowers
    # the shift to a collective-permute (ppermute-style) neighbor exchange.
    out = jnp.roll(x, shift, axis=i)
    if not wrap:
        src = jnp.arange(pl.size) - shift
        valid = (src >= 0) & (src < pl.size)
        valid = valid.reshape(
            (1,) * i + (pl.size,) + (1,) * (x.ndim - i - 1)
        )
        out = jnp.where(valid, out, jnp.zeros_like(out))
    return sharding_lib.constrain_partitioned(out, pctx, depth=i + 1)


def _stage_transfer_abstract(x, *, pctx, placement=None, shift=1, wrap=False):
    pl, i = _resolve(pctx, placement)
    _check_kind(pl, "stage_transfer", "stages")
    _check_operand_depth(x, pctx, i + 1, "stage_transfer")
    return core.ShapedArray(x.shape, x.dtype)


stage_transfer_p.def_impl(_stage_transfer_impl)
stage_transfer_p.def_abstract_eval(_stage_transfer_abstract)
mlir.register_lowering(
    stage_transfer_p,
    mlir.lower_fun(_stage_transfer_impl, multiple_results=False),
)


def _stage_transfer_jvp(primals, tangents, *, pctx, placement=None,
                        shift=1, wrap=False):
    (x,), (t,) = primals, tangents
    out = stage_transfer_p.bind(
        x, pctx=pctx, placement=placement, shift=shift, wrap=wrap
    )
    if isinstance(t, ad.Zero):
        t_out = ad.Zero(core.get_aval(out).to_tangent_aval())
    else:
        t_out = stage_transfer_p.bind(
            t, pctx=pctx, placement=placement, shift=shift, wrap=wrap
        )
    return out, t_out


ad.primitive_jvps[stage_transfer_p] = _stage_transfer_jvp


def _stage_transfer_transpose(ct, x, *, pctx, placement=None, shift=1,
                              wrap=False):
    # d(transfer shift)^T = transfer -shift: cotangents flow stage s+shift
    # -> stage s, the backward pipeline's reverse neighbor exchange (with
    # wrap, the reverse rotation).
    if isinstance(ct, ad.Zero):
        return (ad.Zero(x.aval),)
    return (
        stage_transfer_p.bind(
            ct, pctx=pctx, placement=placement, shift=-shift, wrap=wrap
        ),
    )


ad.primitive_transposes[stage_transfer_p] = _stage_transfer_transpose


def _stage_transfer_batch(args, dims, *, pctx, placement=None, shift=1,
                          wrap=False):
    (x,), (d,) = args, dims
    if d is batching.not_mapped:
        return (
            stage_transfer_p.bind(
                x, pctx=pctx, placement=placement, shift=shift, wrap=wrap
            ),
            d,
        )
    # Batch axis to the end so the placement-prefix axes stay leading.
    x = jnp.moveaxis(x, d, x.ndim - 1)
    out = stage_transfer_p.bind(
        x, pctx=pctx, placement=placement, shift=shift, wrap=wrap
    )
    return out, out.ndim - 1


batching.primitive_batchers[stage_transfer_p] = _stage_transfer_batch


# ---------------------------------------------------------------------------
# user-facing single-leaf binders (one primitive at one placement)
# ---------------------------------------------------------------------------


def _ctx() -> placement_lib.PlacementContext:
    return placement_lib.current_context()


def _bind_params(placement: Optional[str]):
    """Resolve the addressed placement to its concrete name at bind time so
    the eqn params carry an explicit placement tag (the §5 interpreter reads
    it back without re-resolving defaults)."""
    ctx = _ctx()
    return dict(pctx=ctx, placement=ctx.get(placement).name)


def bind_broadcast(x, placement: Optional[str] = None):
    x = jnp.asarray(x)
    return broadcast_p.bind(x, **_bind_params(placement))


def bind_reduce_sum(x, placement: Optional[str] = None):
    return reduce_sum_p.bind(x, **_bind_params(placement))


def bind_reduce_mean(x, placement: Optional[str] = None, *,
                     compress: Optional[str] = None, qaxis: int = -1):
    """``compress="int8"`` tags the eqn for the fused single-pass
    reduce+roundtrip execution (``qaxis`` = the partial's axis that carries
    the per-row-block scales). The params are only attached when set, so
    plain reductions keep their exact eqn signature."""
    if compress is None:
        return reduce_mean_p.bind(x, **_bind_params(placement))
    return reduce_mean_p.bind(
        x, compress=compress, qaxis=qaxis, **_bind_params(placement)
    )


def bind_reduce_max(x, placement: Optional[str] = None):
    return reduce_max_p.bind(x, **_bind_params(placement))


def bind_stage_transfer(x, placement: Optional[str] = None, *,
                        shift: int = 1, wrap: bool = False):
    x = jnp.asarray(x)
    return stage_transfer_p.bind(
        x, shift=int(shift), wrap=bool(wrap), **_bind_params(placement)
    )


DRJAX_PRIMITIVES: Tuple[Primitive, ...] = (
    broadcast_p,
    reduce_sum_p,
    reduce_mean_p,
    reduce_max_p,
    stage_transfer_p,
)

# Primitives that imply cross-group communication when interpreted onto a
# distributed system (used by the jaxpr interpreter, paper §5).
COMMUNICATION_PRIMITIVES = frozenset(p.name for p in DRJAX_PRIMITIVES)
