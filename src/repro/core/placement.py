"""Placement context for DrJAX programs.

A *placement* names a logical partition (e.g. ``"clients"``) and carries its
cardinality (the number of groups). DrJAX decouples this logical cardinality
from physical devices: a partition of size ``n`` may be sharded over any ``m``
devices with ``m | n`` (paper §3, "Sharding DrJAX computations").

The context also carries the *mesh axes* that the partition's leading array
axis should be sharded over, and whether sharding annotations are installed at
all (``use_sharding_annotations=False`` reproduces the paper's DrJAX-NS
ablation, Fig. 6).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Sequence, Tuple, Union

import jax

AxisSpec = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class PlacementContext:
    """Ambient configuration for DrJAX primitives.

    Attributes:
      placement: logical name of the partition ("clients" by default — the
        paper's federated heritage — but any name works).
      partition_size: number of groups n in the partition.
      partition_axes: mesh axis name(s) the leading (partition) array axis is
        sharded over, e.g. ``"data"`` or ``("pod", "data")``. ``None`` means
        no sharding constraint is emitted (DrJAX-NS).
      mesh: optional concrete mesh. If ``None``, sharding constraints use the
        ambient mesh (``repro.compat.set_mesh``, which picks the right
        mechanism for the installed JAX version).
      use_sharding_annotations: master switch for static + dynamic sharding
        annotations. ``False`` == DrJAX-NS (paper Fig. 6 ablation).
      use_spmd_axis_name: whether ``map_fn`` passes ``spmd_axis_name`` to
        ``jax.vmap`` (the *dynamic* sharding annotations on intermediates).
    """

    placement: str = "clients"
    partition_size: int = 1
    partition_axes: AxisSpec = None
    mesh: Optional[jax.sharding.Mesh] = None
    use_sharding_annotations: bool = True
    use_spmd_axis_name: bool = True

    def axes_tuple(self) -> Tuple[str, ...]:
        if self.partition_axes is None:
            return ()
        if isinstance(self.partition_axes, str):
            return (self.partition_axes,)
        return tuple(self.partition_axes)

    def spmd_axis_name(self):
        axes = self.axes_tuple()
        if not axes or not self.use_sharding_annotations or not self.use_spmd_axis_name:
            return None
        # jax.vmap accepts a single name or a tuple of names.
        return axes if len(axes) > 1 else axes[0]


class _ContextStack(threading.local):
    def __init__(self):
        super().__init__()
        self.stack = []


_CTX = _ContextStack()


def current_context() -> PlacementContext:
    if not _CTX.stack:
        raise RuntimeError(
            "No DrJAX placement context active. Wrap your computation with "
            "@drjax.program(partition_size=...) or `with placement_context(...)`."
        )
    return _CTX.stack[-1]


def has_context() -> bool:
    return bool(_CTX.stack)


@contextlib.contextmanager
def placement_context(ctx: PlacementContext):
    _CTX.stack.append(ctx)
    try:
        yield ctx
    finally:
        _CTX.stack.pop()


def make_context(
    partition_size: int,
    *,
    placement: str = "clients",
    partition_axes: AxisSpec = "data",
    mesh: Optional[jax.sharding.Mesh] = None,
    use_sharding_annotations: bool = True,
    use_spmd_axis_name: bool = True,
) -> PlacementContext:
    if partition_size < 1:
        raise ValueError(f"partition_size must be >= 1, got {partition_size}")
    return PlacementContext(
        placement=placement,
        partition_size=partition_size,
        partition_axes=partition_axes,
        mesh=mesh,
        use_sharding_annotations=use_sharding_annotations,
        use_spmd_axis_name=use_spmd_axis_name,
    )
