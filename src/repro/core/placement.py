"""Placement stack for DrJAX programs.

A *placement* names a logical partition (e.g. ``"clients"``) and carries its
cardinality (the number of groups). DrJAX decouples this logical cardinality
from physical devices: a partition of size ``n`` may be sharded over any ``m``
devices with ``m | n`` (paper §3, "Sharding DrJAX computations").

Placements NEST (paper §6, "hierarchical placements"): a context may hold an
ordered stack of named placements, outermost first — e.g.
``{"pods": P, "clients": m}`` models ``m`` clients inside each of ``P`` pods.
A value partitioned at depth ``k`` carries the ``k`` outermost placements'
group axes as its ``k`` leading array axes, in stack order; depth 0 is the
server. Placement-sets therefore form a chain of stack prefixes — the
placement lattice the §5 interpreter solves over.

Each placement carries its *own* mesh axes, so its group axis pins its own
slice of the device mesh (pods over the slow DCN axis, clients over ICI), and
whether sharding annotations are installed at all
(``use_sharding_annotations=False`` reproduces the paper's DrJAX-NS ablation,
Fig. 6).

The single-placement context of the paper's API is the one-entry degenerate
case: every legacy accessor (``partition_size``, ``partition_axes``,
``axes_tuple`` …) reads the innermost placement.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax

AxisSpec = Union[str, Tuple[str, ...], None]

#: Valid placement kinds. ``"replicas"`` is the paper's data-replica group
#: (broadcast/reduce communicate across it); ``"stages"`` marks the level as
#: model pipeline stages (JaxPP-style MPMD), which communicate only through
#: neighbor ``stage_transfer`` exchange and per-stage ``stage_map``.
PLACEMENT_KINDS = ("replicas", "stages")


def _axes_tuple(axes: AxisSpec) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


@dataclasses.dataclass(frozen=True)
class Placement:
    """One named level of the placement stack.

    Attributes:
      name: logical name of the partition ("clients", "pods", ...).
      size: number of groups at this level.
      axes: mesh axis name(s) this level's group axis is sharded over, e.g.
        ``"data"`` or ``("pod", "data")``. ``None`` means no sharding
        constraint for this level (purely logical).
      kind: what the groups at this level *are*. ``"replicas"`` (default,
        today's behavior unchanged) — data-parallel replica groups addressed
        by broadcast/reduce. ``"stages"`` — model pipeline stages: replica
        collectives are rejected at this level; stages exchange values with
        ``stage_transfer`` (ppermute-style neighbor traffic) and run
        per-stage functions via ``stage_map``.
    """

    name: str
    size: int
    axes: AxisSpec = None
    kind: str = "replicas"

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(
                f"placement {self.name!r} must have size >= 1, got {self.size}"
            )
        if self.kind not in PLACEMENT_KINDS:
            raise ValueError(
                f"placement {self.name!r} has unknown kind {self.kind!r}; "
                f"valid kinds are {list(PLACEMENT_KINDS)}"
            )

    def axes_tuple(self) -> Tuple[str, ...]:
        return _axes_tuple(self.axes)


@dataclasses.dataclass(frozen=True)
class PlacementContext:
    """Ambient configuration for DrJAX primitives.

    Attributes:
      placements: the placement stack, outermost first. A value partitioned
        at depth k leads with the k outermost placements' group axes.
      mesh: optional concrete mesh. If ``None``, sharding constraints use the
        ambient mesh (``repro.compat.set_mesh``, which picks the right
        mechanism for the installed JAX version).
      use_sharding_annotations: master switch for static + dynamic sharding
        annotations. ``False`` == DrJAX-NS (paper Fig. 6 ablation).
      use_spmd_axis_name: whether ``map_fn`` passes ``spmd_axis_name`` to
        ``jax.vmap`` (the *dynamic* sharding annotations on intermediates).
    """

    placements: Tuple[Placement, ...] = (Placement("clients", 1),)
    mesh: Optional[jax.sharding.Mesh] = None
    use_sharding_annotations: bool = True
    use_spmd_axis_name: bool = True

    def __post_init__(self):
        if not self.placements:
            raise ValueError("PlacementContext needs at least one placement")
        names = [p.name for p in self.placements]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate placement names: {names}")

    # -- stack accessors ----------------------------------------------------

    @property
    def depth(self) -> int:
        """Number of stacked placements (1 for the paper's flat API)."""
        return len(self.placements)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.placements)

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(p.size for p in self.placements)

    @property
    def kinds(self) -> Tuple[str, ...]:
        return tuple(p.kind for p in self.placements)

    def stage_names(self) -> Tuple[str, ...]:
        """Names of the stage-kind levels, outermost first."""
        return tuple(p.name for p in self.placements if p.kind == "stages")

    @property
    def innermost(self) -> Placement:
        return self.placements[-1]

    def index_of(self, name: Optional[str]) -> int:
        """Stack index of a placement; ``None`` addresses the innermost."""
        if name is None:
            return self.depth - 1
        for i, p in enumerate(self.placements):
            if p.name == name:
                return i
        raise KeyError(
            f"no placement named {name!r} in this context "
            f"(have {list(self.names)})"
        )

    def get(self, name: Optional[str]) -> Placement:
        return self.placements[self.index_of(name)]

    def total_size(self) -> int:
        """Total number of innermost groups across the whole stack."""
        return math.prod(self.sizes)

    def spmd_axis_name_for(self, placement: Optional[str] = None):
        """The vmap ``spmd_axis_name`` for one placement level (or None)."""
        if not self.use_sharding_annotations or not self.use_spmd_axis_name:
            return None
        axes = self.get(placement).axes_tuple()
        if not axes:
            return None
        # jax.vmap accepts a single name or a tuple of names.
        return axes if len(axes) > 1 else axes[0]

    # -- legacy single-placement surface (innermost placement) --------------

    @property
    def placement(self) -> str:
        return self.innermost.name

    @property
    def partition_size(self) -> int:
        return self.innermost.size

    @property
    def partition_axes(self) -> AxisSpec:
        return self.innermost.axes

    def axes_tuple(self) -> Tuple[str, ...]:
        return self.innermost.axes_tuple()

    def spmd_axis_name(self):
        return self.spmd_axis_name_for(None)


class _ContextStack(threading.local):
    def __init__(self):
        super().__init__()
        self.stack = []


_CTX = _ContextStack()


def current_context() -> PlacementContext:
    if not _CTX.stack:
        raise RuntimeError(
            "No DrJAX placement context active. Wrap your computation with "
            "@drjax.program(partition_size=...) or `with placement_context(...)`."
        )
    return _CTX.stack[-1]


def has_context() -> bool:
    return bool(_CTX.stack)


@contextlib.contextmanager
def placement_context(ctx: PlacementContext):
    _CTX.stack.append(ctx)
    try:
        yield ctx
    finally:
        _CTX.stack.pop()


def _normalize_axes(
    names: Sequence[str], partition_axes
) -> Tuple[AxisSpec, ...]:
    """Per-placement mesh axes from the user-facing ``partition_axes`` arg.

    Accepts a mapping {placement_name: axes}, or (single placement only) the
    legacy bare axis spec applied to that placement.
    """
    if isinstance(partition_axes, Mapping):
        unknown = set(partition_axes) - set(names)
        if unknown:
            raise ValueError(
                f"partition_axes names unknown placements {sorted(unknown)}; "
                f"placements are {list(names)}"
            )
        return tuple(partition_axes.get(n) for n in names)
    if len(names) == 1:
        return (partition_axes,)
    if partition_axes is None:
        return tuple(None for _ in names)
    raise ValueError(
        "with multiple placements, partition_axes must be a mapping "
        "{placement_name: mesh_axes} (or None)"
    )


def make_context(
    partition_size: Optional[int] = None,
    *,
    placement: str = "clients",
    placements: Optional[Mapping[str, int]] = None,
    partition_axes=None,
    placement_kinds: Optional[Mapping[str, str]] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    use_sharding_annotations: bool = True,
    use_spmd_axis_name: bool = True,
) -> PlacementContext:
    """Build a context from either the flat or the stacked spec.

    ``make_context(n)`` — the paper's single placement of size n.
    ``make_context(placements={"pods": P, "clients": m})`` — a nested stack,
    outermost first (mapping order is the stack order).
    ``placement_kinds`` optionally maps placement names to a kind
    (``"replicas"`` — the default — or ``"stages"`` for pipeline stages).
    """
    if placements is not None:
        if partition_size is not None:
            raise ValueError("pass either partition_size or placements, not both")
        if not placements:
            raise ValueError("placements mapping must not be empty")
        names = tuple(placements)
        sizes = tuple(placements.values())
    else:
        if partition_size is None:
            raise ValueError("partition_size (or placements) is required")
        if partition_size < 1:
            raise ValueError(
                f"partition_size must be >= 1, got {partition_size}"
            )
        names, sizes = (placement,), (partition_size,)
    axes = _normalize_axes(names, partition_axes)
    kinds_map = dict(placement_kinds or {})
    unknown_kinds = set(kinds_map) - set(names)
    if unknown_kinds:
        raise ValueError(
            f"placement_kinds names unknown placements "
            f"{sorted(unknown_kinds)}; placements are {list(names)}"
        )
    stack = tuple(
        Placement(n, s, a, kind=kinds_map.get(n, "replicas"))
        for n, s, a in zip(names, sizes, axes)
    )
    return PlacementContext(
        placements=stack,
        mesh=mesh,
        use_sharding_annotations=use_sharding_annotations,
        use_spmd_axis_name=use_spmd_axis_name,
    )
