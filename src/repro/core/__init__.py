"""DrJAX core: differentiable MapReduce primitives for JAX.

Usage mirrors the paper:

.. code-block:: python

    from repro import core as drjax

    @drjax.program(partition_size=3)
    def f(x):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a: 2 * a, y)
        return drjax.reduce_sum(z)
"""

from .api import (
    broadcast,
    map_fn,
    masked_reduce_mean,
    partition_size,
    placement_context,
    program,
    reduce_max,
    reduce_mean,
    reduce_sum,
    reduce_weighted_mean,
    stage_map,
    stage_transfer,
    current_context,
)
from .hierarchical import (
    cross_pod_bytes,
    hierarchical_reduce_mean,
    int8_wire_ratio,
)
from .interpreter import (
    Broadcast,
    CondStage,
    LocalCompute,
    LoopStage,
    MapReducePlan,
    Reduce,
    Transfer,
    build_plan,
    count_primitives,
    run_plan,
    trace,
)
from .placement import Placement, PlacementContext, make_context
from .primitives import (
    COMMUNICATION_PRIMITIVES,
    DRJAX_PRIMITIVES,
    broadcast_p,
    reduce_max_p,
    reduce_mean_p,
    reduce_sum_p,
    stage_transfer_p,
)
from .sharding import constrain_partitioned, constrain_replicated, partition_spec

__all__ = [
    "broadcast",
    "map_fn",
    "masked_reduce_mean",
    "partition_size",
    "placement_context",
    "program",
    "reduce_max",
    "reduce_mean",
    "reduce_sum",
    "reduce_weighted_mean",
    "stage_map",
    "stage_transfer",
    "current_context",
    "hierarchical_reduce_mean",
    "cross_pod_bytes",
    "int8_wire_ratio",
    "MapReducePlan",
    "Broadcast",
    "Reduce",
    "LocalCompute",
    "LoopStage",
    "CondStage",
    "Transfer",
    "build_plan",
    "count_primitives",
    "run_plan",
    "trace",
    "Placement",
    "PlacementContext",
    "make_context",
    "COMMUNICATION_PRIMITIVES",
    "DRJAX_PRIMITIVES",
    "broadcast_p",
    "reduce_max_p",
    "reduce_mean_p",
    "reduce_sum_p",
    "stage_transfer_p",
    "constrain_partitioned",
    "constrain_replicated",
    "partition_spec",
]
