"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus squared-ReLU channel-mix.

Per head (head_dim = N) the time-mix recurrence over state S ∈ R^{N×N}:

    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)

with data-dependent decay  w_t = exp(-exp(w0 + LoRA_w(x̃_t))) ∈ (0,1)^N.

Three execution paths:
 * ``sequential_wkv`` — plain lax.scan, the oracle (and the decode step);
 * ``chunked_wkv`` — TPU-native chunkwise-parallel form: the per-pair decay
   factorizes as exp(lcw_{i-1} - lcw_j) = (r_i e^{lcw_{i-1}})·(k_j e^{-lcw_j}),
   turning intra-chunk interaction into plain matmuls (MXU-friendly) while the
   state S carries across chunks — this is the hardware adaptation of the
   paper's CUDA kernel;
 * a Pallas TPU kernel (``repro.kernels.wkv6``) with the same chunked scheme.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .partitioning import with_logical_constraint

_LORA = 32


def num_heads(cfg) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init_params(rng, cfg):
    d, dt = cfg.d_model, cfg.jnp_dtype
    n = cfg.rwkv_head_dim
    h = num_heads(cfg)
    ks = jax.random.split(rng, 12)
    return {
        # time-mix projections
        "wr": common.normal_init(ks[0], (d, d), dt),
        "wk": common.normal_init(ks[1], (d, d), dt),
        "wv": common.normal_init(ks[2], (d, d), dt),
        "wg": common.normal_init(ks[3], (d, d), dt),
        "wo": common.normal_init(ks[4], (d, d), dt),
        # token-shift interpolation weights (static lerp mixes) for r,k,v,g,w
        "mix": 0.5 * jnp.ones((5, d), dt),
        # data-dependent decay: w0 + tanh(x A) B
        "w0": common.normal_init(ks[5], (d,), jnp.float32, stddev=0.5),
        "wA": common.normal_init(ks[6], (d, _LORA), jnp.float32, stddev=0.1),
        "wB": common.normal_init(ks[7], (_LORA, d), jnp.float32, stddev=0.1),
        # per-channel bonus
        "u": common.normal_init(ks[8], (d,), jnp.float32, stddev=0.5),
        # group-norm scale on heads
        "ln_scale": jnp.ones((d,), dt),
        # channel mix
        "cm_rk": 0.5 * jnp.ones((2, d), dt),
        "ck": common.normal_init(ks[9], (d, cfg.d_ff), dt),
        "cv": common.normal_init(ks[10], (cfg.d_ff, d), dt),
        "cr": common.normal_init(ks[11], (d, d), dt),
    }


def param_axes(cfg):
    return {
        "wr": ("p_fsdp", "heads"),
        "wk": ("p_fsdp", "heads"),
        "wv": ("p_fsdp", "heads"),
        "wg": ("p_fsdp", "heads"),
        "wo": ("heads", "p_fsdp"),
        "mix": (None, None),
        "w0": (None,),
        "wA": (None, None),
        "wB": (None, None),
        "u": (None,),
        "ln_scale": (None,),
        "cm_rk": (None, None),
        "ck": ("p_fsdp", "p_ff"),
        "cv": ("p_ff", "p_fsdp"),
        "cr": ("p_fsdp", None),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros or given state at t=0). x: (B,S,D)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None].astype(x.dtype)
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _mixes(p, x, xprev):
    """Apply static lerp token-shift for (r, k, v, g, w) channels."""
    mix = p["mix"].astype(x.dtype)  # (5, D)
    outs = []
    for i in range(5):
        outs.append(x + (xprev - x) * mix[i])
    return outs  # xr, xk, xv, xg, xw


def _decay(p, xw):
    """Data-dependent per-channel decay w_t ∈ (0,1)."""
    lora = jnp.einsum(
        "bsd,dl->bsl", xw.astype(jnp.float32), p["wA"]
    )
    lora = jnp.tanh(lora)
    loga = p["w0"] + jnp.einsum("bsl,ld->bsd", lora, p["wB"])
    return -jnp.exp(loga)  # log(w_t) = -exp(...) ∈ (-inf, 0)


# ---------------------------------------------------------------------------
# WKV recurrence: sequential (oracle / decode) and chunked (TPU)
# ---------------------------------------------------------------------------


def sequential_wkv(r, k, v, logw, u, state=None):
    """r,k,v: (B, S, H, N); logw: (B, S, H, N); u: (H, N).

    Returns (out (B,S,H,N), final_state (B,H,N,N))."""
    b, s, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)

    def step(S, inp):
        rt, kt, vt, lwt = inp  # (B,H,N)
        wt = jnp.exp(lwt)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., None] * S + kv
        return S_new, out

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, logw)
    )
    final, outs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(outs, 0, 1), final


def chunked_wkv(r, k, v, logw, u, state=None, chunk: int = 64):
    """Chunkwise-parallel WKV (matmul form). Same contract as sequential."""
    b, s, h, n = r.shape
    pad = (-s) % chunk
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = r.shape[1]
    nc = sp // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, nc, chunk, h, n)
    kc = k.astype(f32).reshape(b, nc, chunk, h, n)
    vc = v.astype(f32).reshape(b, nc, chunk, h, n)
    lw = logw.astype(f32).reshape(b, nc, chunk, h, n)

    if state is None:
        state = jnp.zeros((b, h, n, n), f32)

    def chunk_step(S, inp):
        rc_, kc_, vc_, lw_ = inp  # (B, C, H, N)
        # cumulative log-decay within chunk: lcw_i = sum_{t<=i} lw_t
        lcw = jnp.cumsum(lw_, axis=1)  # (B,C,H,N)
        lcw_prev = lcw - lw_  # sum_{t<i+1} = lcw_{i-1}
        # inter-chunk: o_i += (r_i ⊙ e^{lcw_{i-1}}) @ S
        r_dec = rc_ * jnp.exp(lcw_prev)
        o = jnp.einsum("bchk,bhkv->bchv", r_dec, S)
        # intra-chunk: pairwise j < i, decay e^{lcw_{i-1} - lcw_j}
        k_dec = kc_ * jnp.exp(-lcw)
        scores = jnp.einsum("bchk,bdhk->bhcd", r_dec, k_dec)  # (B,H,C,C)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        o = o + jnp.einsum("bhcd,bdhv->bchv", scores, vc_)
        # bonus (diagonal) term: (r_i · (u ⊙ k_i)) v_i
        bonus = jnp.einsum("bchk,hk,bchk->bch", rc_, u, kc_)
        o = o + bonus[..., None] * vc_
        # state update: S' = diag(e^{lcw_C}) S + Σ_j e^{lcw_C - lcw_j} k_j v_jᵀ
        total = lcw[:, -1]  # (B,H,N)
        k_rem = kc_ * jnp.exp(total[:, None] - lcw)
        S_new = jnp.exp(total)[..., None] * S + jnp.einsum(
            "bchk,bchv->bhkv", k_rem, vc_
        )
        return S_new, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, lw))
    final, outs = jax.lax.scan(chunk_step, state, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, h, n)
    return out[:, :s], final


def _group_norm(x, scale, n):
    """Per-head RMS-style norm. x: (B,S,H,N) f32."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


# ---------------------------------------------------------------------------
# full blocks
# ---------------------------------------------------------------------------


def time_mix(cfg, p, x, *, shift_state=None, wkv_state=None, chunked=True):
    """RWKV6 attention analogue. x: (B,S,D) -> (out, (shift_state, wkv_state))."""
    b, s, d = x.shape
    h, n = num_heads(cfg), cfg.rwkv_head_dim
    xprev = _shift(x, shift_state)
    xr, xk, xv, xg, xw = _mixes(p, x, xprev)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(b, s, h, n)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(b, s, h, n)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(b, s, h, n)
    g = jnp.einsum("bsd,de->bse", xg, p["wg"])
    logw = _decay(p, xw).reshape(b, s, h, n)
    u = p["u"].reshape(h, n)
    r = with_logical_constraint(r, ("batch", "seq", "heads", None))
    k = with_logical_constraint(k, ("batch", "seq", "heads", None))
    v = with_logical_constraint(v, ("batch", "seq", "heads", None))

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    if chunked and s > 1:
        out, final = chunked_wkv(rf, kf, vf, logw, u, state=wkv_state)
    else:
        out, final = sequential_wkv(rf, kf, vf, logw, u, state=wkv_state)
    out = _group_norm(out, p["ln_scale"].astype(jnp.float32).reshape(h, n), n)
    out = out.reshape(b, s, d).astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", out, p["wo"], preferred_element_type=jnp.float32)
    new_shift = x[:, -1].astype(jnp.float32)
    return out.astype(x.dtype), (new_shift, final)


def channel_mix(cfg, p, x, *, shift_state=None):
    xprev = _shift(x, shift_state)
    mix = p["cm_rk"].astype(x.dtype)
    xk = x + (xprev - x) * mix[0]
    xr = x + (xprev - x) * mix[1]
    kk = jnp.einsum("bsd,df->bsf", xk, p["ck"], preferred_element_type=jnp.float32)
    kk = jnp.square(jax.nn.relu(kk)).astype(x.dtype)
    kk = with_logical_constraint(kk, ("batch", "seq", "ff"))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cv"], preferred_element_type=jnp.float32)
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cr"], preferred_element_type=jnp.float32)
    )
    out = (rr * vv).astype(x.dtype)
    return out, x[:, -1].astype(jnp.float32)


def init_state(cfg, batch: int):
    h, n = num_heads(cfg), cfg.rwkv_head_dim
    return {
        "tm_shift": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "cm_shift": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((batch, h, n, n), jnp.float32),
    }


def state_axes():
    return {
        "tm_shift": ("kv_batch", None),
        "cm_shift": ("kv_batch", None),
        "wkv": ("kv_batch", "heads", None, None),
    }
