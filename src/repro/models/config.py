"""Model configuration for the architecture zoo.

One frozen dataclass covers all 10 assigned families (dense / MoE / VLM /
audio enc-dec / hybrid / SSM). Exact per-arch values live in
``repro/configs/<id>.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- attention ---
    attention: str = "global"  # global | local | none
    window_size: int = 0  # for local attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0

    # --- hybrid (recurrentgemma): repeating block pattern ---
    # e.g. ("recurrent", "recurrent", "attention") for RG's 1 attn : 2 rec
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0  # RG-LRU width (0 -> d_model)

    # --- ssm (rwkv6) ---
    rwkv_head_dim: int = 64

    # --- encoder-decoder (seamless) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0

    # --- modality frontend stubs ---
    # none | vision_patches (llava anyres) | audio_frames (seamless)
    frontend: str = "none"
    num_frontend_tokens: int = 0  # patch/frame embeddings per example

    # --- serving ---
    # Token id that terminates generation (None: generate max_new tokens).
    # The serve schedulers stop a slot as soon as this id is emitted.
    eos_id: Optional[int] = None

    # --- numerics / structure ---
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"  # activation/param dtype for lowering
    tie_embeddings: bool = False

    # --- execution ---
    mesh_strategy: str = "tp"  # tp: model dims over "model" axis; dp: pure data
    scan_layers: bool = True  # lax.scan over stacked layers (uniform stacks)
    remat: str = "none"  # none | full | dots — activation checkpoint policy
    attn_impl: str = "blocked"  # blocked | naive | flash(pallas, TPU only)
    tp_comm: str = "bf16"  # bf16 | int8 — TP reduction wire format (fwd-only steps)
    q_block: int = 512
    kv_block: int = 1024

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.family == "hybrid" and not self.block_pattern:
            object.__setattr__(
                self, "block_pattern", ("recurrent", "recurrent", "attention")
            )

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_heads_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    # ------------------------------------------------------------------
    # parameter counting (used for 6·N·D roofline accounting)
    # ------------------------------------------------------------------

    def _attn_params(self) -> int:
        hd = self.head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _dense_ffn_params(self, d_ff: Optional[int] = None) -> int:
        d_ff = d_ff or self.d_ff
        return 3 * self.d_model * d_ff  # gated (SwiGLU/GeGLU): wi, wg, wo

    def _rglru_params(self) -> int:
        w = self.lru_width
        # linear in/out (conv-free simplification), gates a/x, Λ params
        return 2 * self.d_model * w + 2 * w * (w // 8) * 8 // 8 + 2 * w

    def _rwkv_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + data-dependent decay lora + mixes
        tm = 5 * d * d + 2 * d * 64 + 6 * d
        cm = 2 * d * self.d_ff + d * d  # channel mix (k, v, receptance)
        return tm + cm

    def layer_params(self, layer_kind: str = "attention") -> int:
        norms = 2 * self.d_model
        if self.family == "ssm":
            return self._rwkv_params() + norms
        if layer_kind == "recurrent":
            return self._rglru_params() + self._dense_ffn_params() + norms
        ffn = (
            self.num_experts * self._dense_ffn_params()
            + self.d_model * self.num_experts  # router
            if self.family in ("moe",)
            else self._dense_ffn_params()
        )
        return self._attn_params() + ffn + norms

    def active_layer_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        norms = 2 * self.d_model
        if self.family == "ssm":
            return self._rwkv_params() + norms
        if self.family == "moe":
            ffn = self.experts_per_token * self._dense_ffn_params() + (
                self.d_model * self.num_experts
            )
            return self._attn_params() + ffn + norms
        return self.layer_params()

    def _pattern_counts(self):
        if self.family != "hybrid":
            return {"attention": self.num_layers}
        pat = self.block_pattern
        full, rem = divmod(self.num_layers, len(pat))
        counts = {}
        for i, kind in enumerate(pat):
            counts[kind] = counts.get(kind, 0) + full + (1 if i < rem else 0)
        return counts

    def param_count(self) -> int:
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        body = 0
        for kind, cnt in self._pattern_counts().items():
            body += cnt * self.layer_params(kind)
        if self.is_encoder_decoder:
            # encoder layers + decoder cross-attention
            body += self.encoder_layers * self.layer_params()
            body += self.num_layers * self._attn_params()  # cross-attn
        return emb + head + body + self.d_model  # final norm

    def active_param_count(self) -> int:
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        body = 0
        for kind, cnt in self._pattern_counts().items():
            if kind == "attention" or self.family != "hybrid":
                body += cnt * self.active_layer_params()
            else:
                body += cnt * self.layer_params(kind)
        if self.is_encoder_decoder:
            body += self.encoder_layers * self.active_layer_params()
            body += self.num_layers * self._attn_params()
        return emb + head + body + self.d_model

    # ------------------------------------------------------------------

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family."""
        small = dict(
            num_layers=min(self.num_layers, 2 * len(self.block_pattern) or 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            lru_width=64,
            window_size=min(self.window_size, 32) if self.window_size else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=(
                min(self.experts_per_token, 2) if self.experts_per_token else 0
            ),
            encoder_layers=min(self.encoder_layers, 2),
            num_frontend_tokens=(
                min(self.num_frontend_tokens, 8) if self.num_frontend_tokens else 0
            ),
            dtype="float32",
            attn_impl="naive",
            q_block=8,
            kv_block=8,
        )
        if self.family == "hybrid":
            small["num_layers"] = len(self.block_pattern)
        small.update(overrides)
        return dataclasses.replace(self, **small)
