"""Quantized tensor-parallel collectives (beyond-paper optimization).

The dominant roofline term for the large train/prefill cells is the per-layer
tensor-parallel activation reduction: each TP block ends with partial sums
that GSPMD reduces in bf16 (ring all-reduce ≈ 2·(m-1)/m · bytes on the wire).

``int8_matmul_reduce`` replaces that reduction for a TP matmul's output with:

    local partial matmul (f32)
      → per-row symmetric int8 quantization (repro.kernels.quantize scheme)
      → all-gather of (int8 values + f32 row scales) over the model axis
      → local dequant-sum

Wire bytes: (m-1)/m · (1 byte + scales) vs 2·(m-1)/m · 2 bytes for bf16
all-reduce → ≈ 3.9× fewer bytes at m=16. Cost: m× dequant-add flops
(negligible vs the matmul) and bounded quantization error on *partial sums*
(error ≤ absmax/254 per row per shard; validated in tests, cosine > 0.999).

Implemented with shard_map (via ``repro.compat``, which picks the right API
across JAX versions) so the collective is explicit in the lowered HLO — the
dry-run's collective parser sees ``all-gather`` ops with ``s8`` operands,
which is the measurement used in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

from . import partitioning


def _quant_rows(x):
    """x: (..., d) f32 -> (int8, scales). Per-row symmetric quantization."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_matmul_reduce(x, w, *, axis_name: str = "model",
                       batch_axes=("data",), out_dtype=None):
    """TP matmul with int8-quantized cross-shard reduction.

    x: (T, f) with f sharded over ``axis_name`` (and T over ``batch_axes``);
    w: (f, d) with f sharded over ``axis_name``. Returns (T, d) = x @ w with
    the partial-sum reduction carried in int8.

    Falls back to a plain matmul when no mesh is installed (CPU tests).
    """
    mesh = partitioning.current_mesh()
    out_dtype = out_dtype or x.dtype
    if mesh is None or axis_name not in mesh.axis_names:
        out = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return out.astype(out_dtype)

    batch = tuple(a for a in batch_axes if a in mesh.axis_names)
    bspec = batch if len(batch) > 1 else (batch[0] if batch else None)

    def local(xs, ws):
        # xs: (T_loc, f_loc); ws: (f_loc, d). Partial over the f shards.
        part = jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        q, s = _quant_rows(part)
        qg = jax.lax.all_gather(q, axis_name)  # (m, T_loc, d) int8 on the wire
        sg = jax.lax.all_gather(s, axis_name)  # (m, T_loc, 1) f32
        out = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
        return out.astype(out_dtype)

    fn = compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(bspec, axis_name), P(axis_name, None)),
        out_specs=P(bspec, None),
        check=False,
    )
    return fn(x, w)


def bf16_wire_bytes(t_tokens: int, d: int, m: int) -> float:
    """Per-device wire bytes of the baseline bf16 all-reduce."""
    return 2.0 * (m - 1) / m * t_tokens * d * 2.0


def int8_wire_bytes(t_tokens: int, d: int, m: int) -> float:
    """Per-device wire bytes of the int8 all-gather reduction."""
    return (m - 1) / m * t_tokens * (d * 1.0 + 4.0)
