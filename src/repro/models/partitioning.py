"""Logical-axis partitioning (MaxText-style) for the model zoo.

Model code annotates tensors with *logical* axis names
(``("batch", "seq", "heads", "head_dim")``); a rule table maps logical names
to mesh axes. The same model code then runs on any mesh — single-pod
``(data, model)``, multi-pod ``(pod, data, model)``, or CPU (no mesh — all
constraints become no-ops).

FSDP is purely a rule choice here: pointing a parameter's storage axis at
``("data",)`` makes GSPMD keep it sharded at rest and all-gather it layer by
layer inside the scan — no model-code change (this is the standard pjit FSDP
pattern). The DrJAX partition axis composes on top: inside
``drjax.map_fn``'s vmap, intermediates get the partition axes prepended via
``spmd_axis_name``.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import REPLICA_AXES

AxisName = Union[str, Tuple[str, ...], None]

# Default logical → mesh-axis rules. First matching mesh axis set that exists
# on the ambient mesh (and divides the dim, for parameters) wins.
DEFAULT_RULES: Dict[str, Tuple[AxisName, ...]] = {
    # activations
    "batch": (REPLICA_AXES, "data"),
    "seq": (None,),
    "embed": ("model", None),  # sharded residual stream (Megatron seq-par analogue)
    "heads": ("model",),
    "kv_heads": ("model", None),
    "head_dim": (None,),
    "ff": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    # parameters (storage)
    "p_embed": ("model", None),   # param rows over model axis
    "p_vocab": ("model", None),
    "p_ff": ("model",),
    "p_heads": ("model",),
    "p_kv_heads": ("model", None),
    "p_head_dim": (None,),
    "p_experts": ("model",),
    "p_fsdp": ("data", None),     # FSDP storage axis
    "layers": (None,),
    # misc
    "kv_batch": (REPLICA_AXES, "data"),
    "kv_head_dim": ("model", None),
    "recurrent_width": ("model",),
}


class _Ctx(threading.local):
    def __init__(self):
        super().__init__()
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Tuple[AxisName, ...]] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    """Install a mesh + logical-rule table for model code in this thread."""
    old_mesh, old_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _CTX.rules = merged
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old_mesh, old_rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_axis(logical: Optional[str], dim_size: Optional[int] = None) -> AxisName:
    """Resolve one logical axis name to mesh axis/axes (or None)."""
    if logical is None or _CTX.mesh is None:
        return None
    sizes = _mesh_axis_sizes(_CTX.mesh)
    for cand in _CTX.rules.get(logical, (None,)):
        if cand is None:
            return None
        names = cand if isinstance(cand, tuple) else (cand,)
        if not all(n in sizes for n in names):
            continue
        if dim_size is not None:
            total = 1
            for n in names:
                total *= sizes[n]
            if dim_size % total != 0:
                continue
        return cand
    return None


def spec_for(logical_axes: Sequence[Optional[str]], shape=None) -> P:
    parts = []
    for i, name in enumerate(logical_axes):
        dim = None if shape is None else shape[i]
        parts.append(resolve_axis(name, dim))
    return P(*parts)


def with_logical_constraint(x, logical_axes: Sequence[Optional[str]]):
    """Constrain an array's sharding via logical axis names (no-op w/o mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    assert x.ndim == len(logical_axes), (x.shape, logical_axes)
    spec = spec_for(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]], shape=None):
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(logical_axes, shape))


def tree_shardings(tree_logical, tree_shapes=None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    if tree_shapes is None:
        return jax.tree_util.tree_map(
            lambda ax: named_sharding(ax),
            tree_logical,
            is_leaf=lambda v: isinstance(v, tuple) and all(
                isinstance(e, (str, type(None))) for e in v
            ),
        )
    return jax.tree_util.tree_map(
        lambda ax, shp: named_sharding(ax, shp),
        tree_logical,
        tree_shapes,
        is_leaf=lambda v: isinstance(v, tuple) and all(
            isinstance(e, (str, type(None))) for e in v
        ),
    )
