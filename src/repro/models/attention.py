"""GQA attention: blocked (flash-style, XLA), naive, decode-with-cache.

The *blocked* path is the production default: an online-softmax scan over KV
blocks that never materializes the (Sq, Skv) score matrix — the same
algorithmic shape as the Pallas flash kernel in ``repro.kernels``, expressed
in XLA so it lowers on any backend (the dry-run runs on CPU host devices
where Mosaic cannot lower). HLO matmul FLOPs are identical to the kernel's;
the kernel additionally keeps tiles in VMEM.

Supports:
 * grouped-query attention (Hq = G * Hkv),
 * causal and local (sliding-window) masking,
 * cross-attention (no masking, separate memory length),
 * single-token decode against a fixed-size or ring-buffer KV cache.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import common
from .partitioning import with_logical_constraint

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_params(rng, cfg, cross: bool = False):
    """QKV/O projection params. Shapes: wq (D, Hq, hd); wk/wv (D, Hkv, hd);
    wo (Hq, hd, D)."""
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.jnp_dtype
    ks = jax.random.split(rng, 4)
    p = {
        "wq": common.normal_init(ks[0], (d, hq, hd), dt),
        "wk": common.normal_init(ks[1], (d, hkv, hd), dt),
        "wv": common.normal_init(ks[2], (d, hkv, hd), dt),
        "wo": common.normal_init(ks[3], (hq, hd, d), dt, stddev=1.0 / math.sqrt(hq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, hd), dt)
        p["bk"] = jnp.zeros((hkv, hd), dt)
        p["bv"] = jnp.zeros((hkv, hd), dt)
    return p


_MODEL_AXIS = 16  # production meshes always have model=16


def _shard_heads(cfg) -> bool:
    """Shard attention over heads when divisible, else over head_dim
    (56-head archs like yi-34b: 56 % 16 != 0 but head_dim 128 % 16 == 0)."""
    return cfg.num_heads % _MODEL_AXIS == 0


def head_logical_axes(cfg, kv: bool = False):
    if _shard_heads(cfg):
        if not kv:
            return ("heads", None)
        if cfg.num_kv_heads % _MODEL_AXIS == 0:
            return ("kv_heads", None)
        # GQA with few kv heads: replicate the (small) kv activations rather
        # than shard head_dim — sharding hd here conflicts with heads-sharded
        # Q in the attention contraction and forces SPMD full remat (seen in
        # compile logs). The KV *cache* still stores hd-sharded (cache_axes).
        return (None, None)
    return (None, "kv_head_dim")


def param_axes(cfg, cross: bool = False):
    if _shard_heads(cfg):
        h, hd = "p_heads", "p_head_dim"
    else:
        h, hd = None, "kv_head_dim"
    kvh = "p_kv_heads" if cfg.num_kv_heads % _MODEL_AXIS == 0 else None
    kvd = "p_head_dim" if kvh else "kv_head_dim"
    axes = {
        "wq": ("p_fsdp", h, hd),
        "wk": ("p_fsdp", kvh, kvd),
        "wv": ("p_fsdp", kvh, kvd),
        "wo": (h, hd, "p_fsdp"),
    }
    if cfg.qkv_bias:
        axes["bq"] = (h, hd)
        axes["bk"] = (kvh, kvd)
        axes["bv"] = (kvh, kvd)
    return axes


def _proj(x, w, b=None):
    out = jnp.einsum("bsd,dhk->bshk", x, w, preferred_element_type=jnp.float32)
    out = out.astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def qkv(cfg, p, x, positions, rope: bool = True):
    q = _proj(x, p["wq"], p.get("bq"))
    k = _proj(x, p["wk"], p.get("bk"))
    v = _proj(x, p["wv"], p.get("bv"))
    if rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    qh = head_logical_axes(cfg)
    kvh = head_logical_axes(cfg, kv=True)
    q = with_logical_constraint(q, ("batch", "seq") + qh)
    k = with_logical_constraint(k, ("batch", "seq") + kvh)
    v = with_logical_constraint(v, ("batch", "seq") + kvh)
    return q, k, v


def out_proj(p, attn_out):
    out = jnp.einsum(
        "bshk,hkd->bsd", attn_out, p["wo"], preferred_element_type=jnp.float32
    )
    return out.astype(attn_out.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(…q, …k) additive bias from position comparisons."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# naive attention (smoke tests / tiny shapes / oracle)
# ---------------------------------------------------------------------------


def naive_attention(q, k, v, *, causal=True, window=0, q_pos=None, k_pos=None):
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if q_pos is None:
        q_pos = jnp.arange(sq)
    if k_pos is None:
        k_pos = jnp.arange(skv)
    s = s + _mask_bias(q_pos, k_pos, causal, window)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, hq, hd)


# ---------------------------------------------------------------------------
# blocked (online-softmax) attention
# ---------------------------------------------------------------------------


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def blocked_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 1024,
    skip_masked_blocks: bool = False,
):
    """Flash-style attention via scan over KV blocks; O(Sq·block) memory.

    ``skip_masked_blocks=True`` enables the causal block-skipping schedule:
    only lower-triangular (q-block, kv-block) pairs are computed (≈2× fewer
    attention FLOPs at long sequence), at the cost of a flattened-pair scan.
    """
    b, sq_orig, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    q, sq_orig = _pad_to(q, 1, q_block)
    k, skv_orig = _pad_to(k, 1, kv_block)
    v, _ = _pad_to(v, 1, kv_block)
    sq, skv = q.shape[1], k.shape[1]
    nq, nk = sq // q_block, skv // kv_block

    qg = q.reshape(b, nq, q_block, hkv, g, hd)
    kb = k.reshape(b, nk, kv_block, hkv, hd)
    vb = v.reshape(b, nk, kv_block, hkv, hd)

    def kv_step(carry, j, qi, i):
        acc, m, l = carry
        kj = kb[:, j]
        vj = vb[:, j]
        s = (
            jnp.einsum("bqhgd,bkhd->bqhgk", qi, kj, preferred_element_type=jnp.float32)
            * scale
        )
        q_pos = i * q_block + jnp.arange(q_block)
        k_pos = j * kv_block + jnp.arange(kv_block)
        bias = _mask_bias(q_pos, k_pos, causal, window)
        # also mask KV padding
        bias = jnp.where((k_pos < skv_orig)[None, :], bias, NEG_INF)
        s = s + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    def q_block_fn(i):
        qi = qg[:, i]
        acc0 = jnp.zeros((b, q_block, hkv, g, hd), jnp.float32)
        m0 = jnp.full((b, q_block, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_block, hkv, g), jnp.float32)

        if skip_masked_blocks and causal and not window:
            # only kv blocks whose start can be visible to this q block
            # (static bound: scan over all, but the mask-only blocks are
            # handled by the pair schedule below instead).
            pass
        step = functools.partial(kv_step, qi=qi, i=i)
        (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    if skip_masked_blocks and causal and not window:
        return _blocked_attention_tri(
            qg, kb, vb, scale, b, nq, nk, q_block, kv_block, hkv, g, hd,
            sq_orig, skv_orig,
        )

    out = jax.lax.map(q_block_fn, jnp.arange(nq))  # (nq, b, qb, hkv, g, hd)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hkv * g, hd)
    return out[:, :sq_orig]


def _blocked_attention_tri(
    qg, kb, vb, scale, b, nq, nk, q_block, kv_block, hkv, g, hd, sq_orig, skv_orig
):
    """Causal block-skipping schedule: scan lower-triangular (i, j) pairs only.

    Beyond-paper perf optimization (see EXPERIMENTS.md §Perf): for causal
    attention with Sq == Skv this computes nq(nq+1)/2 block pairs instead of
    nq·nk, halving attention FLOPs at long sequence length.
    """
    ratio = max(kv_block // q_block, 1)
    pairs = [
        (i, j)
        for i in range(nq)
        for j in range(nk)
        if j * kv_block <= i * q_block + q_block - 1  # block intersects causal
    ]
    pair_arr = jnp.array(pairs, jnp.int32)  # (P, 2)

    acc0 = jnp.zeros((nq, b, q_block, hkv, g, hd), jnp.float32)
    m0 = jnp.full((nq, b, q_block, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, q_block, hkv, g), jnp.float32)

    def step(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qg, i, axis=1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
        s = (
            jnp.einsum("bqhgd,bkhd->bqhgk", qi, kj, preferred_element_type=jnp.float32)
            * scale
        )
        q_pos = i * q_block + jnp.arange(q_block)
        k_pos = j * kv_block + jnp.arange(kv_block)
        ok = (k_pos[None, :] <= q_pos[:, None]) & (k_pos < skv_orig)[None, :]
        s = s + jnp.where(ok, 0.0, NEG_INF)[None, :, None, None, :]
        mi = jax.lax.dynamic_index_in_dim(m, i, axis=0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, axis=0, keepdims=False)
        acci = jax.lax.dynamic_index_in_dim(acc, i, axis=0, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(axis=-1)
        acc_new = acci * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        acc = jax.lax.dynamic_update_index_in_dim(acc, acc_new, i, axis=0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), pair_arr)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_block, hkv * g, hd)
    return out[:, :sq_orig].astype(qg.dtype)


# ---------------------------------------------------------------------------
# flash attention in XLA with a custom VJP (O(S) residual memory)
#
# Plain autodiff through the blocked-attention scan stores the per-block
# softmax probabilities — O(S²) residuals that dominate training memory at
# 4k+ context. The custom VJP saves only (q, k, v, out, L=logsumexp) and
# recomputes score blocks in the backward pass (Dao et al.'s recipe, here
# expressed with a static lower-triangular block-pair schedule that also
# skips fully-masked pairs — causal FLOPs ≈ halved, fwd and bwd).
# ---------------------------------------------------------------------------


def _visible_pairs(nq, nk, q_block, kv_block, skv_orig, causal, window):
    pairs = []
    for i in range(nq):
        for j in range(nk):
            q_lo, q_hi = i * q_block, i * q_block + q_block - 1
            k_lo, k_hi = j * kv_block, j * kv_block + kv_block - 1
            if k_lo >= skv_orig:
                continue
            if causal and k_lo > q_hi:
                continue
            if window and window > 0 and k_hi <= q_lo - window:
                continue
            pairs.append((i, j))
    return pairs


def _pair_mask(i, j, q_block, kv_block, skv_orig, causal, window):
    q_pos = i * q_block + jnp.arange(q_block)
    k_pos = j * kv_block + jnp.arange(kv_block)
    ok = (k_pos < skv_orig)[None, :] & jnp.ones((q_block, 1), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return ok


def _flash_fwd_core(q, k, v, causal, window, q_block, kv_block):
    """Returns (out (B,Sq,Hq,hd) f32, L (B,Sq,hkv,g) f32 logsumexp)."""
    b, sq_orig, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    qp, _ = _pad_to(q, 1, q_block)
    kp, skv_orig = _pad_to(k, 1, kv_block)
    vp, _ = _pad_to(v, 1, kv_block)
    sq, skv = qp.shape[1], kp.shape[1]
    nq, nk = sq // q_block, skv // kv_block
    qg = qp.reshape(b, nq, q_block, hkv, g, hd).astype(jnp.float32)
    kb = kp.reshape(b, nk, kv_block, hkv, hd).astype(jnp.float32)
    vb = vp.reshape(b, nk, kv_block, hkv, hd).astype(jnp.float32)

    pairs = _visible_pairs(nq, nk, q_block, kv_block, skv_orig, causal, window)
    pair_arr = jnp.array(pairs, jnp.int32)

    acc0 = jnp.zeros((nq, b, q_block, hkv, g, hd), jnp.float32)
    m0 = jnp.full((nq, b, q_block, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, q_block, hkv, g), jnp.float32)

    def step(carry, pair):
        acc, m, l = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        # dynamic mask (i, j traced)
        q_pos = i * q_block + jnp.arange(q_block)
        k_pos = j * kv_block + jnp.arange(kv_block)
        ok = (k_pos < skv_orig)[None, :] & jnp.ones((q_block, 1), bool)
        if causal:
            ok = ok & (k_pos[None, :] <= q_pos[:, None])
        if window and window > 0:
            ok = ok & (k_pos[None, :] > (q_pos[:, None] - window))
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        acci = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(axis=-1)
        acc_new = acci * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vj, preferred_element_type=jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, acc_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), pair_arr)
    lsafe = jnp.maximum(l, 1e-30)
    out = acc / lsafe[..., None]
    L = m + jnp.log(lsafe)  # (nq, b, qb, hkv, g)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hkv * g, hd)[:, :sq_orig]
    L = jnp.moveaxis(L, 0, 1).reshape(b, sq, hkv, g)[:, :sq_orig]
    return out, L


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_xla(q, k, v, causal, window, q_block, kv_block):
    out, _ = _flash_fwd_core(q, k, v, causal, window, q_block, kv_block)
    return out.astype(q.dtype)


def _flash_fwd_rule(q, k, v, causal, window, q_block, kv_block):
    out, L = _flash_fwd_core(q, k, v, causal, window, q_block, kv_block)
    return out.astype(q.dtype), (q, k, v, out, L)


def _flash_bwd_rule(causal, window, q_block, kv_block, res, dout):
    q, k, v, out, L = res
    b, sq_orig, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    qp, _ = _pad_to(q, 1, q_block)
    kp, skv_orig = _pad_to(k, 1, kv_block)
    vp, _ = _pad_to(v, 1, kv_block)
    dop, _ = _pad_to(dout.astype(jnp.float32), 1, q_block)
    outp, _ = _pad_to(out, 1, q_block)
    Lp, _ = _pad_to(L, 1, q_block)
    sq, skv = qp.shape[1], kp.shape[1]
    nq, nk = sq // q_block, skv // kv_block

    qg = qp.reshape(b, nq, q_block, hkv, g, hd).astype(jnp.float32)
    kb = kp.reshape(b, nk, kv_block, hkv, hd).astype(jnp.float32)
    vb = vp.reshape(b, nk, kv_block, hkv, hd).astype(jnp.float32)
    dog = dop.reshape(b, nq, q_block, hkv, g, hd)
    og = outp.reshape(b, nq, q_block, hkv, g, hd)
    Lg = Lp.reshape(b, nq, q_block, hkv, g)
    # D_i = rowsum(dout * out)
    Dg = jnp.sum(dog * og, axis=-1)  # (b, nq, qb, hkv, g)

    pairs = _visible_pairs(nq, nk, q_block, kv_block, skv_orig, causal, window)
    pair_arr = jnp.array(pairs, jnp.int32)

    dq0 = jnp.zeros((nq, b, q_block, hkv, g, hd), jnp.float32)
    dk0 = jnp.zeros((nk, b, kv_block, hkv, hd), jnp.float32)
    dv0 = jnp.zeros((nk, b, kv_block, hkv, hd), jnp.float32)

    def step(carry, pair):
        dq, dk, dv = carry
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        doi = jax.lax.dynamic_index_in_dim(dog, i, 1, keepdims=False)
        Li = jax.lax.dynamic_index_in_dim(Lg, i, 1, keepdims=False)
        Di = jax.lax.dynamic_index_in_dim(Dg, i, 1, keepdims=False)

        s = jnp.einsum("bqhgd,bkhd->bqhgk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        q_pos = i * q_block + jnp.arange(q_block)
        k_pos = j * kv_block + jnp.arange(kv_block)
        ok = (k_pos < skv_orig)[None, :] & jnp.ones((q_block, 1), bool)
        if causal:
            ok = ok & (k_pos[None, :] <= q_pos[:, None])
        if window and window > 0:
            ok = ok & (k_pos[None, :] > (q_pos[:, None] - window))
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - Li[..., None])  # exact probabilities via saved L

        dvj = jnp.einsum("bqhgk,bqhgd->bkhd", p, doi)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", doi, vj)
        ds = p * (dp - Di[..., None]) * scale
        dqi = jnp.einsum("bqhgk,bkhd->bqhgd", ds, kj)
        dkj = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qi)

        dq = jax.lax.dynamic_update_index_in_dim(
            dq, jax.lax.dynamic_index_in_dim(dq, i, 0, keepdims=False) + dqi,
            i, 0)
        dk = jax.lax.dynamic_update_index_in_dim(
            dk, jax.lax.dynamic_index_in_dim(dk, j, 0, keepdims=False) + dkj,
            j, 0)
        dv = jax.lax.dynamic_update_index_in_dim(
            dv, jax.lax.dynamic_index_in_dim(dv, j, 0, keepdims=False) + dvj,
            j, 0)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), pair_arr)
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, sq, hq, hd)[:, :sq_orig]
    dk = jnp.moveaxis(dk, 0, 1).reshape(b, skv, hkv, hd)[:, :skv_orig]
    dv = jnp.moveaxis(dv, 0, 1).reshape(b, skv, hkv, hd)[:, :skv_orig]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention_xla.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def self_attention(cfg, q, k, v, *, causal=True, window=0):
    if cfg.attn_impl == "naive":
        return naive_attention(q, k, v, causal=causal, window=window)
    if cfg.attn_impl == "blocked_novjp":
        # plain-autodiff baseline (stores O(S²) residuals under grad;
        # kept for the §Perf before/after comparison)
        skip = getattr(cfg, "skip_masked_blocks", False)
        return blocked_attention(
            q, k, v, causal=causal, window=window,
            q_block=cfg.q_block, kv_block=cfg.kv_block,
            skip_masked_blocks=skip,
        )
    return flash_attention_xla(
        q, k, v, causal, window, min(cfg.q_block, q.shape[1]),
        min(cfg.kv_block, k.shape[1]),
    )


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, *, window: Optional[int] = None,
               ring: bool = True):
    """Fixed-size cache; for local attention pass window to get a ring buffer.

    ``ring=False`` forces the no-ring layout (size == max_len, slot index ==
    absolute position) even for windowed attention — the layout chunked
    prefill requires (``chunk_attention`` writes at absolute positions), used
    by the serve slot pool. The window is then applied as an explicit mask in
    ``decode_attention``/``chunk_attention``.
    """
    size = min(window, max_len) if (window and ring) else max_len
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    dt = cfg.jnp_dtype
    return {
        "k": jnp.zeros((batch, size, hkv, hd), dt),
        "v": jnp.zeros((batch, size, hkv, hd), dt),
        "pos": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


_MODEL_AXIS_SIZE = 16  # production meshes always have model=16


def cache_logical_axes(cfg):
    """KV-cache sharding: kv_heads over the model axis when divisible, else
    head_dim (GQA archs with kv_heads < model axis; vLLM-style layout)."""
    if cfg.num_kv_heads and cfg.num_kv_heads % _MODEL_AXIS_SIZE == 0:
        return ("kv_batch", "seq", "kv_heads", None)
    return ("kv_batch", "seq", None, "kv_head_dim")


def cache_axes(cfg):
    kv = cache_logical_axes(cfg)
    return {"k": kv, "v": kv, "pos": ()}


def fill_cache(cache, k, v, *, window: int = 0):
    """Prefill: write a whole prefix into the cache (truncate to window).

    Ring-buffer invariant (window case): absolute position p lives at slot
    p % size, matching ``decode_attention``'s write slot.
    """
    size = cache["k"].shape[1]
    s = k.shape[1]
    if window and s > size:
        k = k[:, -size:]
        v = v[:, -size:]
        write = size
        start = s - size
    else:
        write = min(s, size)
        k = k[:, :write]
        v = v[:, :write]
        start = 0
    slots = (start + jnp.arange(write)) % size
    newk = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
    newv = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    return {"k": newk, "v": newv, "pos": jnp.asarray(s, jnp.int32)}


def decode_attention(cfg, p, x, cache, *, window: int = 0, rope: bool = True):
    """One decode step. x: (B, 1, D). Returns (out (B,1,D), new_cache)."""
    b = x.shape[0]
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _proj(x, p["wq"], p.get("bq"))
    k = _proj(x, p["wk"], p.get("bk"))
    v = _proj(x, p["wv"], p.get("bv"))
    if rope:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)

    size = cache["k"].shape[1]
    # Two windowed layouts: a ring buffer (size == window; recency by
    # overwrite) and the serve-pool "no-ring" layout (size > window, one slot
    # per absolute position, window applied as an explicit mask below).
    ring = bool(window) and size == window
    slot = jnp.mod(pos, size) if ring else jnp.minimum(pos, size - 1)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    kv_axes = cache_logical_axes(cfg)
    ck = with_logical_constraint(ck, kv_axes)
    cv = with_logical_constraint(cv, kv_axes)

    hq, hd = cfg.num_heads, cfg.head_dim
    hkv = cfg.num_kv_heads
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, hd)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, ck, preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(hd))

    # valid slots: for ring buffer all slots < min(pos+1, size); absolute
    # recency is guaranteed by the ring overwrite. For global cache, slots
    # <= pos are valid. For the no-ring windowed layout slot index == absolute
    # position, so the sliding window is an explicit mask.
    idx = jnp.arange(size)
    valid = idx < jnp.minimum(pos + 1, size)
    if window and not ring:
        valid &= idx > pos - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(cv.dtype), cv)
    out = out.reshape(b, 1, hq, hd)
    out = out_proj(p, out)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1}
    return out, new_cache


def chunk_attention(cfg, p, x, cache, positions, *, window: int = 0):
    """Chunked-prefill continuation: C prompt tokens against an existing cache.

    x: (B, C, D); ``positions`` (B, C) absolute token positions (``pos0 +
    arange(C)`` with ``pos0 == cache["pos"]``). Requires the no-ring cache
    layout (``init_cache(..., ring=False)`` — slot index == absolute
    position): writes the chunk's K/V at ``[pos0, pos0 + C)`` and attends each
    chunk query over all cached positions ``<= q_pos`` (window applied as an
    explicit mask). For a global-attention config this is bitwise-equal to
    ``naive_attention`` full prefill over the same prefix: masked slots get
    an additive ``NEG_INF`` whose ``exp`` underflows to exactly 0, so the
    softmax sums and the value contraction see exact zeros.
    """
    b, c, _ = x.shape
    pos0 = cache["pos"]
    size = cache["k"].shape[1]
    # Contract (not statically checkable): the cache must hold every absolute
    # position, i.e. size == max_len (``init_cache(..., ring=False)``). A
    # windowed *ring* cache (size == window < max_len) would wrap — its
    # writes clamp silently. A cache with size == window == max_len is fine:
    # ring and no-ring layouts coincide when no position can wrap.
    q = _proj(x, p["wq"], p.get("bq"))
    k = _proj(x, p["wk"], p.get("bk"))
    v = _proj(x, p["wv"], p.get("bv"))
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, pos0, 0, 0)
    )
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, pos0, 0, 0)
    )
    kv_axes = cache_logical_axes(cfg)
    ck = with_logical_constraint(ck, kv_axes)
    cv = with_logical_constraint(cv, kv_axes)

    hq, hd = cfg.num_heads, cfg.head_dim
    hkv = cfg.num_kv_heads
    g = hq // hkv
    qg = q.reshape(b, c, hkv, g, hd)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, ck, preferred_element_type=jnp.float32
    ) * (1.0 / math.sqrt(hd))
    q_pos = pos0 + jnp.arange(c)
    idx = jnp.arange(size)
    ok = idx[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= idx[None, :] > (q_pos[:, None] - window)
    s = jnp.where(ok[None, None, None, :, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(cv.dtype), cv)
    out = out.reshape(b, c, hq, hd)
    out = out_proj(p, out)
    return out, {"k": ck, "v": cv, "pos": pos0 + c}


# ---------------------------------------------------------------------------
# cross-attention (enc-dec)
# ---------------------------------------------------------------------------


def cross_attention(cfg, p, x, memory_k, memory_v):
    """x: (B, Sq, D) attends to precomputed encoder memory (B, Sm, Hkv, hd)."""
    q = _proj(x, p["wq"], p.get("bq"))
    if cfg.attn_impl == "naive":
        return out_proj(
            p, naive_attention(q, memory_k, memory_v, causal=False, window=0)
        )
    out = blocked_attention(
        q,
        memory_k,
        memory_v,
        causal=False,
        window=0,
        q_block=cfg.q_block,
        kv_block=cfg.kv_block,
    )
    return out_proj(p, out)
