"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (Griffin "recurrent block"):

    x ── linear_in ──┬── causal conv1d(4) ── RG-LRU ──┐
                     └── gelu gate ────────────────────⊙── linear_out

RG-LRU recurrence (diagonal, data-dependent decay):

    r_t = sigmoid(W_a x_t + b_a)           (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)           (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)      (decay in (0,1), c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth, TPU-friendly);
decode is a single fused step. A Pallas TPU kernel for the scan lives in
``repro.kernels.rglru_scan``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .partitioning import with_logical_constraint

_C = 8.0
_CONV_WIDTH = 4


def init_params(rng, cfg):
    d, w, dt = cfg.d_model, cfg.lru_width, cfg.jnp_dtype
    ks = jax.random.split(rng, 7)
    return {
        "w_in": common.normal_init(ks[0], (d, 2 * w), dt),
        "w_out": common.normal_init(ks[1], (w, d), dt),
        "conv": common.normal_init(ks[2], (_CONV_WIDTH, w), dt, stddev=0.1),
        "w_a": common.normal_init(ks[3], (w, w), dt),
        "b_a": jnp.zeros((w,), dt),
        "w_x": common.normal_init(ks[4], (w, w), dt),
        "b_x": jnp.zeros((w,), dt),
        # Λ init so that softplus(Λ) gives decays in a useful range
        "lam": common.normal_init(ks[5], (w,), jnp.float32, stddev=0.5),
    }


def param_axes(cfg):
    return {
        "w_in": ("p_fsdp", "recurrent_width"),
        "w_out": ("recurrent_width", "p_fsdp"),
        "conv": (None, "recurrent_width"),
        "w_a": ("p_fsdp", "recurrent_width"),
        "b_a": ("recurrent_width",),
        "w_x": ("p_fsdp", "recurrent_width"),
        "b_x": ("recurrent_width",),
        "lam": ("recurrent_width",),
    }


def _gates(p, u):
    """u: (..., W) post-conv input. Returns decay a and gated input."""
    r = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", u, p["w_a"]).astype(jnp.float32)
        + p["b_a"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...w,wv->...v", u, p["w_x"]).astype(jnp.float32)
        + p["b_x"].astype(jnp.float32)
    )
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (..., W), <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, gated


def _causal_conv(p, x, state=None):
    """Depthwise causal conv, width 4. x: (B, S, W)."""
    w = p["conv"].astype(jnp.float32)  # (4, W)
    xf = x.astype(jnp.float32)
    if state is not None:  # state (B, 3, W) holds the last 3 inputs
        if x.shape[1] == 1:  # decode
            buf = jnp.concatenate([state, xf], axis=1)  # (B, 4, W)
            out = jnp.einsum("btw,tw->bw", buf, w)[:, None]
            return out.astype(x.dtype), buf[:, 1:]
        # chunked prefill: continue the conv window across the chunk boundary
        buf = jnp.concatenate([state, xf], axis=1)  # (B, S+3, W)
        stacked = jnp.stack(
            [buf[:, i : i + x.shape[1]] for i in range(_CONV_WIDTH)], axis=-1
        )  # (B, S, W, 4)
        out = jnp.einsum("bswt,tw->bsw", stacked, w)
        return out.astype(x.dtype), buf[:, -(_CONV_WIDTH - 1):]
    pads = jnp.pad(xf, ((0, 0), (_CONV_WIDTH - 1, 0), (0, 0)))
    stacked = jnp.stack(
        [pads[:, i : i + x.shape[1]] for i in range(_CONV_WIDTH)], axis=-1
    )  # (B, S, W, 4)
    out = jnp.einsum("bswt,tw->bsw", stacked, w)
    return out.astype(x.dtype), None


def lru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan. a,b: (B, S, W) f32."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply(cfg, p, x):
    """Train/prefill path. x: (B, S, D) -> (B, S, D)."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"], preferred_element_type=jnp.float32)
    u = u.astype(x.dtype)
    u, gate = jnp.split(u, 2, axis=-1)
    u = with_logical_constraint(u, ("batch", "seq", "recurrent_width"))
    u, _ = _causal_conv(p, u)
    a, bterm = _gates(p, u)
    h = lru_scan(a, bterm)
    h = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = jnp.einsum("bsw,wd->bsd", h, p["w_out"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def init_state(cfg, batch: int):
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_WIDTH - 1, w), jnp.float32),
    }


def state_axes():
    return {
        "h": ("kv_batch", "recurrent_width"),
        "conv": ("kv_batch", None, "recurrent_width"),
    }


def decode_step(cfg, p, x, state):
    """x: (B, 1, D) -> (out (B, 1, D), new_state)."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"], preferred_element_type=jnp.float32)
    u = u.astype(x.dtype)
    u, gate = jnp.split(u, 2, axis=-1)
    u, conv_state = _causal_conv(p, u, state["conv"])
    a, bterm = _gates(p, u[:, 0])
    h = a * state["h"] + bterm
    out = h.astype(x.dtype)[:, None] * jax.nn.gelu(gate)
    out = jnp.einsum("bsw,wd->bsd", out, p["w_out"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype), {"h": h, "conv": conv_state}


def prefill(cfg, p, x, state=None):
    """Run the block over a prefix and return (out, final_state).

    With ``state`` (a previous chunk's final state) the recurrence, the conv
    window, and the LRU hidden state all continue across the chunk boundary —
    the chunked-prefill path of the serve runtime.
    """
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"], preferred_element_type=jnp.float32)
    u = u.astype(x.dtype)
    u, gate = jnp.split(u, 2, axis=-1)
    uc, _ = _causal_conv(p, u, None if state is None else state["conv"])
    a, bterm = _gates(p, uc)
    h = lru_scan(a, bterm, h0=None if state is None else state["h"])
    out = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = jnp.einsum("bsw,wd->bsd", out, p["w_out"], preferred_element_type=jnp.float32)
    u32 = u.astype(jnp.float32)
    if state is not None:
        # conv inputs seen so far: previous window ++ this chunk
        u32 = jnp.concatenate([state["conv"], u32], axis=1)
    if u32.shape[1] < _CONV_WIDTH - 1:  # short prefix: left-pad with zeros
        pad = _CONV_WIDTH - 1 - u32.shape[1]
        u32 = jnp.pad(u32, ((0, 0), (pad, 0), (0, 0)))
    new_state = {
        "h": h[:, -1],
        "conv": u32[:, -(_CONV_WIDTH - 1):],
    }
    return out.astype(x.dtype), new_state
