"""Gated feed-forward (SwiGLU / GeGLU) blocks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common, tpcomm
from .partitioning import current_mesh, resolve_axis, with_logical_constraint


def init_params(rng, cfg, d_ff=None):
    d, dt = cfg.d_model, cfg.jnp_dtype
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "wi": common.normal_init(ks[0], (d, d_ff), dt),
        "wg": common.normal_init(ks[1], (d, d_ff), dt),
        "wo": common.normal_init(ks[2], (d_ff, d), dt),
    }


def param_axes(cfg):
    return {
        "wi": ("p_fsdp", "p_ff"),
        "wg": ("p_fsdp", "p_ff"),
        "wo": ("p_ff", "p_fsdp"),
    }


def apply(cfg, p, x):
    act = common.activation(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"], preferred_element_type=jnp.float32)
    g = jnp.einsum("bsd,df->bsf", x, p["wg"], preferred_element_type=jnp.float32)
    h = (act(g) * h).astype(x.dtype)
    h = with_logical_constraint(h, ("batch", "seq", "ff"))
    if (
        cfg.tp_comm == "int8"
        and current_mesh() is not None
        and resolve_axis("ff", h.shape[-1]) == "model"
    ):
        # quantized TP reduction (see tpcomm): forward-only steps
        b, s_, f = h.shape
        mesh = current_mesh()
        from repro.launch.mesh import REPLICA_AXES

        batch_axes = tuple(a for a in REPLICA_AXES if a in mesh.axis_names)
        out = tpcomm.int8_matmul_reduce(
            h.reshape(b * s_, f), p["wo"], batch_axes=batch_axes,
            out_dtype=x.dtype,
        ).reshape(b, s_, -1)
        return out
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"], preferred_element_type=jnp.float32)
    return out.astype(x.dtype)
