"""Architecture registry: configs → init/loss/serve functions + input specs.

Every assigned architecture is selectable by ``--arch <id>``; each shape cell
(train_4k / prefill_32k / decode_32k / long_500k) maps to a concrete step
function plus ``jax.ShapeDtypeStruct`` input stand-ins (no allocation — the
dry-run lowers against these).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer, vlm
from .config import ModelConfig

ARCH_IDS = (
    "phi35_moe",
    "qwen3_moe",
    "llava_next_34b",
    "internlm2_20b",
    "stablelm_3b",
    "qwen2_72b",
    "yi_34b",
    "seamless_m4t_medium",
    "recurrentgemma_2b",
    "rwkv6_3b",
    # the paper's own local-SGD experiment models
    "lm_350m",
    "lm_1b",
    "lm_8b",
)

SHAPE_CELLS: Dict[str, Dict[str, int]] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# archs with O(S^2) full attention cannot run the 512k decode cell —
# documented skip (DESIGN.md §Arch-applicability).
SUBQUADRATIC = ("recurrentgemma_2b", "rwkv6_3b")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def cell_applicable(cfg: ModelConfig, cell: str) -> Tuple[bool, str]:
    if cell == "long_500k" and cfg.attention == "global" and cfg.family != "ssm":
        return False, "full attention is O(S^2); 512k decode out of scope"
    return True, ""


def family_module(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec
    if cfg.family == "vlm":
        return vlm
    return transformer


def init_params(rng, cfg: ModelConfig):
    return family_module(cfg).init_params(rng, cfg)


def param_axes(cfg: ModelConfig):
    return family_module(cfg).param_axes(cfg)


def loss_fn(cfg: ModelConfig, params, batch):
    return family_module(cfg).loss_fn(cfg, params, batch)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; shardable, no device allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_spec(cfg: ModelConfig, batch: int, seq: int):
    """The per-step training batch pytree spec."""
    if cfg.is_encoder_decoder:
        st = max(seq // 8, 16)
        return {
            "frames": _sds((batch, seq, cfg.d_model), jnp.dtype(cfg.dtype)),
            "tokens": _sds((batch, st), jnp.int32),
            "labels": _sds((batch, st), jnp.int32),
        }
    if cfg.family == "vlm":
        nf = max(min(cfg.num_frontend_tokens, seq // 2), 1)
        st = seq - nf
        return {
            "embeds": _sds((batch, nf, cfg.d_model), jnp.dtype(cfg.dtype)),
            "tokens": _sds((batch, st), jnp.int32),
            "labels": _sds((batch, st), jnp.int32),
        }
    return {
        "tokens": _sds((batch, seq), jnp.int32),
        "labels": _sds((batch, seq), jnp.int32),
    }


def batch_axes(cfg: ModelConfig):
    """Logical axes for the training batch."""
    if cfg.is_encoder_decoder:
        return {
            "frames": ("batch", "seq", "embed"),
            "tokens": ("batch", "seq"),
            "labels": ("batch", "seq"),
        }
    if cfg.family == "vlm":
        return {
            "embeds": ("batch", "seq", "embed"),
            "tokens": ("batch", "seq"),
            "labels": ("batch", "seq"),
        }
    return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}


def make_concrete_batch(cfg: ModelConfig, batch: int, seq: int, rng=None):
    """Small concrete batch for smoke tests / CPU training."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    spec = train_batch_spec(cfg, batch, seq)
    out = {}
    for k, s in spec.items():
        kr, rng = jax.random.split(rng)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[k] = jax.random.randint(kr, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            out[k] = jax.random.normal(kr, s.shape, jnp.float32).astype(s.dtype)
    return out


# ---------------------------------------------------------------------------
# serve-step builders
# ---------------------------------------------------------------------------


def make_prefill_fn(cfg: ModelConfig, *, max_len: Optional[int] = None):
    """``max_len`` sizes the returned KV caches for subsequent decode steps
    (default: the prompt length, the lower-only historical behavior)."""
    mod = family_module(cfg)
    kw = {} if max_len is None else {"max_len": max_len}

    if cfg.is_encoder_decoder:

        def prefill_fn(params, batch):
            logits, caches, memkv = mod.prefill(
                cfg, params, batch["frames"], batch["tokens"], **kw
            )
            return logits, caches

        return prefill_fn

    if cfg.family == "vlm":

        def prefill_fn(params, batch):
            return mod.prefill(
                cfg, params, batch["tokens"], embeds=batch["embeds"], **kw
            )

        return prefill_fn

    def prefill_fn(params, batch):
        return mod.prefill(cfg, params, batch["tokens"], **kw)

    return prefill_fn


def make_decode_fn(cfg: ModelConfig):
    mod = family_module(cfg)

    if cfg.is_encoder_decoder:

        def decode_fn(params, token, caches, memory_kv):
            return mod.decode_step(cfg, params, token, caches, memory_kv)

        return decode_fn

    def decode_fn(params, token, caches):
        return mod.decode_step(cfg, params, token, caches)

    return decode_fn


# ---------------------------------------------------------------------------
# serve slot-pool metadata (continuous-batching runtime)
# ---------------------------------------------------------------------------

POS_LEAF = -1  # sentinel: leaf has no batch axis (e.g. attention "pos")


def _axis_tuple_leaf(v):
    return isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v
    )


def cache_batch_dims(cfg: ModelConfig):
    """Per-leaf batch-axis index for the decode-cache pytree.

    Mirrors the ``init_caches`` structure. Each leaf is the index of the axis
    that carries requests ("batch"/"kv_batch" in ``cache_axes``), or
    ``POS_LEAF`` (-1) for leaves with no batch axis (scalar positions). The
    serve slot pool uses this to (a) give pos-like leaves a leading slot axis
    and (b) drive per-slot ``vmap`` in/out axes — the same metadata covers
    the whole decoder zoo (attention KV, RG-LRU state, RWKV wkv state).
    """
    if cfg.is_encoder_decoder:
        raise ValueError("slot pools support decoder-only models")
    axes = family_module(cfg).cache_axes(cfg)

    def leaf_dim(ax):
        for i, name in enumerate(ax):
            if name in ("batch", "kv_batch"):
                return i
        return POS_LEAF

    return jax.tree_util.tree_map(leaf_dim, axes, is_leaf=_axis_tuple_leaf)


def slot_vmap_axes(cfg: ModelConfig):
    """``vmap`` in/out axes over the slot pool (the slot axis per leaf)."""
    return jax.tree_util.tree_map(
        lambda d: 0 if d == POS_LEAF else d, cache_batch_dims(cfg)
    )


def init_slot_pool(cfg: ModelConfig, slots: int, max_len: int):
    """Allocate the serve cache pool: one fixed buffer set shared by all
    slots, updated in place via donation for the life of the server.

    Batch-bearing leaves carry ``slots`` on their batch axis; pos-like
    leaves gain a leading ``(slots,)`` axis so every slot tracks its own
    position. Attention caches use the no-ring layout (size == ``max_len``,
    slot index == absolute position) that chunked prefill requires.
    """
    caches = family_module(cfg).init_caches(cfg, slots, max_len, ring=False)
    return jax.tree_util.tree_map(
        lambda leaf, d: leaf
        if d != POS_LEAF
        else jnp.zeros((slots,) + leaf.shape, leaf.dtype),
        caches,
        cache_batch_dims(cfg),
    )


def slot_pool_bytes(cfg: ModelConfig, slots: int, max_len: int) -> int:
    """Device bytes the slot pool pins (for admission-control sizing)."""
    pool = jax.eval_shape(lambda: init_slot_pool(cfg, slots, max_len))
    return sum(
        l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(pool)
    )


def make_chunk_prefill_fn(cfg: ModelConfig):
    """Chunked-prefill step for the serve runtime.

    ``chunk_fn(params, tokens (B, C), caches, pos0)`` -> (last_logits,
    caches); continues pre-allocated no-ring caches from absolute position
    ``pos0``. Token-only decoder models (the serve runtime's scope).
    """
    if cfg.is_encoder_decoder or cfg.family == "vlm":
        raise ValueError(
            "chunked prefill supports token-only decoder models; "
            f"{cfg.name} is {cfg.family}"
        )

    def chunk_fn(params, tokens, caches, pos0):
        return transformer.chunk_prefill(cfg, params, tokens, caches, pos0)

    return chunk_fn


def decode_state_spec(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct pytree for the decode-time state (KV caches etc.)."""
    mod = family_module(cfg)
    caches = jax.eval_shape(lambda: mod.init_caches(cfg, batch, max_len))
    extras = {}
    if cfg.is_encoder_decoder:
        mem_len = max_len
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        kv = _sds(
            (cfg.num_layers, batch, mem_len, hkv, hd), jnp.dtype(cfg.dtype)
        )
        extras["memory_kv"] = (kv, kv)
    return caches, extras


def prefill_spec(cfg: ModelConfig, batch: int, seq: int):
    return train_batch_spec(cfg, batch, seq)


def decode_token_spec(cfg: ModelConfig, batch: int):
    return _sds((batch, 1), jnp.int32)
