"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech/text frontend is a STUB per the task card: ``input_specs()``
supplies precomputed frame embeddings (B, S_frames, D) for the encoder. The
decoder is a standard causal transformer with cross-attention into the
encoder memory.

Serving: ``encode`` runs once per request; ``prefill``/``decode_step`` manage
the decoder's self-attention KV cache plus per-layer precomputed cross K/V.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import attention, common, mlp
from .partitioning import with_logical_constraint


def _enc_block_init(rng, cfg):
    ks = jax.random.split(rng, 2)
    d, dt = cfg.d_model, cfg.jnp_dtype
    return {
        "ln1": common.rmsnorm_init(d, dt),
        "attn": attention.init_params(ks[0], cfg),
        "ln2": common.rmsnorm_init(d, dt),
        "mlp": mlp.init_params(ks[1], cfg),
    }


def _dec_block_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    d, dt = cfg.d_model, cfg.jnp_dtype
    return {
        "ln1": common.rmsnorm_init(d, dt),
        "self_attn": attention.init_params(ks[0], cfg),
        "ln_x": common.rmsnorm_init(d, dt),
        "cross_attn": attention.init_params(ks[1], cfg, cross=True),
        "ln2": common.rmsnorm_init(d, dt),
        "mlp": mlp.init_params(ks[2], cfg),
    }


def init_params(rng, cfg):
    ks = jax.random.split(rng, 4)
    pv = -(-cfg.vocab_size // 512) * 512
    enc_rngs = jax.random.split(ks[0], cfg.encoder_layers)
    dec_rngs = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": common.embedding_init(ks[2], pv, cfg.d_model, cfg.jnp_dtype),
        "enc_layers": jax.vmap(lambda r: _enc_block_init(r, cfg))(enc_rngs),
        "dec_layers": jax.vmap(lambda r: _dec_block_init(r, cfg))(dec_rngs),
        "enc_ln": common.rmsnorm_init(cfg.d_model, cfg.jnp_dtype),
        "final_ln": common.rmsnorm_init(cfg.d_model, cfg.jnp_dtype),
        "lm_head": {
            "w": common.normal_init(ks[3], (cfg.d_model, pv), cfg.jnp_dtype)
        },
    }


def param_axes(cfg):
    def stack(ax):
        return jax.tree_util.tree_map(
            lambda a: ("layers",) + a,
            ax,
            is_leaf=lambda v: isinstance(v, tuple)
            and all(isinstance(e, (str, type(None))) for e in v),
        )

    attn_ax = attention.param_axes(cfg)
    enc = {
        "ln1": {"scale": (None,)},
        "attn": attn_ax,
        "ln2": {"scale": (None,)},
        "mlp": mlp.param_axes(cfg),
    }
    dec = {
        "ln1": {"scale": (None,)},
        "self_attn": attn_ax,
        "ln_x": {"scale": (None,)},
        "cross_attn": attention.param_axes(cfg, cross=True),
        "ln2": {"scale": (None,)},
        "mlp": mlp.param_axes(cfg),
    }
    return {
        "embed": {"table": ("p_vocab", "p_fsdp")},
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_ln": {"scale": (None,)},
        "final_ln": {"scale": (None,)},
        "lm_head": {"w": ("p_fsdp", "p_vocab")},
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def encode(cfg, params, frames):
    """frames: (B, S, D) stub frontend embeddings -> encoder memory (B, S, D)."""
    x = frames.astype(cfg.jnp_dtype)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = with_logical_constraint(x, ("batch", "seq", "embed"))

    def body(h, layer_p):
        hn = common.rmsnorm_apply(layer_p["ln1"], h, cfg.norm_eps)
        q, k, v = attention.qkv(cfg, layer_p["attn"], hn, positions)
        a = attention.self_attention(cfg, q, k, v, causal=False, window=0)
        h = h + attention.out_proj(layer_p["attn"], a)
        hn = common.rmsnorm_apply(layer_p["ln2"], h, cfg.norm_eps)
        h = h + mlp.apply(cfg, layer_p["mlp"], hn)
        h = with_logical_constraint(h, ("batch", "seq", "embed"))
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return common.rmsnorm_apply(params["enc_ln"], x, cfg.norm_eps)


def encode_memory_kv(cfg, params, memory):
    """Precompute per-decoder-layer cross K/V: (L, B, Sm, Hkv, hd)."""

    def per_layer(layer_p):
        ca = layer_p["cross_attn"]
        k = attention._proj(memory, ca["wk"], ca.get("bk"))
        v = attention._proj(memory, ca["wv"], ca.get("bv"))
        return k, v

    return jax.vmap(per_layer)(params["dec_layers"])


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------


def _dec_block(cfg, layer_p, x, positions, memory_kv, mode, cache):
    mk, mv = memory_kv
    new_cache = cache
    h = common.rmsnorm_apply(layer_p["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        a, new_cache = attention.decode_attention(cfg, layer_p["self_attn"], h, cache)
        x = x + a
    else:
        q, k, v = attention.qkv(cfg, layer_p["self_attn"], h, positions)
        a = attention.self_attention(cfg, q, k, v, causal=True, window=0)
        x = x + attention.out_proj(layer_p["self_attn"], a)
        if mode == "prefill":
            new_cache = attention.fill_cache(cache, k, v)
    hx = common.rmsnorm_apply(layer_p["ln_x"], x, cfg.norm_eps)
    x = x + attention.cross_attention(cfg, layer_p["cross_attn"], hx, mk, mv)
    h2 = common.rmsnorm_apply(layer_p["ln2"], x, cfg.norm_eps)
    x = x + mlp.apply(cfg, layer_p["mlp"], h2)
    return with_logical_constraint(x, ("batch", "seq", "embed")), new_cache


def decode_stack(cfg, params, tokens, memory, *, mode="train", caches=None,
                 memory_kv=None):
    x = common.embedding_lookup(params["embed"], tokens)
    b, s = x.shape[:2]
    if mode == "decode":
        # position comes from the cache
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if memory_kv is None:
        memory_kv = encode_memory_kv(cfg, params, memory)

    if mode == "train":

        def body(h, scanned):
            layer_p, mkv = scanned
            h, _ = _dec_block(cfg, layer_p, h, positions, mkv, "train", None)
            return h, None

        x, _ = jax.lax.scan(body, x, (params["dec_layers"], memory_kv))
        new_caches = None
    else:

        def body(h, scanned):
            layer_p, mkv, cache = scanned
            h, nc = _dec_block(cfg, layer_p, h, positions, mkv, mode, cache)
            return h, nc

        x, new_caches = jax.lax.scan(
            body, x, (params["dec_layers"], memory_kv, caches)
        )

    x = common.rmsnorm_apply(params["final_ln"], x, cfg.norm_eps)
    logits = jax.lax.dot_general(
        x, params["lm_head"]["w"], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return with_logical_constraint(logits, ("batch", "seq", "vocab")), new_caches


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------


def loss_fn(cfg, params, batch):
    """batch: {frames (B,Sf,D), tokens (B,St), labels (B,St)}."""
    memory = encode(cfg, params, batch["frames"])
    logits, _ = decode_stack(cfg, params, batch["tokens"], memory, mode="train")
    return common.softmax_cross_entropy(logits, batch["labels"], batch.get("mask"))


def init_caches(cfg, batch: int, max_len: int):
    one = attention.init_cache(cfg, batch, max_len)
    return jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (cfg.num_layers,) + l.shape), one
    )


def prefill(cfg, params, frames, tokens, *, max_len=None):
    memory = encode(cfg, params, frames)
    memory_kv = encode_memory_kv(cfg, params, memory)
    b, s = tokens.shape
    max_len = max_len or s
    caches = init_caches(cfg, b, max_len)
    logits, caches = decode_stack(
        cfg, params, tokens, memory, mode="prefill", caches=caches,
        memory_kv=memory_kv,
    )
    return logits[:, -1], caches, memory_kv


def decode_step(cfg, params, token, caches, memory_kv):
    logits, caches = decode_stack(
        cfg, params, token, None, mode="decode", caches=caches,
        memory_kv=memory_kv,
    )
    return logits[:, -1], caches
