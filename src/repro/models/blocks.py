"""Composable transformer / recurrent / RWKV blocks with a uniform interface.

``block_apply(cfg, kind, params, x, positions, mode, cache)`` where

 * ``kind``  ∈ {"attention", "recurrent", "rwkv"}
 * ``mode``  ∈ {"train", "prefill", "decode", "chunk"}

"chunk" is chunked prefill for the serve runtime: like "prefill" but it
*continues* an existing cache/state (no-ring attention layout, recurrent
state threading) instead of filling a fresh one.
 * ``cache`` is the block's decode state (KV cache / LRU state / WKV state)

Returns ``(x_out, aux_loss, new_cache)``. ``aux_loss`` is nonzero only for
MoE blocks (load-balancing loss).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import attention, common, mlp, moe, rglru, rwkv
from .partitioning import with_logical_constraint


def block_init(rng, cfg, kind: str = "attention"):
    ks = jax.random.split(rng, 4)
    d, dt = cfg.d_model, cfg.jnp_dtype
    p = {"ln1": common.rmsnorm_init(d, dt), "ln2": common.rmsnorm_init(d, dt)}
    if kind == "attention":
        p["attn"] = attention.init_params(ks[0], cfg)
    elif kind == "recurrent":
        p["rec"] = rglru.init_params(ks[0], cfg)
    elif kind == "rwkv":
        p["tm"] = rwkv.init_params(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "rwkv":
        if cfg.family == "moe":
            p["moe"] = moe.init_params(ks[1], cfg)
        else:
            p["mlp"] = mlp.init_params(ks[1], cfg)
    return p


def block_axes(cfg, kind: str = "attention"):
    ax = {"ln1": {"scale": (None,)}, "ln2": {"scale": (None,)}}
    if kind == "attention":
        ax["attn"] = attention.param_axes(cfg)
    elif kind == "recurrent":
        ax["rec"] = rglru.param_axes(cfg)
    elif kind == "rwkv":
        ax["tm"] = rwkv.param_axes(cfg)
    if kind != "rwkv":
        if cfg.family == "moe":
            ax["moe"] = moe.param_axes(cfg)
        else:
            ax["mlp"] = mlp.param_axes(cfg)
    return ax


def block_cache_init(cfg, kind: str, batch: int, max_len: int, *,
                     ring: bool = True):
    """``ring=False`` builds the no-ring (slot == absolute position) layout
    chunked prefill requires — the serve slot pool's layout."""
    if kind == "attention":
        window = cfg.window_size if cfg.attention == "local" else None
        return attention.init_cache(cfg, batch, max_len, window=window,
                                    ring=ring)
    if kind == "recurrent":
        return rglru.init_state(cfg, batch)
    if kind == "rwkv":
        return rwkv.init_state(cfg, batch)
    raise ValueError(kind)


def block_cache_axes(cfg, kind: str):
    if kind == "attention":
        return attention.cache_axes(cfg)
    if kind == "recurrent":
        return rglru.state_axes()
    if kind == "rwkv":
        return rwkv.state_axes()
    raise ValueError(kind)


def _ffn(cfg, p, x):
    if "moe" in p:
        return moe.apply(cfg, p["moe"], x)
    return mlp.apply(cfg, p["mlp"], x), jnp.zeros((), jnp.float32)


def block_apply(
    cfg,
    kind: str,
    p,
    x,
    positions,
    *,
    mode: str = "train",
    cache=None,
):
    window = cfg.window_size if (cfg.attention == "local" and kind == "attention") else 0
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    h = common.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)

    if kind == "attention":
        ap = p["attn"]
        if mode == "decode":
            attn_out, new_cache = attention.decode_attention(
                cfg, ap, h, cache, window=window
            )
        elif mode == "chunk":
            attn_out, new_cache = attention.chunk_attention(
                cfg, ap, h, cache, positions, window=window
            )
        else:
            q, k, v = attention.qkv(cfg, ap, h, positions)
            attn_out = attention.self_attention(
                cfg, q, k, v, causal=True, window=window
            )
            attn_out = attention.out_proj(ap, attn_out)
            if mode == "prefill":
                new_cache = attention.fill_cache(cache, k, v, window=window)
        x = x + attn_out
        x = with_logical_constraint(x, ("batch", "seq", "embed"))
        h2 = common.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        ffn_out, aux = _ffn(cfg, p, h2)
        x = x + ffn_out

    elif kind == "recurrent":
        rp = p["rec"]
        if mode == "decode":
            rec_out, new_cache = rglru.decode_step(cfg, rp, h, cache)
        elif mode == "chunk":
            rec_out, new_cache = rglru.prefill(cfg, rp, h, state=cache)
        elif mode == "prefill":
            rec_out, new_cache = rglru.prefill(cfg, rp, h)
        else:
            rec_out = rglru.apply(cfg, rp, h)
        x = x + rec_out
        x = with_logical_constraint(x, ("batch", "seq", "embed"))
        h2 = common.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        ffn_out, aux = _ffn(cfg, p, h2)
        x = x + ffn_out

    elif kind == "rwkv":
        tp = p["tm"]
        if mode in ("decode", "prefill", "chunk"):
            tm_out, (tm_shift, wkv_state) = rwkv.time_mix(
                cfg,
                tp,
                h,
                shift_state=cache["tm_shift"],
                wkv_state=cache["wkv"],
                chunked=(mode != "decode"),
            )
            x = x + tm_out
            h2 = common.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
            cm_out, cm_shift = rwkv.channel_mix(
                cfg, tp, h2, shift_state=cache["cm_shift"]
            )
            x = x + cm_out
            new_cache = {
                "tm_shift": tm_shift,
                "cm_shift": cm_shift,
                "wkv": wkv_state,
            }
        else:
            tm_out, _ = rwkv.time_mix(cfg, tp, h, chunked=True)
            x = x + tm_out
            x = with_logical_constraint(x, ("batch", "seq", "embed"))
            h2 = common.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
            cm_out, _ = rwkv.channel_mix(cfg, tp, h2)
            x = x + cm_out
    else:
        raise ValueError(kind)

    x = with_logical_constraint(x, ("batch", "seq", "embed"))
    return x, aux, new_cache


def layer_kinds(cfg):
    """Per-layer block kinds for this config."""
    if cfg.family == "ssm":
        return ["rwkv"] * cfg.num_layers
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        return [pat[i % len(pat)] for i in range(cfg.num_layers)]
    return ["attention"] * cfg.num_layers
