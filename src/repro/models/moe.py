"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

GShard/Switch-style einsum dispatch, adapted for TPU:

 * tokens are grouped by their data-parallel shard (``G`` groups), so the
   dispatch/combine tensors are sharded over (data: G, model: E) and never
   materialize globally;
 * experts shard over the ``model`` mesh axis (expert parallelism); the
   dispatch einsum induces the all-to-all;
 * router runs in fp32 with jitter-free deterministic top-k (inference safe).

The load-balancing auxiliary loss follows Shazeer et al. / GShard.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import common
from .partitioning import with_logical_constraint


def init_params(rng, cfg):
    d, e, dt = cfg.d_model, cfg.num_experts, cfg.jnp_dtype
    ks = jax.random.split(rng, 4)
    return {
        "router": common.normal_init(ks[0], (d, e), jnp.float32, stddev=0.02),
        "wi": common.normal_init(ks[1], (e, d, cfg.d_ff), dt),
        "wg": common.normal_init(ks[2], (e, d, cfg.d_ff), dt),
        "wo": common.normal_init(ks[3], (e, cfg.d_ff, d), dt),
    }


def param_axes(cfg):
    return {
        "router": ("p_fsdp", None),
        "wi": ("p_experts", "p_fsdp", None),
        "wg": ("p_experts", "p_fsdp", None),
        "wo": ("p_experts", None, "p_fsdp"),
    }


def _combine(cfg, eout, combine, out_shape):
    """Expert-combine contraction over the (model-sharded) expert dim.

    With ``tp_comm == "int8"`` the cross-shard partial-sum reduction rides
    int8 all-gather (see repro.models.tpcomm) — forward-only steps.
    """
    from . import tpcomm
    from .partitioning import current_mesh, resolve_axis

    b, s, d = out_shape
    if (
        cfg.tp_comm == "int8"
        and current_mesh() is not None
        and resolve_axis("experts", eout.shape[1]) == "model"
    ):
        import jax
        from jax.sharding import PartitionSpec as P

        from repro import compat

        mesh = current_mesh()
        from repro.launch.mesh import REPLICA_AXES

        batch = tuple(a for a in REPLICA_AXES if a in mesh.axis_names)
        bspec = batch if len(batch) > 1 else (batch[0] if batch else None)

        def local(eo, cm):
            part = jnp.einsum(
                "gecd,gtec->gtd", eo, cm, preferred_element_type=jnp.float32
            )
            q, sc = tpcomm._quant_rows(part)
            qg = jax.lax.all_gather(q, "model")
            sg = jax.lax.all_gather(sc, "model")
            out = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
            return out.astype(eo.dtype)

        fn = compat.shard_map(
            local,
            mesh=mesh,
            in_specs=(P(bspec, "model", None, None),
                      P(bspec, None, "model", None)),
            out_specs=P(bspec, None, None),
            check=False,
        )
        return fn(eout, combine).reshape(b, s, d)
    out = jnp.einsum("gecd,gtec->gtd", eout, combine)
    return out.reshape(b, s, d)


def _top_k_mask(gates, k):
    """gates: (..., E) -> (mask (..., E, k), weights (..., E, k))."""
    vals, idx = jax.lax.top_k(gates, k)  # (..., k)
    onehot = jax.nn.one_hot(idx, gates.shape[-1], dtype=gates.dtype)  # (...,k,E)
    return onehot, vals


def _group_size(total_tokens: int, target: int = 512) -> int:
    """Largest divisor of total_tokens that is <= target (static)."""
    gs = min(target, total_tokens)
    while total_tokens % gs != 0:
        gs -= 1
    return gs


def apply(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Tokens are re-grouped into fixed-size routing groups (GShard-style) so the
    dispatch/combine tensors are O(tokens · gs · k · cf) — bounded per device
    regardless of sequence length — instead of O(tokens · S · k · cf).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    act = common.activation(cfg.act)

    total = b * s
    gs = _group_size(total)
    ng = total // gs
    xg = x.reshape(ng, gs, d)
    xg = with_logical_constraint(xg, ("batch", None, "embed"))

    # ---- routing (fp32) ----
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    gates = jax.nn.softmax(logits, axis=-1)  # (G, T, E)
    onehot, topv = _top_k_mask(gates, k)  # (G,T,k,E), (G,T,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # ---- capacity assignment (per group) ----
    cap = max(int(cfg.capacity_factor * gs * k / e), 1)
    flat = onehot.reshape(ng, gs * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat) * flat  # (G, T*k, E)
    keep = (pos_in_expert < cap) & (flat > 0)
    pos = pos_in_expert.astype(jnp.int32)

    cap_onehot = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=x.dtype)
    dispatch = (keep.astype(x.dtype))[..., None] * cap_onehot  # (G,T*k,E,C)
    dispatch = dispatch.reshape(ng, gs, k, e, cap)
    combine = dispatch * topv[..., None, None].astype(x.dtype)
    dispatch = dispatch.sum(2)  # (G, T, E, C)
    combine = combine.sum(2)
    dispatch = with_logical_constraint(dispatch, ("batch", None, "experts", None))
    combine = with_logical_constraint(combine, ("batch", None, "experts", None))

    # ---- expert computation (all-to-all induced by sharding) ----
    xin = jnp.einsum("gtd,gtec->gecd", xg, dispatch)  # (G, E, C, D)
    xin = with_logical_constraint(xin, ("batch", "experts", None, None))
    h = jnp.einsum("gecd,edf->gecf", xin, p["wi"], preferred_element_type=jnp.float32)
    g = jnp.einsum("gecd,edf->gecf", xin, p["wg"], preferred_element_type=jnp.float32)
    h = (act(g) * h).astype(x.dtype)
    eout = jnp.einsum("gecf,efd->gecd", h, p["wo"], preferred_element_type=jnp.float32)
    eout = eout.astype(x.dtype)
    eout = with_logical_constraint(eout, ("batch", "experts", None, None))
    out = _combine(cfg, eout, combine, (b, s, d))
    out = with_logical_constraint(out, ("batch", "seq", "embed"))

    # ---- load-balance aux loss (GShard eq. 4) ----
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))  # (E,) fraction routed
    frac_gates = jnp.mean(gates, axis=(0, 1))  # (E,)
    aux = e * jnp.sum(frac_tokens * frac_gates) / k
    return out, aux.astype(jnp.float32)
