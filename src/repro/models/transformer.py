"""Decoder-only language model over the block zoo.

Uniform-stack configs use ``lax.scan`` over layer-stacked parameters (compact
HLO, fast compiles, remat-friendly) — the production pattern for 90+-layer
models. Mixed-kind stacks (hybrid RG patterns) scan over each kind-group with
interleaving handled by a Python loop over the (short) repeating pattern.

Also supports ``embeds`` inputs (VLM / audio frontends inject precomputed
patch/frame embeddings).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks, common
from .partitioning import with_logical_constraint


def padded_vocab(cfg) -> int:
    return -(-cfg.vocab_size // 512) * 512


def _uniform(cfg) -> bool:
    return len(set(blocks.layer_kinds(cfg))) == 1 and cfg.scan_layers


def init_params(rng, cfg):
    kinds = blocks.layer_kinds(cfg)
    ks = jax.random.split(rng, 3)
    pv = padded_vocab(cfg)
    params: Dict[str, Any] = {
        "embed": common.embedding_init(ks[0], pv, cfg.d_model, cfg.jnp_dtype),
        "final_ln": common.rmsnorm_init(cfg.d_model, cfg.jnp_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": common.normal_init(ks[1], (cfg.d_model, pv), cfg.jnp_dtype)
        }
    if _uniform(cfg):
        kind = kinds[0]
        layer_rngs = jax.random.split(ks[2], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda r: blocks.block_init(r, cfg, kind)
        )(layer_rngs)
    else:
        layer_rngs = jax.random.split(ks[2], cfg.num_layers)
        params["layers"] = [
            blocks.block_init(layer_rngs[i], cfg, kinds[i])
            for i in range(cfg.num_layers)
        ]
    return params


def param_axes(cfg):
    kinds = blocks.layer_kinds(cfg)
    axes: Dict[str, Any] = {
        "embed": {"table": ("p_vocab", "p_fsdp")},
        "final_ln": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = {"w": ("p_fsdp", "p_vocab")}
    if _uniform(cfg):
        base = blocks.block_axes(cfg, kinds[0])
        # prepend the stacked-layers axis to every leaf
        axes["layers"] = jax.tree_util.tree_map(
            lambda ax: ("layers",) + ax,
            base,
            is_leaf=lambda v: isinstance(v, tuple)
            and all(isinstance(e, (str, type(None))) for e in v),
        )
    else:
        axes["layers"] = [blocks.block_axes(cfg, k) for k in kinds]
    return axes


def _maybe_remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(cfg.remat)


def backbone(cfg, params, x, positions, *, mode="train", caches=None):
    """Run the layer stack. x: (B, S, D). Returns (x, aux, new_caches)."""
    kinds = blocks.layer_kinds(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if _uniform(cfg):
        kind = kinds[0]

        if mode == "train":

            def body(carry, layer_p):
                h, aux = carry
                h, a, _ = blocks.block_apply(
                    cfg, kind, layer_p, h, positions, mode="train"
                )
                return (h, aux + a), None

            body = _maybe_remat(cfg, body)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])
            return x, aux_total, None

        def body(carry, scanned):
            h, aux = carry
            layer_p, cache = scanned
            h, a, new_cache = blocks.block_apply(
                cfg, kind, layer_p, h, positions, mode=mode, cache=cache
            )
            return (h, aux + a), new_cache

        (x, aux_total), new_caches = jax.lax.scan(
            body, (x, aux_total), (params["layers"], caches)
        )
        return x, aux_total, new_caches

    # --- non-uniform (hybrid) stack: python loop ---
    new_caches = []
    for i, kind in enumerate(kinds):
        cache = None if caches is None else caches[i]
        if mode == "train" and cfg.remat != "none":
            fn = _maybe_remat(
                cfg,
                lambda p_, x_, kind_=kind: blocks.block_apply(
                    cfg, kind_, p_, x_, positions, mode="train"
                ),
            )
            x, a, nc = fn(params["layers"][i], x)
        else:
            x, a, nc = blocks.block_apply(
                cfg, kind, params["layers"][i], x, positions, mode=mode,
                cache=cache,
            )
        aux_total = aux_total + a
        new_caches.append(nc)
    return x, aux_total, (new_caches if mode != "train" else None)


def _logits(cfg, params, x):
    x = common.rmsnorm_apply(params["final_ln"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = common.embedding_logits(params["embed"], x)
    else:
        logits = jax.lax.dot_general(
            x, params["lm_head"]["w"], (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    return with_logical_constraint(logits, ("batch", "seq", "vocab"))


def forward(cfg, params, tokens=None, *, embeds=None, positions=None, mode="train",
            caches=None):
    """tokens: (B, S) int32 or embeds: (B, S, D). Returns (logits, aux, caches)."""
    if embeds is None:
        x = common.embedding_lookup(params["embed"], tokens)
    else:
        x = embeds.astype(cfg.jnp_dtype)
        if tokens is not None:  # VLM: prepend frontend embeddings to text
            tx = common.embedding_lookup(params["embed"], tokens)
            x = jnp.concatenate([x, tx], axis=1)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = with_logical_constraint(x, ("batch", "seq", "embed"))
    x, aux, new_caches = backbone(
        cfg, params, x, positions, mode=mode, caches=caches
    )
    return _logits(cfg, params, x), aux, new_caches


def loss_fn(cfg, params, batch):
    """batch: {tokens, labels, [embeds], [mask]} -> scalar loss."""
    logits, aux, _ = forward(
        cfg,
        params,
        batch.get("tokens"),
        embeds=batch.get("embeds"),
        mode="train",
    )
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # VLM: loss only on text tail
        logits = logits[:, -labels.shape[1]:]
    loss = common.softmax_cross_entropy(logits, labels, batch.get("mask"))
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_caches(cfg, batch: int, max_len: int, *, ring: bool = True):
    kinds = blocks.layer_kinds(cfg)
    if _uniform(cfg):
        one = blocks.block_cache_init(cfg, kinds[0], batch, max_len, ring=ring)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (cfg.num_layers,) + l.shape), one
        )
    return [
        blocks.block_cache_init(cfg, k, batch, max_len, ring=ring) for k in kinds
    ]


def cache_axes(cfg):
    kinds = blocks.layer_kinds(cfg)
    if _uniform(cfg):
        base = blocks.block_cache_axes(cfg, kinds[0])
        return jax.tree_util.tree_map(
            lambda ax: ("layers",) + ax,
            base,
            is_leaf=lambda v: isinstance(v, tuple)
            and all(isinstance(e, (str, type(None))) for e in v),
        )
    return [blocks.block_cache_axes(cfg, k) for k in kinds]


def prefill(cfg, params, tokens=None, *, embeds=None, max_len=None):
    """Process a prompt, returning (last_logits, caches)."""
    if tokens is not None:
        s = tokens.shape[1]
        b = tokens.shape[0]
    else:
        s = embeds.shape[1]
        b = embeds.shape[0]
    if embeds is not None and tokens is not None:
        s = s + embeds.shape[1]
    max_len = max_len or s
    caches = init_caches(cfg, b, max_len)
    logits, _, caches = forward(
        cfg, params, tokens, embeds=embeds, mode="prefill", caches=caches
    )
    return logits[:, -1], caches


def decode_step(cfg, params, token, caches):
    """token: (B, 1) int32. Returns (logits (B, V), new_caches)."""
    logits, _, caches = forward(
        cfg, params, token, mode="decode", caches=caches
    )
    return logits[:, -1], caches


def chunk_prefill(cfg, params, tokens, caches, pos0):
    """Process one prompt chunk against pre-allocated no-ring caches.

    tokens: (B, C) int32, caches from ``init_caches(..., ring=False)`` (or a
    previous chunk's output), pos0: () int32 — absolute position of the
    chunk's first token. Returns (last_logits (B, V), new_caches). Attention
    caches must use the no-ring layout (slot == absolute position); recurrent
    and RWKV states continue across the chunk boundary natively.
    """
    b, c = tokens.shape
    positions = pos0 + jnp.broadcast_to(jnp.arange(c), (b, c))
    logits, _, caches = forward(
        cfg, params, tokens, positions=positions, mode="chunk", caches=caches
    )
    return logits[:, -1], caches
