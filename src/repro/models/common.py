"""Shared model components: initializers, norms, embeddings, rotary, dense.

Pure-JAX module style: every layer is an ``init(rng, ...) -> params`` plus an
``apply(params, x, ...) -> y`` pair, with params as nested dicts of arrays.
No flax dependency — parameters are plain pytrees, which keeps them directly
compatible with DrJAX partitioned structures (a partitioned model is simply
the same pytree with a leading group axis on every leaf).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def normal_init(rng, shape, dtype, stddev: Optional[float] = None):
    if stddev is None:
        fan_in = shape[0] if len(shape) >= 1 else 1
        stddev = 1.0 / math.sqrt(max(fan_in, 1))
    return (stddev * jax.random.normal(rng, shape)).astype(dtype)


def zeros_init(_rng, shape, dtype):
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# dense / einsum layers
# ---------------------------------------------------------------------------


def dense_init(rng, in_dim: int, out_shape, dtype, use_bias=False):
    """General projection: (in_dim,) -> out_shape (possibly multi-dim)."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    w = normal_init(rng, (in_dim, *out_shape), dtype)
    p = {"w": w}
    if use_bias:
        p["b"] = jnp.zeros(out_shape, dtype)
    return p


def dense_apply(p, x):
    """x: (..., in_dim) @ w: (in_dim, *out) -> (..., *out)."""
    w = p["w"]
    out = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    if "b" in p:
        out = out + p["b"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def embedding_init(rng, vocab: int, d: int, dtype):
    return {"table": normal_init(rng, (vocab, d), dtype, stddev=1.0)}


def embedding_lookup(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def embedding_logits(p, x):
    """Tied-readout logits."""
    return jax.lax.dot_general(
        x, p["table"], (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None):
    """logits: (..., V) f32; labels: (...) int32. Mean over unmasked tokens."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
