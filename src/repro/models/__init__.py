"""Pure-JAX model zoo for the assigned architectures."""

from .config import ModelConfig
from . import registry

__all__ = ["ModelConfig", "registry"]
