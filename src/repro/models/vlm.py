"""VLM (llava-next) backbone: decoder-only LM consuming anyres patch embeds.

The vision tower + anyres tiling is a STUB per the task card:
``input_specs()`` provides precomputed patch embeddings (B, n_patches, D)
which are prepended to the text-token embeddings; loss applies to text
positions only (handled in ``transformer.loss_fn``).
"""

from __future__ import annotations

from . import transformer

init_params = transformer.init_params
param_axes = transformer.param_axes
forward = transformer.forward
loss_fn = transformer.loss_fn
prefill = transformer.prefill
decode_step = transformer.decode_step
init_caches = transformer.init_caches
cache_axes = transformer.cache_axes
