"""Minimal optax-style optimizers as (init, update) pairs over pytrees.

``update(grads, state, params) -> (updates, state)`` where ``updates`` are
*deltas* to be added to params (optax sign convention: already negated).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), tree), norm


def sgd(lr: Schedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
            )
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mu"],
                grads,
            )
            if nesterov:
                upd = jax.tree_util.tree_map(
                    lambda m, g: momentum * m + g.astype(jnp.float32), mu, grads
                )
            else:
                upd = mu
            new_state = {"step": step, "mu": mu}
        else:
            upd = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
            new_state = {"step": step}
        updates = jax.tree_util.tree_map(lambda u: -lr_t * u, upd)
        return updates, new_state

    return Optimizer(init, update)


def adamw(
    lr: Schedule,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    """AdamW with fp32 moments (params may be bf16 — standard mixed precision)."""

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )

        def upd(m_, v_, p):
            mhat = m_ / c1
            vhat = v_ / c2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )
