"""Server-side (outer-loop) optimizers for MapReduce training rounds.

These consume the *average client delta* produced by a DrJAX reduction and
update the global model: FedAvg(+server momentum), FedAdam (Reddi et al.),
and the DiLoCo outer optimizer (Nesterov momentum SGD; Douillard et al. 2023
— one of the algorithms the paper explicitly cites as expressible in DrJAX).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .optimizers import Optimizer


def fedavg_momentum(lr: float = 1.0, momentum: float = 0.0) -> Optimizer:
    """Classic FedAvg: apply the mean client delta (optionally with momentum)."""

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, jnp.float32), params
            )
        return state

    def update(mean_delta, state, params=None):
        step = state["step"] + 1
        if momentum:
            mu = jax.tree_util.tree_map(
                lambda m, d: momentum * m + d.astype(jnp.float32),
                state["mu"], mean_delta,
            )
            upd = jax.tree_util.tree_map(lambda m: lr * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree_util.tree_map(
            lambda d: lr * d.astype(jnp.float32), mean_delta
        )
        return upd, {"step": step}

    return Optimizer(init, update)


def fedadam(lr: float = 1e-2, b1: float = 0.9, b2: float = 0.99,
            eps: float = 1e-3) -> Optimizer:
    """FedAdam (Reddi et al. 2021): Adam on the mean client delta."""

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(mean_delta, state, params=None):
        step = state["step"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, d: b1 * m_ + (1 - b1) * d.astype(jnp.float32),
            state["m"], mean_delta,
        )
        v = jax.tree_util.tree_map(
            lambda v_, d: b2 * v_ + (1 - b2) * jnp.square(d.astype(jnp.float32)),
            state["v"], mean_delta,
        )
        upd = jax.tree_util.tree_map(
            lambda m_, v_: lr * m_ / (jnp.sqrt(v_) + eps), m, v
        )
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def diloco_optimizer(lr: float = 0.7, momentum: float = 0.9) -> Optimizer:
    """DiLoCo outer optimizer: Nesterov momentum over the mean delta."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, jnp.float32), params
            ),
        }

    def update(mean_delta, state, params=None):
        step = state["step"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, d: momentum * m + d.astype(jnp.float32),
            state["mu"], mean_delta,
        )
        # Nesterov lookahead
        upd = jax.tree_util.tree_map(
            lambda m, d: lr * (momentum * m + d.astype(jnp.float32)),
            mu, mean_delta,
        )
        return upd, {"step": step, "mu": mu}

    return Optimizer(init, update)
