"""Optimizers (no optax dependency): local/client and server/outer."""

from .optimizers import (
    Optimizer,
    adamw,
    clip_by_global_norm,
    sgd,
    global_norm,
)
from .schedules import constant, cosine_decay, linear_warmup
from .server import diloco_optimizer, fedadam, fedavg_momentum

__all__ = [
    "Optimizer",
    "adamw",
    "sgd",
    "clip_by_global_norm",
    "global_norm",
    "constant",
    "cosine_decay",
    "linear_warmup",
    "diloco_optimizer",
    "fedadam",
    "fedavg_momentum",
]
