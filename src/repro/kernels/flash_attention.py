"""Pallas TPU flash attention (causal / local-window, GQA).

TPU-native tiling: the (Sq, Skv) score matrix never leaves VMEM — the grid is
``(batch, kv_heads, q_blocks, kv_blocks)`` with the kv-block dimension
innermost; online-softmax accumulators (acc, m, l) live in VMEM scratch and
persist across the innermost grid dimension (the standard TPU flash pattern).
Fully-masked kv blocks beyond the causal diagonal (or outside the local
window) are skipped with ``pl.when`` — compute cost matches the
lower-triangular schedule.

Block shapes are MXU-aligned (multiples of 128 on the contracting/lane dims
when the head_dim allows). Layout: q (B, Hkv, G, Sq, hd); k/v (B, Hkv, Skv,
hd) — G = query groups per kv head (GQA).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    causal: bool,
    window: int,
    scale: float,
    q_block: int,
    kv_block: int,
    nk: int,
    kv_len: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * q_block
    k_start = j * kv_block

    # visibility of this (i, j) block pair
    visible = True
    if causal:
        visible = k_start <= q_start + q_block - 1
    if window and window > 0:
        visible = jnp.logical_and(
            visible, k_start + kv_block - 1 > q_start - window
        )

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, qb, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (kb, hd)
        v = v_ref[0, 0].astype(jnp.float32)  # (kb, hd)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, qb, kb)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        ok = k_pos < kv_len
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window and window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok[None], s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, qb, hd)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv

    @pl.when(j == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    kv_block: int = 512,
    interpret: bool = False,
):
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd). Returns (B, Sq, Hq, hd)."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    pad_q = (-sq) % q_block
    pad_k = (-skv) % kv_block
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    sqp, skvp = qp.shape[1], kp.shape[1]
    nq, nk = sqp // q_block, skvp // kv_block

    # (B, Hkv, G, S, hd) / (B, Hkv, S, hd)
    qr = jnp.moveaxis(qp.reshape(b, sqp, hkv, g, hd), 1, 3)
    kr = jnp.moveaxis(kp, 1, 2)
    vr = jnp.moveaxis(vp, 1, 2)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        scale=scale,
        q_block=q_block,
        kv_block=kv_block,
        nk=nk,
        kv_len=skv,
    )

    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, g, q_block, hd), lambda b_, h, i, j: (b_, h, 0, i, 0)
            ),
            pl.BlockSpec((1, 1, kv_block, hd), lambda b_, h, i, j: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, kv_block, hd), lambda b_, h, i, j: (b_, h, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, q_block, hd), lambda b_, h, i, j: (b_, h, 0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, q_block, hd), jnp.float32),
            pltpu.VMEM((g, q_block), jnp.float32),
            pltpu.VMEM((g, q_block), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = jnp.moveaxis(out, 3, 1).reshape(b, sqp, hq, hd)
    return out[:, :sq]
