"""Pallas TPU kernel: symmetric int8 block quantization (+ dequant).

Used by the gradient-compression path (``repro.compression``) to quantize
client→server deltas before the cross-pod reduction. Per-row-block absmax
scaling; rows map to the sublane dimension, the 128-wide lane dimension stays
contiguous.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (rb, C)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (rb, 1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(x_ref.dtype)


def quantize(x, *, row_block: int = 256, interpret: bool = False):
    """x: (R, C) -> (q int8 (R, C), scales f32 (R, 1))."""
    r, c = x.shape
    row_block = min(row_block, r)
    pad = (-r) % row_block
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rp = x.shape[0]
    nb = rp // row_block
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((row_block, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((row_block, c), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:r], s[:r]


def dequantize(q, scales, dtype=jnp.float32, *, row_block: int = 256,
               interpret: bool = False):
    """Inverse of :func:`quantize`."""
    r, c = q.shape
    row_block = min(row_block, r)
    pad = (-r) % row_block
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, ((0, pad), (0, 0)))
    rp = q.shape[0]
    nb = rp // row_block
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((row_block, c), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), dtype),
        interpret=interpret,
    )(q, scales)
    return x[:r]
