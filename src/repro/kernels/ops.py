"""Jit'd public wrappers for the Pallas kernels.

Backend dispatch: on TPU the Mosaic kernels run natively; elsewhere
``interpret=True`` executes the kernel bodies in Python (correctness path,
used by tests) and the model code defaults to the XLA blocked implementations
(``repro.models.attention.blocked_attention`` etc.) which share the same
algorithm.
"""

from __future__ import annotations

import functools

import jax

from . import flash_attention as _fa
from . import quantize as _quant
from . import rglru_scan as _lru
from . import wkv6 as _wkv


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_block", "kv_block", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=0, q_block=512,
                    kv_block=512, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "width_block", "interpret"))
def lru_scan(a, b, *, chunk=256, width_block=512, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _lru.lru_scan(
        a, b, chunk=chunk, width_block=width_block, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, *, chunk=64, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _wkv.wkv6(r, k, v, logw, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def quantize(x, *, row_block=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _quant.quantize(x, row_block=row_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def dequantize(q, scales, *, row_block=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _quant.dequantize(
        q, scales, row_block=row_block, interpret=interpret
    )
