"""Jit'd public wrappers for the Pallas kernels.

Backend dispatch: on TPU the Mosaic kernels run natively; elsewhere
``interpret=True`` executes the kernel bodies in Python (correctness path,
used by tests) and the model code defaults to the XLA blocked implementations
(``repro.models.attention.blocked_attention`` etc.) which share the same
algorithm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import flash_attention as _fa
from . import quantize as _quant
from . import reduce_compress as _rc
from . import ref as _ref
from . import rglru_scan as _lru
from . import wkv6 as _wkv


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_block", "kv_block", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=0, q_block=512,
                    kv_block=512, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "width_block", "interpret"))
def lru_scan(a, b, *, chunk=256, width_block=512, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _lru.lru_scan(
        a, b, chunk=chunk, width_block=width_block, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, logw, u, *, chunk=64, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _wkv.wkv6(r, k, v, logw, u, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def quantize(x, *, row_block=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _quant.quantize(x, row_block=row_block, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("dtype", "row_block", "interpret")
)
def dequantize(q, scales, *, dtype=None, row_block=256, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _quant.dequantize(
        q, scales, dtype if dtype is not None else jnp.float32,
        row_block=row_block, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# fused intra-pod reduce + compress (hierarchical-reduction fast path)
# ---------------------------------------------------------------------------
#
# Dispatch rule (ROADMAP "Fused reduce+compress" conventions): on TPU the
# Mosaic kernels in ``reduce_compress.py`` run natively; elsewhere the fused
# jnp oracle runs (a single-pass XLA formulation, NOT the interpreted kernel,
# so the CPU fast path stays fast). ``backend="pallas"`` forces the kernel
# (pass ``interpret=True`` off-TPU), ``backend="jnp"`` forces the oracle.


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def reduce_compress(x, *, row_block=256, interpret=None):
    """(G, R, C) -> ((R, C) int8, (R, 1) f32): fused partial mean + quantize."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _rc.reduce_compress(x, row_block=row_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def dequant_accumulate(q, scales, *, row_block=256, interpret=None):
    """((P, R, C) int8, (P, R, 1)) -> (R, C): fused dequantize + pod mean."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    return _rc.dequant_accumulate(
        q, scales, row_block=row_block, interpret=interpret
    )


# Above roughly this many f32 entries the block-diagonal reduction matrix of
# the oracle's one-pass gemm stops being worth materializing.
_GEMM_WEIGHT_LIMIT = 1 << 22


def _roundtrip_rows(part, qaxis):
    """Straight-through int8 roundtrip of ``part`` with per-row scales over
    axis ``qaxis`` (the wire-format granularity)."""
    moved = part if qaxis == part.ndim - 1 else jnp.moveaxis(part, qaxis, -1)
    rows = moved.reshape(-1, moved.shape[-1])
    q, s = _ref.quantize_ref(rows)
    back = _ref.dequantize_ref(q, s, part.dtype).reshape(moved.shape)
    return back if qaxis == part.ndim - 1 else jnp.moveaxis(back, -1, qaxis)


def _reduce_compress_roundtrip_jnp(x, axis, qaxis):
    """Fused jnp oracle: one pass over ``x`` produces the roundtrip partial.

    The partial mean is a block-diagonal matmul (one gemm reads the operand
    once and emits every pod's partial), which XLA:CPU executes far faster
    than a chain of axis reductions; the quantize/dequantize then runs on the
    small partial only.
    """
    lead = x.shape[:axis]
    g = x.shape[axis]
    trail = x.shape[axis + 1:]
    l = int(np.prod(lead, dtype=np.int64)) if lead else 1
    d = int(np.prod(trail, dtype=np.int64)) if trail else 1
    if x.dtype == jnp.float32 and l * l * g <= _GEMM_WEIGHT_LIMIT:
        w = jnp.repeat(jnp.eye(l, dtype=jnp.float32), g, axis=1) * (1.0 / g)
        part = (w @ x.reshape(l * g, d)).reshape(lead + trail)
    else:
        part = jnp.sum(x.astype(jnp.float32), axis=axis) * (1.0 / g)
        part = part.astype(x.dtype)
    return _roundtrip_rows(part, qaxis)


def _reduce_compress_roundtrip_pallas(x, axis, qaxis, row_block, interpret):
    if qaxis < axis:
        # Quant axis in the lead region: the kernel wants it trailing, but
        # moving it would reorder the pod axes too. Rare (the fast path
        # always quantizes a trailing axis) — use the jnp formulation.
        return _reduce_compress_roundtrip_jnp(x, axis, qaxis)
    lead = x.shape[:axis]
    g = x.shape[axis]
    trail = x.shape[axis + 1:]
    part_shape = lead + trail
    # Canonicalize for the kernel: (L, G, R, C) with the quant axis last.
    if qaxis != len(part_shape) - 1:
        x = jnp.moveaxis(x, qaxis + 1, -1)
        trail = x.shape[axis + 1:]
    c = trail[-1] if trail else 1
    l = int(np.prod(lead, dtype=np.int64)) if lead else 1
    r = int(np.prod(trail[:-1], dtype=np.int64)) if len(trail) > 1 else 1
    x3 = x.reshape(l, g, r, c)

    def one(pod):
        back, _, _ = _rc.reduce_compress_roundtrip(
            pod, row_block=row_block, interpret=interpret
        )
        return back

    back = jax.vmap(one)(x3).reshape(lead + trail)
    if qaxis != len(part_shape) - 1:
        back = jnp.moveaxis(back, -1, qaxis)
    return back


@functools.partial(
    jax.jit, static_argnames=("axis", "qaxis", "row_block", "backend",
                              "interpret")
)
def reduce_compress_roundtrip(x, *, axis=0, qaxis=-1, row_block=256,
                              backend=None, interpret=False):
    """Straight-through fused reduce+compress: mean over ``axis`` followed by
    an int8 roundtrip with per-row-block scales over ``qaxis`` (an axis of
    the *partial*), produced in a single pass over ``x``.

    This is the execution backend of the ``compress="int8"``-tagged DrJAX
    ``reduce_mean`` eqn (``core/hierarchical.py`` fast path).
    """
    part_ndim = x.ndim - 1
    if part_ndim < 1:
        raise ValueError("reduce_compress_roundtrip needs a non-group axis")
    qaxis = qaxis % part_ndim
    if backend is None:
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "jnp":
        return _reduce_compress_roundtrip_jnp(x, axis, qaxis)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    return _reduce_compress_roundtrip_pallas(x, axis, qaxis, row_block,
                                             interpret)
