"""Pallas TPU kernels: fused intra-pod reduce + int8 compress.

The hierarchical reduction (``core/hierarchical.py``) splits a mean over n
clients into a fast intra-pod leg (n -> P pod partials) and a slow cross-pod
leg (P -> 1). The DCN-bound payload is the int8-quantized partial; producing
it with separate reduce / quantize / dequantize ops costs three passes over
the partials plus a full f32 materialization of the roundtrip. These kernels
produce it in a single pass over the deltas:

* :func:`reduce_compress` — partial mean over the leading group axis AND the
  int8 wire payload (values + per-row-block scales) in one kernel: each grid
  step loads one ``(G, rb, C)`` block, accumulates the mean over ``G`` in
  VMEM, and quantizes the resulting ``(rb, C)`` rows without ever writing the
  f32 partial to HBM.
* :func:`reduce_compress_roundtrip` — same pass, but emits the straight-
  through f32 roundtrip value ``dequant(quant(mean(x)))`` (what the DrJAX
  reduction semantics see) alongside the payload.
* :func:`dequant_accumulate` — the matching cross-pod leg: dequantizes the P
  per-pod payloads and accumulates their mean in one pass, so the f32
  partials are never materialized on the receiving side either.

Scale granularity is per row block: rows map to the sublane dimension and a
row is one lane-contiguous block of ``C`` values (the flat-packing utility in
``repro.compression`` lays trees out as ``(..., R, 256)`` buffers, so a
"row" is a 256-wide slice of the packed delta).

Shape contract (canonical 3-D; ``repro.kernels.ops`` folds leading pod axes
in via ``jax.vmap``):

    reduce_compress:           (G, R, C) f32-like -> ((R, C) int8, (R, 1) f32)
    reduce_compress_roundtrip: (G, R, C) -> ((R, C) x.dtype, (R, C) int8, (R, 1) f32)
    dequant_accumulate:        ((P, R, C) int8, (P, R, 1) f32) -> (R, C) f32
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _partial_mean(x_ref):
    """Mean over the group axis of one (G, rb, C) block, in f32."""
    x = x_ref[...].astype(jnp.float32)  # (G, rb, C)
    return jnp.sum(x, axis=0) * (1.0 / x.shape[0])  # (rb, C)


def _quantize_rows(part):
    """Per-row symmetric int8 quantization of a (rb, C) block."""
    absmax = jnp.max(jnp.abs(part), axis=-1, keepdims=True)  # (rb, 1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(part / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _reduce_compress_kernel(x_ref, q_ref, s_ref):
    q, scale = _quantize_rows(_partial_mean(x_ref))
    q_ref[...] = q
    s_ref[...] = scale


def _reduce_compress_roundtrip_kernel(x_ref, back_ref, q_ref, s_ref):
    q, scale = _quantize_rows(_partial_mean(x_ref))
    back_ref[...] = (q.astype(jnp.float32) * scale).astype(back_ref.dtype)
    q_ref[...] = q
    s_ref[...] = scale


def _dequant_accumulate_kernel(q_ref, s_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)          # (P, rb, C)
    back = q * s_ref[...]                       # (P, rb, C) dequant inline
    out_ref[...] = jnp.sum(back, axis=0) * (1.0 / q.shape[0])


def _pad_rows(x, row_block, axis):
    pad = (-x.shape[axis]) % row_block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x


def reduce_compress(x, *, row_block: int = 256, interpret: bool = False):
    """Fused partial mean + int8 quantize: (G, R, C) -> ((R, C) q, (R, 1) s)."""
    g, r, c = x.shape
    row_block = min(row_block, r)
    x = _pad_rows(x, row_block, axis=1)
    rp = x.shape[1]
    nb = rp // row_block
    q, s = pl.pallas_call(
        _reduce_compress_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((g, row_block, c), lambda i: (0, i, 0))],
        out_specs=[
            pl.BlockSpec((row_block, c), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:r], s[:r]


def reduce_compress_roundtrip(x, *, row_block: int = 256,
                              interpret: bool = False):
    """Fused mean + quantize + dequantize: (G, R, C) -> (back, q, s).

    ``back`` is the straight-through roundtrip partial in ``x.dtype`` — the
    value the DrJAX reduction consumes; ``(q, s)`` is the wire payload.
    """
    g, r, c = x.shape
    row_block = min(row_block, r)
    x = _pad_rows(x, row_block, axis=1)
    rp = x.shape[1]
    nb = rp // row_block
    back, q, s = pl.pallas_call(
        _reduce_compress_roundtrip_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((g, row_block, c), lambda i: (0, i, 0))],
        out_specs=[
            pl.BlockSpec((row_block, c), lambda i: (i, 0)),
            pl.BlockSpec((row_block, c), lambda i: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, c), x.dtype),
            jax.ShapeDtypeStruct((rp, c), jnp.int8),
            jax.ShapeDtypeStruct((rp, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return back[:r], q[:r], s[:r]


def dequant_accumulate(q, scales, *, row_block: int = 256,
                       interpret: bool = False):
    """Fused dequantize + mean over pods: ((P, R, C), (P, R, 1)) -> (R, C)."""
    p, r, c = q.shape
    row_block = min(row_block, r)
    q = _pad_rows(q, row_block, axis=1)
    scales = _pad_rows(scales, row_block, axis=1)
    rp = q.shape[1]
    nb = rp // row_block
    out = pl.pallas_call(
        _dequant_accumulate_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((p, row_block, c), lambda i: (0, i, 0)),
            pl.BlockSpec((p, row_block, 1), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out[:r]
