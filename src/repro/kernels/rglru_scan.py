"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

    h_t = a_t ⊙ h_{t-1} + b_t

Grid: ``(batch, width_blocks, time_chunks)`` with time innermost; the hidden
state ``h`` lives in VMEM scratch and persists across time chunks, so HBM
traffic is exactly one read of (a, b) and one write of h — the recurrence is
bandwidth-bound and this tiling hits the HBM roofline. Within a chunk the
sequential dependence runs in a ``fori_loop`` over VMEM-resident tiles
(width tiles are lane-aligned multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, o_ref, h_ref, *, chunk: int):
    t_chunk = pl.program_id(2)

    @pl.when(t_chunk == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)  # (chunk, wb)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def lru_scan(a, b, *, chunk: int = 256, width_block: int = 512,
             interpret: bool = False):
    """a, b: (B, S, W) -> h: (B, S, W) with h_t = a_t h_{t-1} + b_t."""
    bsz, s, w = a.shape
    chunk = min(chunk, s)
    width_block = min(width_block, w)
    pad_s = (-s) % chunk
    pad_w = (-w) % width_block
    if pad_s or pad_w:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
    if pad_w:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_w)))
    sp, wp = a.shape[1], a.shape[2]
    nt, nw = sp // chunk, wp // width_block

    out = pl.pallas_call(
        functools.partial(_lru_kernel, chunk=chunk),
        grid=(bsz, nw, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, width_block), lambda b_, w_, t: (b_, t, w_)),
            pl.BlockSpec((1, chunk, width_block), lambda b_, w_, t: (b_, t, w_)),
        ],
        out_specs=pl.BlockSpec(
            (1, chunk, width_block), lambda b_, w_, t: (b_, t, w_)
        ),
        out_shape=jax.ShapeDtypeStruct((bsz, sp, wp), a.dtype),
        scratch_shapes=[pltpu.VMEM((width_block,), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:, :s, :w]
