"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Naive full-materialization GQA attention. Same contract as the kernel."""
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window and window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def lru_scan_ref(a, b):
    """Sequential h_t = a_t h_{t-1} + b_t."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a32 = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b32 = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h0 = jnp.zeros_like(a32[0])
    _, hs = jax.lax.scan(step, h0, (a32, b32))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Sequential WKV6 recurrence (fp32)."""
    b, s, h, n = r.shape
    S0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(S, inp):
        rt, kt, vt, lwt = inp
        wt = jnp.exp(lwt)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        return wt[..., None] * S + kv, out

    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, logw)
    )
    _, outs = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 1)


def quantize_ref(x, *, row_block=256):
    """Per-row symmetric int8 quantization (row granularity = 1 row)."""
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q, scales, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scales).astype(dtype)


def reduce_compress_ref(x):
    """Fused partial mean + int8 quantize, op-for-op the kernel's math.

    (G, R, C) -> ((R, C) int8, (R, 1) f32 scales). The mean accumulates in
    f32 over the leading group axis; scales are per row (one lane-contiguous
    block of C values).
    """
    part = jnp.sum(x.astype(jnp.float32), axis=0) * (1.0 / x.shape[0])
    return quantize_ref(part)


def reduce_compress_roundtrip_ref(x):
    """(G, R, C) -> (back x.dtype, q int8, s f32): mean + quant + dequant."""
    q, s = reduce_compress_ref(x)
    back = dequantize_ref(q, s, x.dtype)
    return back, q, s


def dequant_accumulate_ref(q, scales):
    """Fused dequantize + mean over pods: ((P, R, C), (P, R, 1)) -> (R, C)."""
    back = q.astype(jnp.float32) * scales
    return jnp.sum(back, axis=0) * (1.0 / q.shape[0])
