"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunkwise-parallel).

Hardware adaptation of the paper's CUDA kernel: instead of one thread per
channel, the chunked form turns intra-chunk token interactions into plain
(C×N)·(N×C) matmuls that feed the MXU, while the inter-chunk state
S ∈ R^{N×N} persists in VMEM scratch across the (innermost, sequential)
chunk grid dimension:

    S_{c+1} = diag(e^{Σ logw}) S_c + Σ_j (k_j ⊙ e^{Σ_{t>j} logw_t}) v_jᵀ
    o_i     = (r_i ⊙ e^{lcw_{i-1}}) S_c
            + Σ_{j<i} [(r_i ⊙ e^{lcw_{i-1}})·(k_j ⊙ e^{-lcw_j})] v_j
            + (r_i · (u ⊙ k_i)) v_i

Grid: ``(batch, heads, chunks)``. All exp() arguments are differences of
cumulative log-decays within one chunk, so they are ≤ 0 for the interaction
terms — numerically safe in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, chunk):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0].astype(jnp.float32)  # (C, N)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (N,)
    S = s_ref[...]  # (N, N)

    lcw = jnp.cumsum(lw, axis=0)  # (C, N)
    lcw_prev = lcw - lw

    r_dec = r * jnp.exp(lcw_prev)
    o = jax.lax.dot_general(
        r_dec, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, N)

    k_dec = k * jnp.exp(-lcw)
    scores = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj < ii, scores, 0.0)
    o = o + jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    bonus = jnp.sum(r * u[None] * k, axis=-1, keepdims=True)  # (C, 1)
    o = o + bonus * v
    o_ref[0, :, 0] = o.astype(o_ref.dtype)

    total = lcw[-1]  # (N,)
    k_rem = k * jnp.exp(total[None] - lcw)  # (C, N)
    s_ref[...] = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def wkv6(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """r,k,v,logw: (B, S, H, N); u: (H, N). Returns out (B, S, H, N) f32."""
    b, s, h, n = r.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = padf(r), padf(k), padf(v), padf(logw)
    sp = r.shape[1]
    nc = sp // chunk

    out = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, n), lambda b_, h_, c: (h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, h, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out[:, :s]
