"""Delta/gradient compression for the reduce path."""

from .api import int8_roundtrip, topk_sparsify, ErrorFeedback

__all__ = ["int8_roundtrip", "topk_sparsify", "ErrorFeedback"]
