"""Delta/gradient compression for the reduce path."""

from .api import (
    PACK_COLS,
    ErrorFeedback,
    PackSpec,
    flat_pack,
    flat_unpack,
    int8_roundtrip,
    topk_sparsify,
)

__all__ = [
    "PACK_COLS",
    "PackSpec",
    "flat_pack",
    "flat_unpack",
    "int8_roundtrip",
    "topk_sparsify",
    "ErrorFeedback",
]
