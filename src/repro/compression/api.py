"""Gradient/delta compression for cross-pod reductions.

At 1000+-node scale, the cross-pod leg of the reduction rides the slow DCN
links; quantizing the client deltas to int8 cuts those bytes 4× (vs f32)
at <1% cosine error for local-SGD deltas. The quantize/dequantize pair is
the Pallas kernel in ``repro.kernels.quantize`` on TPU and its jnp oracle
elsewhere (dispatched through ``repro.kernels.ops``).

The quantize→dequantize *roundtrip* runs before the DrJAX reduction: the
reduction semantics are unchanged, only the value is quantized — so the same
program interprets out to federated systems that apply wire compression.
Under MapReduce AD the roundtrip is **straight-through** (a ``custom_jvp``
identity): ``grad`` of a compressed program equals ``grad`` of the
uncompressed one, which is what lets ``core/hierarchical.py`` swap the
composition for the fused reduce+compress kernel without changing
derivatives.

Pytrees are compressed via **flat packing** (:func:`flat_pack` /
:func:`flat_unpack`): all leaves of one dtype are concatenated into a single
contiguous ``(R, 256)`` buffer (each leaf's span zero-aligned to the block
boundary, so no scale block crosses a leaf), and the whole tree pays one
kernel launch per dtype instead of a padded f32 materialization per leaf.
A 256-wide row is the per-row-block scale granularity of the wire format
(one f32 scale per 256 int8 values).

``ErrorFeedback`` keeps the residual (x - Q(x)) and adds it to the next
round's delta (Seide et al. 2014) — restores convergence at aggressive
compression.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops

# Lane width of the packed wire format: one f32 scale per PACK_COLS values.
PACK_COLS = 256


# ---------------------------------------------------------------------------
# pytree flat packing: one contiguous buffer per dtype
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Layout record produced by :func:`flat_pack`.

    ``segments`` maps a dtype name to the ordered ``(leaf_index, size,
    stride)`` spans of its buffer's last (flattened) axis — ``stride`` is
    ``size`` rounded up to the ``cols`` block boundary, so no quantization
    scale block ever spans two leaves. ``trail_shapes`` are the per-leaf
    shapes *below* the packed lead axes, which is what :func:`flat_unpack`
    restores (the lead axes at unpack time may be fewer — e.g. gone entirely
    after a stack-spanning reduction).
    """

    treedef: Any
    trail_shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    segments: Dict[str, Tuple[Tuple[int, int], ...]]
    cols: Optional[int]


def flat_pack(tree, lead_ndim: int = 0, cols: Optional[int] = PACK_COLS):
    """Pack a pytree into one contiguous buffer per dtype.

    Every leaf must carry the same ``lead_ndim`` leading (group) axes; the
    trailing axes are flattened and concatenated. With ``cols`` set, each
    leaf's span is zero-padded up to a ``cols`` boundary before the concat
    and the buffer is reshaped to ``(*lead, R, cols)`` — the row-block
    layout the quantization kernels consume. The per-leaf alignment keeps
    every scale block inside a single leaf: a small-magnitude leaf packed
    next to a large one must not share the large leaf's quantization scale
    (it would dequantize to zero). Returns ``(buffers, spec)`` with
    ``buffers`` keyed by dtype name.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return {}, PackSpec(treedef, (), (), {}, cols)
    lead = jnp.shape(leaves[0])[:lead_ndim]
    groups: Dict[str, list] = {}
    trail_shapes = []
    dtypes = []
    for i, leaf in enumerate(leaves):
        shape = jnp.shape(leaf)
        if shape[:lead_ndim] != lead:
            raise ValueError(
                f"flat_pack: leaf {i} has lead axes {shape[:lead_ndim]}, "
                f"expected {lead} (every leaf must carry the same "
                f"{lead_ndim} leading group axes)."
            )
        trail_shapes.append(shape[lead_ndim:])
        dtypes.append(leaf.dtype)
        groups.setdefault(jnp.dtype(leaf.dtype).name, []).append(i)
    buffers = {}
    segments = {}
    for key, idxs in groups.items():
        parts = []
        segs = []
        for i in idxs:
            part = jnp.reshape(leaves[i], lead + (-1,))
            size = part.shape[-1]
            stride = size
            if cols:
                pad = (-size) % cols
                if pad:
                    widths = [(0, 0)] * (part.ndim - 1) + [(0, pad)]
                    part = jnp.pad(part, widths)
                stride = size + pad
            parts.append(part)
            segs.append((i, size, stride))
        buf = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)
        segments[key] = tuple(segs)
        if cols:
            buf = buf.reshape(lead + (-1, cols))
        buffers[key] = buf
    spec = PackSpec(treedef, tuple(trail_shapes), tuple(dtypes), segments,
                    cols)
    return buffers, spec


def flat_unpack(buffers, spec: PackSpec, lead_ndim: int = 0):
    """Inverse of :func:`flat_pack`. ``lead_ndim`` counts the lead axes the
    buffers carry *now* (0 after a stack-spanning reduction)."""
    leaves: list = [None] * len(spec.trail_shapes)
    for key, segs in spec.segments.items():
        buf = buffers[key]
        lead = buf.shape[:lead_ndim]
        flat = buf.reshape(lead + (-1,))
        offset = 0
        for i, size, stride in segs:
            piece = jax.lax.slice_in_dim(
                flat, offset, offset + size, axis=flat.ndim - 1
            )
            leaves[i] = piece.reshape(lead + spec.trail_shapes[i]).astype(
                spec.dtypes[i]
            )
            offset += stride
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# int8 roundtrip (straight-through)
# ---------------------------------------------------------------------------


def _roundtrip_leaves(tree):
    """Quantize-dequantize every floating leaf via the packed wire format.

    One ``(R, 256)`` buffer, one pad, and one kernel dispatch per float
    dtype (``kernels.ops`` → Pallas on TPU, jnp oracle elsewhere);
    non-float leaves pass through untouched.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    float_idx = [
        i for i, leaf in enumerate(leaves)
        if jnp.issubdtype(jnp.result_type(leaf), jnp.floating)
    ]
    if not float_idx:
        return tree
    bufs, spec = flat_pack([leaves[i] for i in float_idx], lead_ndim=0,
                           cols=PACK_COLS)
    out_bufs = {}
    for key, buf in bufs.items():
        q, s = kernel_ops.quantize(buf)
        out_bufs[key] = kernel_ops.dequantize(q, s, dtype=buf.dtype)
    back = flat_unpack(out_bufs, spec, lead_ndim=0)
    for i, leaf in zip(float_idx, back):
        leaves[i] = leaf
    return jax.tree_util.tree_unflatten(treedef, leaves)


@jax.custom_jvp
def int8_roundtrip(tree):
    """Quantize-dequantize every leaf (the value a backend would transmit).

    Straight-through under AD: the tangent passes through unchanged, so
    derivatives of a compressed program equal the uncompressed ones (and
    match the fused reduce+compress kernel's ``custom_vjp`` semantics).
    """
    return _roundtrip_leaves(tree)


@int8_roundtrip.defjvp
def _int8_roundtrip_jvp(primals, tangents):
    (tree,), (t,) = primals, tangents
    return _roundtrip_leaves(tree), t


# Recognition tag for core/hierarchical.py: a compress_fn carrying
# ``drjax_fused_compress = "int8"`` may be replaced by the fused single-pass
# reduce+compress kernel (identical straight-through AD, same wire format).
int8_roundtrip.drjax_fused_compress = "int8"


def _topk_leaf(x, fraction: float):
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(int(flat.size * fraction), 1)
    # Select exactly k entries. A magnitude threshold (|x| >= kth value)
    # would keep MORE than k on ties; scattering the top_k indices keeps the
    # sparsity budget exact (ties broken by index order, as lax.top_k does).
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    sparse = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return sparse.reshape(x.shape).astype(x.dtype)


def topk_sparsify(tree, fraction: float = 0.01):
    """Keep the top-|fraction| entries per leaf (magnitude pruning)."""
    return jax.tree_util.tree_map(lambda x: _topk_leaf(x, fraction), tree)


@dataclasses.dataclass
class ErrorFeedback:
    """Residual accumulator for biased compressors."""

    @staticmethod
    def init(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, jnp.float32), tree
        )

    @staticmethod
    def compress(tree, residual, compressor, *args):
        corrected = jax.tree_util.tree_map(
            lambda x, r: x.astype(jnp.float32) + r, tree, residual
        )
        compressed = compressor(corrected, *args)
        new_residual = jax.tree_util.tree_map(
            lambda c, comp: c - comp.astype(jnp.float32), corrected, compressed
        )
        return compressed, new_residual
