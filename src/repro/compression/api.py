"""Gradient/delta compression for cross-pod reductions.

At 1000+-node scale, the cross-pod leg of the reduction rides the slow DCN
links; quantizing the client deltas to int8 cuts those bytes 4× (vs f32)
at <1% cosine error for local-SGD deltas. The quantize/dequantize pair is
the Pallas kernel in ``repro.kernels.quantize`` on TPU and its jnp oracle
elsewhere.

The quantize→dequantize *roundtrip* runs before the DrJAX reduction: the
reduction semantics (and MapReduce AD) are unchanged, only the value is
quantized — so the same program interprets out to federated systems that
apply wire compression.

``ErrorFeedback`` keeps the residual (x - Q(x)) and adds it to the next
round's delta (Seide et al. 2014) — restores convergence at aggressive
compression.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


def _quant_leaf(x):
    orig_shape = x.shape
    flat = x.reshape(-1)
    # pad to a rows x 256 matrix for per-row scales
    cols = 256 if flat.size >= 256 else flat.size
    pad = (-flat.size) % cols
    mat = jnp.pad(flat, (0, pad)).reshape(-1, cols)
    q, s = kref.quantize_ref(mat)
    back = kref.dequantize_ref(q, s, jnp.float32).reshape(-1)[: flat.size]
    return back.reshape(orig_shape).astype(x.dtype)


def int8_roundtrip(tree):
    """Quantize-dequantize every leaf (the value a backend would transmit)."""
    return jax.tree_util.tree_map(_quant_leaf, tree)


def _topk_leaf(x, fraction: float):
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(int(flat.size * fraction), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    sparse = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return sparse.reshape(x.shape).astype(x.dtype)


def topk_sparsify(tree, fraction: float = 0.01):
    """Keep the top-|fraction| entries per leaf (magnitude pruning)."""
    return jax.tree_util.tree_map(lambda x: _topk_leaf(x, fraction), tree)


@dataclasses.dataclass
class ErrorFeedback:
    """Residual accumulator for biased compressors."""

    @staticmethod
    def init(tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, jnp.float32), tree
        )

    @staticmethod
    def compress(tree, residual, compressor, *args):
        corrected = jax.tree_util.tree_map(
            lambda x, r: x.astype(jnp.float32) + r, tree, residual
        )
        compressed = compressor(corrected, *args)
        new_residual = jax.tree_util.tree_map(
            lambda c, comp: c - comp.astype(jnp.float32), corrected, compressed
        )
        return compressed, new_residual
