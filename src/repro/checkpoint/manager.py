"""Checkpoint manager: atomic, hashed, async, restart-safe.

Layout per step::

    <dir>/step_000123/
        manifest.json     # tree structure, shapes, dtypes, per-array sha256,
                          # user metadata (data-iterator state, rng, mesh)
        arrays.npz        # flattened leaves keyed by leaf index
    <dir>/LATEST          # atomic pointer file (rename barrier)

Guarantees:
 * atomicity — a checkpoint becomes visible only after its directory is
   complete (LATEST is updated last via os.replace);
 * integrity — every array carries a sha256; restore verifies;
 * async — ``save(..., blocking=False)`` hands the host copy to a writer
   thread, training continues (one outstanding write, back-pressure on the
   next save);
 * retention — ``keep_last_n`` garbage-collects old steps;
 * auto-resume — ``restore_latest()`` picks the newest complete checkpoint,
   skipping torn ones.

Chaos hooks: ``fault_hook(step) -> None | "torn" | "corrupt"`` is consulted
once after every completed write and mutates the just-written checkpoint in
place — ``"torn"`` simulates a crash between the array write and the
manifest write (directory present, no manifest, stale LATEST), ``"corrupt"``
a bit-flip on disk (valid npz, sha256 mismatch). Both states MUST be skipped
by ``restore_latest`` in favor of the previous complete step — that
skip-and-fall-back path is what the chaos soak (``runtime/chaos.py``)
exercises under composed failures. ``inject_fault(step, kind)`` applies the
same mutations to an already-written checkpoint (tests).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


#: Fault kinds ``fault_hook`` / ``inject_fault`` understand.
FAULT_KINDS = ("torn", "corrupt")


def _apply_fault(step_dir: str, kind: str) -> None:
    if kind == "torn":
        _tear_checkpoint(step_dir)
    elif kind == "corrupt":
        _corrupt_checkpoint(step_dir)
    else:
        raise ValueError(f"unknown checkpoint fault kind {kind!r}; "
                         f"expected one of {FAULT_KINDS}")


def _tear_checkpoint(step_dir: str) -> None:
    """Simulate a crash mid-write: arrays on disk, manifest never written."""
    manifest = os.path.join(step_dir, "manifest.json")
    if os.path.exists(manifest):
        os.remove(manifest)


def _corrupt_checkpoint(step_dir: str) -> None:
    """Flip one byte of the first non-empty leaf: the npz stays loadable but
    the manifest's sha256 no longer matches."""
    path = os.path.join(step_dir, "arrays.npz")
    data = dict(np.load(path))
    for key in sorted(data):
        a = data[key]
        if a.size == 0:
            continue
        raw = bytearray(a.tobytes())
        raw[0] ^= 0xFF
        data[key] = np.frombuffer(bytes(raw), dtype=a.dtype).reshape(a.shape)
        break
    np.savez(path, **data)


class CheckpointManager:
    def __init__(self, directory: str, keep_last_n: int = 3,
                 fault_hook: Optional[Callable[[int], Optional[str]]] = None):
        self.directory = directory
        self.keep_last_n = keep_last_n
        self.fault_hook = fault_hook
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        # (originating step, exception) — surfaced on the next save()/wait()
        self._write_error: Optional[Tuple[int, BaseException]] = None

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()  # back-pressure: one outstanding async write
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = []
        leaf_dtypes = []
        for l in leaves:
            a = np.asarray(l)  # device->host copy now
            leaf_dtypes.append(str(a.dtype))
            if a.dtype.name == "bfloat16":  # npz can't store ml_dtypes
                a = a.view(np.uint16)
            host_leaves.append(a)
        treedef_repr = str(treedef)

        def _write():
            try:
                tmp = self._step_dir(step) + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                arrays = {_leaf_key(i): l for i, l in enumerate(host_leaves)}
                np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
                manifest = {
                    "step": step,
                    "treedef": treedef_repr,
                    "num_leaves": len(host_leaves),
                    "leaves": [
                        {
                            "shape": list(l.shape),
                            "dtype": dt,
                            "sha256": hashlib.sha256(
                                np.ascontiguousarray(l).tobytes()
                            ).hexdigest(),
                        }
                        for l, dt in zip(host_leaves, leaf_dtypes)
                    ],
                    "metadata": metadata or {},
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                final = self._step_dir(step)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                fault = self.fault_hook(step) if self.fault_hook else None
                if fault is not None:
                    _apply_fault(final, fault)
                if fault != "torn":
                    # atomic LATEST pointer (a torn write crashed before it)
                    ptr_tmp = os.path.join(self.directory, ".LATEST.tmp")
                    with open(ptr_tmp, "w") as f:
                        f.write(os.path.basename(final))
                    os.replace(ptr_tmp, os.path.join(self.directory, "LATEST"))
                self._gc()
            except BaseException as e:  # surfaced on next save()/wait()
                self._write_error = (step, e)

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._writer = threading.Thread(target=_write, daemon=True)
            self._writer.start()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_pending()

    def _raise_pending(self):
        if self._write_error is not None:
            (step, e), self._write_error = self._write_error, None
            raise RuntimeError(
                f"async checkpoint write failed at step {step}"
            ) from e

    def inject_fault(self, step: int, kind: str) -> None:
        """Mutate an already-written checkpoint in place (chaos testing).

        ``kind="torn"`` removes the manifest (the crash-mid-write state);
        ``kind="corrupt"`` flips a byte in ``arrays.npz`` so the sha256
        verification fails. Either way ``restore_latest`` must skip the
        step and fall back to the previous complete one.
        """
        self.wait()
        _apply_fault(self._step_dir(step), kind)

    def _gc(self) -> None:
        steps = sorted(self._complete_steps())
        for s in steps[: -self.keep_last_n]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------

    def _complete_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(
                os.path.join(self.directory, name, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, step: int, example_tree: Any,
                verify: bool = True) -> Tuple[Any, dict]:
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves = []
        for i in range(manifest["num_leaves"]):
            a = data[_leaf_key(i)]
            spec_dtype = manifest["leaves"][i]["dtype"]
            if spec_dtype == "bfloat16" and a.dtype == np.uint16:
                import ml_dtypes
                a = a.view(ml_dtypes.bfloat16)
            leaves.append(a)
        if verify:
            for l, spec in zip(leaves, manifest["leaves"]):
                h = hashlib.sha256(np.ascontiguousarray(l).tobytes()).hexdigest()
                if h != spec["sha256"]:
                    raise IOError(
                        f"checkpoint corruption at step {step}: hash mismatch"
                    )
        _, treedef = jax.tree_util.tree_flatten(example_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        # cast to the example's dtypes (bf16 params round-trip via npz as-is)
        tree = jax.tree_util.tree_map(
            lambda ex, l: np.asarray(l).astype(ex.dtype)
            if hasattr(ex, "dtype")
            else l,
            example_tree,
            tree,
        )
        return tree, manifest["metadata"]

    def restore_latest(self, example_tree: Any,
                       verify: bool = True) -> Optional[Tuple[int, Any, dict]]:
        self.wait()
        steps = sorted(self._complete_steps(), reverse=True)
        for s in steps:
            try:
                tree, meta = self.restore(s, example_tree, verify=verify)
                return s, tree, meta
            except (IOError, KeyError, json.JSONDecodeError):
                continue  # torn/corrupt checkpoint: fall back to previous
        return None
