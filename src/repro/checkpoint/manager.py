"""Checkpoint manager: atomic, hashed, async, crash-consistent, restart-safe.

Layout per step::

    <dir>/step_000123/
        manifest.json     # tree structure, shapes, dtypes, per-array sha256,
                          # user metadata (data-iterator state, rng, mesh)
        arrays.npz        # flattened leaves keyed by leaf index
    <dir>/LATEST          # atomic commit pointer (rename barrier)

Crash-consistency model (the write-ordering contract the mid-write kill
tests sweep):

 1. every file is written into ``step_NNN.tmp`` and fsync'd (file + dir);
 2. the temp dir atomically renames to ``step_NNN`` (``os.replace``);
 3. ONLY THEN does LATEST advance (tmp file + fsync + ``os.replace``).

LATEST is the commit point: ``restore_latest`` considers only complete
steps at or below the step LATEST names, so a writer killed at ANY byte
offset — mid-``arrays.npz``, mid-manifest, after the data but before the
rename, or after the rename but before LATEST — can never surface a
partially-renamed or uncommitted step. The fallback order is still
newest-first below the pointer, skipping torn/corrupt dirs.

Guarantees:
 * atomicity — a checkpoint becomes visible only after its directory is
   complete AND LATEST has advanced past it;
 * integrity — every array carries a sha256; restore verifies;
 * async — ``save(..., blocking=False)`` hands the host copy to a writer
   thread, training continues (one outstanding write, back-pressure on the
   next save);
 * retention — ``keep_last_n`` garbage-collects old steps, but never the
   newest cleanly-written one (a later faulted/killed write must not be
   able to evict the only restorable state);
 * auto-resume — ``restore_latest()`` picks the newest committed complete
   checkpoint, skipping torn/corrupt ones.

Chaos hooks: ``fault_hook(step)`` is consulted once per ``save`` —
``"torn"`` simulates a crash between the array write and the manifest write
(directory present, no manifest, stale LATEST), ``"corrupt"`` a bit-flip on
disk (valid npz, sha256 mismatch), and ``"kill@<bytes>"`` /
``"kill@pre-rename"`` / ``"kill@pre-latest"`` terminate the async writer
mid-write as if the process died (no error surfaces; see
:meth:`CheckpointManager.kill_writer_at_byte`). Every state MUST be
survived by ``restore_latest`` falling back to the previous committed step
— that path is what the chaos soak (``runtime/chaos.py``) exercises under
composed failures. ``inject_fault(step, kind)`` applies the torn/corrupt
mutations to an already-written checkpoint (tests).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import numpy as np


def _leaf_key(i: int) -> str:
    return f"leaf_{i:05d}"


#: Post-write fault kinds ``fault_hook`` / ``inject_fault`` understand.
#: ``fault_hook`` may additionally return mid-write kill specs:
#: ``"kill@<bytes>"``, ``"kill@pre-rename"``, ``"kill@pre-latest"``.
FAULT_KINDS = ("torn", "corrupt")

_KILL_PREFIX = "kill@"
_KILL_PHASES = ("pre-rename", "pre-latest")


class WriterKilled(BaseException):
    """Simulated hard death of the checkpoint writer (SIGKILL mid-write).

    Derives from ``BaseException`` so no ``except Exception`` cleanup path
    can accidentally "handle" it: a killed process reports nothing,
    surfaces no write error, and leaves whatever partial bytes were durable
    at the moment of death. The write path catches exactly this class to
    stop writing — the durability contract (temp dir + fsync + atomic
    rename + LATEST-last) must make EVERY kill point recoverable.
    """


class _KillSwitchFile:
    """File wrapper that terminates the writer after a byte budget.

    Counts every byte written through it (across all files of one
    checkpoint, in write order: ``arrays.npz`` then ``manifest.json``) and
    raises :class:`WriterKilled` once the budget is exhausted — after
    flushing the partial prefix, so the on-disk state is exactly "crashed
    at byte N".
    """

    def __init__(self, raw, budget: List[int]):
        self._raw = raw
        self._budget = budget
        # After the kill fires the wrapper goes dead-silent: a dead process
        # neither writes nor errors, and zipfile's destructor must not trip
        # on the closed underlying file.
        self._dead = False

    def write(self, data):
        if self._dead:
            return len(bytes(data))
        b = bytes(data)
        if self._budget[0] <= 0:
            self._dead = True
            raise WriterKilled("writer killed: byte budget exhausted")
        if len(b) >= self._budget[0]:
            n = self._budget[0]
            self._budget[0] = 0
            self._raw.write(b[:n])
            self._raw.flush()
            self._dead = True
            raise WriterKilled(f"writer killed mid-write after {n} bytes")
        self._budget[0] -= len(b)
        return self._raw.write(b)

    def seek(self, *args):
        return 0 if self._dead else self._raw.seek(*args)

    def tell(self):
        return 0 if self._dead else self._raw.tell()

    def flush(self):
        return None if self._dead else self._raw.flush()

    def __getattr__(self, name):
        # full file-object duck typing (np.savez probes read/seekable/...)
        return getattr(self._raw, name)


def _parse_kill(spec: Union[int, str]):
    """``"kill@256"`` -> 256; ``"kill@pre-rename"`` -> ``"pre-rename"``.

    Bare ints and bare phase strings pass through (the
    ``kill_writer_at_byte`` argument forms)."""
    if isinstance(spec, int):
        offset = spec
    else:
        arg = spec[len(_KILL_PREFIX):] if spec.startswith(_KILL_PREFIX) else spec
        if arg in _KILL_PHASES:
            return arg
        try:
            offset = int(arg)
        except ValueError:
            raise ValueError(
                f"unknown checkpoint fault kind {spec!r}; expected one of "
                f"{FAULT_KINDS}, 'kill@<bytes>', or 'kill@{{{'|'.join(_KILL_PHASES)}}}'"
            ) from None
    if offset < 0:
        raise ValueError(f"kill offset must be >= 0, got {offset}")
    return offset


def _fsync_dir(path: str) -> None:
    """fsync a directory so renames/creates inside it are durable (no-op on
    platforms whose directory fds reject fsync)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic filesystems
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - exotic filesystems
        pass
    finally:
        os.close(fd)


def _apply_fault(step_dir: str, kind: str) -> None:
    if kind == "torn":
        _tear_checkpoint(step_dir)
    elif kind == "corrupt":
        _corrupt_checkpoint(step_dir)
    else:
        raise ValueError(f"unknown checkpoint fault kind {kind!r}; "
                         f"expected one of {FAULT_KINDS}")


def _tear_checkpoint(step_dir: str) -> None:
    """Simulate a crash mid-write: arrays on disk, manifest never written."""
    manifest = os.path.join(step_dir, "manifest.json")
    if os.path.exists(manifest):
        os.remove(manifest)


def _corrupt_checkpoint(step_dir: str) -> None:
    """Flip one byte of the first non-empty leaf: the npz stays loadable but
    the manifest's sha256 no longer matches."""
    path = os.path.join(step_dir, "arrays.npz")
    data = dict(np.load(path))
    for key in sorted(data):
        a = data[key]
        if a.size == 0:
            continue
        raw = bytearray(a.tobytes())
        raw[0] ^= 0xFF
        data[key] = np.frombuffer(bytes(raw), dtype=a.dtype).reshape(a.shape)
        break
    np.savez(path, **data)


class CheckpointManager:
    def __init__(self, directory: str, keep_last_n: int = 3,
                 fault_hook: Optional[Callable[[int], Optional[str]]] = None):
        self.directory = directory
        self.keep_last_n = keep_last_n
        self.fault_hook = fault_hook
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        # (originating step, exception) — surfaced on the next save()/wait()
        self._write_error: Optional[Tuple[int, BaseException]] = None
        # one-shot kill armed by kill_writer_at_byte for the NEXT save
        self._armed_kill: Optional[Union[int, str]] = None
        # step -> kill label, for every write that "died" mid-flight
        self.killed_writes: Dict[int, str] = {}
        # newest step THIS manager wrote cleanly (no fault, no kill): the
        # GC floor — see _gc
        self._last_good_step: Optional[int] = None

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:09d}")

    def kill_writer_at_byte(self, offset: Union[int, str]) -> None:
        """Arm a one-shot mid-write kill for the NEXT :meth:`save`.

        ``offset`` is a byte offset into the checkpoint's write stream
        (``arrays.npz`` then ``manifest.json``, in write order) at which the
        writer is terminated as if the process died: no error surfaces, the
        partial bytes stay in the ``.tmp`` dir, the step never renames into
        place and LATEST never advances. An offset at or past the end of
        the stream kills immediately before the rename instead (an armed
        kill ALWAYS prevents the commit — that totality is what makes
        "restore survives every offset" a sweepable property). The special
        phases ``"pre-rename"`` and ``"pre-latest"`` kill at the named
        ordering point; ``"pre-latest"`` leaves a complete-but-uncommitted
        step dir that ``restore_latest`` must ignore.

        Killed writes are recorded in ``killed_writes`` (step -> label) for
        the chaos soak's accounting; they are deliberately NOT surfaced as
        write errors — a dead process reports nothing.
        """
        self._armed_kill = _parse_kill(offset)

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             blocking: bool = True) -> None:
        self.wait()  # back-pressure: one outstanding async write
        # Fault decision happens here, deterministically, before the writer
        # thread starts: torn/corrupt mutate the completed write as before;
        # kill specs arm the mid-write kill switch.
        fault = self.fault_hook(step) if self.fault_hook else None
        kill = self._armed_kill
        self._armed_kill = None
        if fault is not None and str(fault).startswith(_KILL_PREFIX):
            kill, fault = _parse_kill(str(fault)), None
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = []
        leaf_dtypes = []
        for l in leaves:
            a = np.asarray(l)  # device->host copy now
            leaf_dtypes.append(str(a.dtype))
            if a.dtype.name == "bfloat16":  # npz can't store ml_dtypes
                a = a.view(np.uint16)
            host_leaves.append(a)
        treedef_repr = str(treedef)

        def _write():
            try:
                tmp = self._step_dir(step) + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                budget = [kill] if isinstance(kill, int) else None

                def _out(raw):
                    return _KillSwitchFile(raw, budget) if budget else raw

                arrays = {_leaf_key(i): l for i, l in enumerate(host_leaves)}
                with open(os.path.join(tmp, "arrays.npz"), "wb") as raw:
                    np.savez(_out(raw), **arrays)
                    raw.flush()
                    os.fsync(raw.fileno())
                manifest = {
                    "step": step,
                    "treedef": treedef_repr,
                    "num_leaves": len(host_leaves),
                    "leaves": [
                        {
                            "shape": list(l.shape),
                            "dtype": dt,
                            "sha256": hashlib.sha256(
                                np.ascontiguousarray(l).tobytes()
                            ).hexdigest(),
                        }
                        for l, dt in zip(host_leaves, leaf_dtypes)
                    ],
                    "metadata": metadata or {},
                }
                with open(os.path.join(tmp, "manifest.json"), "wb") as raw:
                    _out(raw).write(json.dumps(manifest).encode("utf-8"))
                    raw.flush()
                    os.fsync(raw.fileno())
                _fsync_dir(tmp)
                if budget is not None and budget[0] > 0:
                    # the byte budget outlived the whole stream: an armed
                    # kill must still prevent the commit
                    raise WriterKilled("writer killed before step-dir rename")
                if kill == "pre-rename":
                    raise WriterKilled("writer killed before step-dir rename")
                final = self._step_dir(step)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                _fsync_dir(self.directory)
                if fault is not None:
                    _apply_fault(final, fault)
                if kill == "pre-latest":
                    raise WriterKilled(
                        "writer killed after rename, before LATEST advanced"
                    )
                if fault != "torn":
                    # atomic LATEST pointer, advanced LAST: the commit point
                    # (a torn write crashed before it)
                    ptr_tmp = os.path.join(self.directory, ".LATEST.tmp")
                    with open(ptr_tmp, "w") as f:
                        f.write(os.path.basename(final))
                        f.flush()
                        os.fsync(f.fileno())
                    os.replace(ptr_tmp, os.path.join(self.directory, "LATEST"))
                    _fsync_dir(self.directory)
                if fault is None:
                    self._last_good_step = step
                self._gc()
            except WriterKilled as e:
                # a dead writer reports nothing — record for introspection
                # only, never surface as a write error
                self.killed_writes[step] = str(e)
            except BaseException as e:  # surfaced on next save()/wait()
                self._write_error = (step, e)

        if blocking:
            _write()
            self._raise_pending()
        else:
            self._writer = threading.Thread(target=_write, daemon=True)
            self._writer.start()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_pending()

    def _raise_pending(self):
        if self._write_error is not None:
            (step, e), self._write_error = self._write_error, None
            raise RuntimeError(
                f"async checkpoint write failed at step {step}"
            ) from e

    def inject_fault(self, step: int, kind: str) -> None:
        """Mutate an already-written checkpoint in place (chaos testing).

        ``kind="torn"`` removes the manifest (the crash-mid-write state);
        ``kind="corrupt"`` flips a byte in ``arrays.npz`` so the sha256
        verification fails. Either way ``restore_latest`` must skip the
        step and fall back to the previous complete one.
        """
        self.wait()
        _apply_fault(self._step_dir(step), kind)

    def _gc(self) -> None:
        # Keep the newest keep_last_n complete steps — but NEVER the newest
        # cleanly-written one or the step LATEST commits to, even when later
        # faulted/killed writes pushed them past the keep budget (a faulted
        # dir counting toward the budget must not evict the only restorable
        # state).
        steps = sorted(self._complete_steps())
        keep = set(steps[-self.keep_last_n:]) if self.keep_last_n > 0 else set()
        if self._last_good_step is not None:
            keep.add(self._last_good_step)
        target = self._latest_target()
        if target is not None:
            keep.add(target)
        for s in steps:
            if s not in keep:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------

    def _complete_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(
                os.path.join(self.directory, name, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return out

    def _latest_target(self) -> Optional[int]:
        """The step LATEST commits to, or None when no commit has happened.

        Robust to a missing/garbled pointer (treated as "nothing committed"
        — the pre-commit crash states)."""
        try:
            with open(os.path.join(self.directory, "LATEST")) as f:
                name = f.read().strip()
            return int(name.split("_")[1])
        except (OSError, IndexError, ValueError):
            return None

    def latest_step(self) -> Optional[int]:
        """Newest complete step at or below the LATEST commit point.

        A step dir that exists but was never committed (writer killed after
        the rename, before LATEST advanced) is invisible here — restoring
        it could silently resume from state whose write was never
        acknowledged."""
        target = self._latest_target()
        if target is None:
            return None
        steps = [s for s in self._complete_steps() if s <= target]
        return max(steps) if steps else None

    def restore(self, step: int, example_tree: Any,
                verify: bool = True) -> Tuple[Any, dict]:
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves = []
        for i in range(manifest["num_leaves"]):
            a = data[_leaf_key(i)]
            spec_dtype = manifest["leaves"][i]["dtype"]
            if spec_dtype == "bfloat16" and a.dtype == np.uint16:
                import ml_dtypes
                a = a.view(ml_dtypes.bfloat16)
            leaves.append(a)
        if verify:
            for l, spec in zip(leaves, manifest["leaves"]):
                h = hashlib.sha256(np.ascontiguousarray(l).tobytes()).hexdigest()
                if h != spec["sha256"]:
                    raise IOError(
                        f"checkpoint corruption at step {step}: hash mismatch"
                    )
        _, treedef = jax.tree_util.tree_flatten(example_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        # cast to the example's dtypes (bf16 params round-trip via npz as-is)
        tree = jax.tree_util.tree_map(
            lambda ex, l: np.asarray(l).astype(ex.dtype)
            if hasattr(ex, "dtype")
            else l,
            example_tree,
            tree,
        )
        return tree, manifest["metadata"]

    def restore_latest(self, example_tree: Any,
                       verify: bool = True) -> Optional[Tuple[int, Any, dict]]:
        self.wait()
        target = self._latest_target()
        if target is None:
            return None
        steps = sorted(
            (s for s in self._complete_steps() if s <= target), reverse=True
        )
        for s in steps:
            try:
                tree, meta = self.restore(s, example_tree, verify=verify)
                return s, tree, meta
            except (IOError, KeyError, json.JSONDecodeError):
                continue  # torn/corrupt checkpoint: fall back to previous
        return None
