"""Checkpointing: sharded npz + manifest, async writes, auto-resume."""

from .manager import FAULT_KINDS, CheckpointManager

__all__ = ["CheckpointManager", "FAULT_KINDS"]
