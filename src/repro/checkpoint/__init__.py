"""Checkpointing: sharded npz + manifest, async writes, auto-resume."""

from .manager import CheckpointManager

__all__ = ["CheckpointManager"]
