"""Asynchronous (one-round-stale) local SGD — compute/communication overlap.

Synchronous rounds serialize: [local steps] → [reduce] → [server update] →
[broadcast]. At pod scale the reduce+broadcast leg can rival the compute leg
(see EXPERIMENTS.md §Roofline, lm_8b). The async variant overlaps them with
one round of staleness (the standard pipelined-DiLoCo trick):

    round r:   clients train on params_{r-1} while the server is still
               aggregating the deltas of round r-1;
    server:    applies delta_{r-1} as soon as it lands → params_r.

The returned step has signature
``(params, pending_delta, server_state, round_data) ->
  (new_params, new_pending_delta, server_state, metrics)``
where ``pending_delta`` is the in-flight aggregate. On hardware, the reduce
of ``new_pending_delta`` overlaps the next round's ``map_fn`` (they have no
data dependency — visible in the jaxpr and exploitable by the scheduler).
Staleness=1 is the classic delayed-gradient regime; convergence holds for
the outer optimizers used here (tested on the CPU-scale model).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import core as drjax
from repro.algorithms.rounds import LocalSGDConfig, _hier_axes, _tree_sub
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm


def make_async_local_sgd_round(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    cfg: LocalSGDConfig,
    *,
    donate: bool = False,
):
    def client_update(params0, client_data):
        opt_state = client_opt.init(params0)

        def one_step(carry, batch):
            params, opt_state = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if cfg.grad_clip:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            updates, opt_state = client_opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), loss

        (params_new, _), losses = jax.lax.scan(
            one_step, (params0, opt_state), client_data
        )
        return _tree_sub(params_new, params0), jnp.mean(losses)

    @drjax.program(
        partition_size=cfg.partition_size,
        partition_axes=cfg.partition_axes,
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )
    def async_round(params, pending_delta, server_state, round_data):
        # 1) apply the delta that finished aggregating during the last round
        updates, server_state = server_opt.update(
            pending_delta, server_state, params
        )
        params = apply_updates(params, updates)
        # 2) launch this round's local training on the just-updated params
        params_b = drjax.broadcast(params)
        deltas, losses = drjax.map_fn(client_update, (params_b, round_data))
        # 3) aggregate — independent of (1)-(2) of the NEXT round, so on
        #    hardware this reduce overlaps the next round's map
        new_pending = drjax.reduce_mean(deltas)
        metrics = {"loss": drjax.reduce_mean(losses)}
        return params, new_pending, server_state, metrics

    def init_pending(params):
        # Match each param's dtype (bf16 params get bf16 pending deltas) so
        # the first server update isn't fed a dtype-mismatched aggregate.
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    if donate:
        # The async carry is (params, pending_delta, server_state): all
        # three are round-to-round state, so the hot loop donates all three.
        async_round = jax.jit(async_round, donate_argnums=(0, 1, 2))
    return async_round, init_pending


def make_hierarchical_async_round(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    cfg: LocalSGDConfig,
    *,
    donate: bool = False,
):
    """Pod-hierarchical asynchronous round (nested {pods, clients} stack).

    Same one-round-stale overlap as :func:`make_async_local_sgd_round`, but
    the delta aggregation is the two-stage hierarchical mean: the fast
    intra-pod leg (``reduce_mean@clients``) can complete while this pod's
    next map is being scheduled, and only the P pod partials cross the DCN
    leg. ``round_data`` leaves are (num_pods, clients_per_pod,
    num_local_steps, ...); ``cfg.partition_size`` counts clients per pod.
    """
    if cfg.num_pods < 1:
        raise ValueError(
            "make_hierarchical_async_round needs cfg.num_pods >= 1"
        )
    from repro.algorithms.rounds import _make_client_update

    client_update = _make_client_update(loss_fn, client_opt, cfg)

    @drjax.program(
        placements={"pods": cfg.num_pods, "clients": cfg.partition_size},
        partition_axes=_hier_axes(cfg),
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )
    def async_round(params, pending_delta, server_state, round_data):
        updates, server_state = server_opt.update(
            pending_delta, server_state, params
        )
        params = apply_updates(params, updates)
        params_b = drjax.broadcast(params)
        deltas, losses = drjax.map_fn(client_update, (params_b, round_data))
        new_pending = drjax.hierarchical_reduce_mean(deltas)
        metrics = {"loss": drjax.hierarchical_reduce_mean(losses)}
        return params, new_pending, server_state, metrics

    def init_pending(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    if donate:
        async_round = jax.jit(async_round, donate_argnums=(0, 1, 2))
    return async_round, init_pending
