"""Parallel algorithms expressed with DrJAX MapReduce primitives."""

from .rounds import (
    LocalSGDConfig,
    make_local_sgd_round,
    make_hierarchical_local_sgd_round,
    make_fedsgd_round,
    make_multi_round,
)
from .async_rounds import (
    make_async_local_sgd_round,
    make_hierarchical_async_round,
)
from .maml import make_parallel_maml
from .btm import branch_train_merge
from .pipeline import (
    PipelineConfig,
    make_pipelined_round,
    pipeline_bubble_fraction,
)

__all__ = [
    "LocalSGDConfig",
    "make_local_sgd_round",
    "make_hierarchical_local_sgd_round",
    "make_fedsgd_round",
    "make_multi_round",
    "make_async_local_sgd_round",
    "make_hierarchical_async_round",
    "make_parallel_maml",
    "branch_train_merge",
    "PipelineConfig",
    "make_pipelined_round",
    "pipeline_bubble_fraction",
]
