"""Parallel MAML over a task partition (paper Snippets 3/4/7).

Model-agnostic: works on any ``loss_fn(params, batch)`` pytree model. The
MAML gradient comes for free from MapReduce AD — ``jax.grad`` of the
parallel loss is another DrJAX program (paper §6: "by simply calling
jax.grad(parallel_maml_loss), we immediately get a DrJAX program that
computes the average MAML gradient over tasks").
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import core as drjax


def make_parallel_maml(
    loss_fn: Callable,
    partition_size: int,
    inner_lr: float = 0.01,
    inner_steps: int = 1,
    *,
    partition_axes: Any = None,
    mesh: Any = None,
):
    """Returns (parallel_maml_loss, maml_train_step)."""

    def maml_task_loss(params, inner_lr_b, task):
        support, query = task["support"], task["query"]

        def inner(p, _):
            g = jax.grad(loss_fn)(p, support)
            p = jax.tree_util.tree_map(
                lambda w, gw: w - inner_lr_b * gw.astype(w.dtype), p, g
            )
            return p, None

        params, _ = jax.lax.scan(inner, params, None, length=inner_steps)
        return loss_fn(params, query)

    @drjax.program(
        partition_size=partition_size, partition_axes=partition_axes, mesh=mesh
    )
    def parallel_maml_loss(params, tasks):
        params_b = drjax.broadcast(params)
        lr_b = drjax.broadcast(jnp.asarray(inner_lr, jnp.float32))
        losses = drjax.map_fn(maml_task_loss, (params_b, lr_b, tasks))
        return drjax.reduce_mean(losses)

    def maml_train_step(params, tasks, outer_lr: float = 0.1):
        """Paper Snippet 7: jax.grad + SGD step."""
        loss, g = jax.value_and_grad(parallel_maml_loss)(params, tasks)
        params = jax.tree_util.tree_map(
            lambda w, gw: (w.astype(jnp.float32) - outer_lr * gw).astype(w.dtype),
            params,
            g,
        )
        return params, loss

    return parallel_maml_loss, maml_train_step
