"""Pipelined rounds over a stage-kind placement (1F1B-style microbatching).

A pipeline is a placement stack whose outermost level is *stage*-kind: the S
groups are not replicas of one computation but S different phases of it, and
they communicate by neighbor transfer (:func:`repro.core.stage_transfer`)
rather than broadcast/reduce. :func:`make_pipelined_round` builds the round
as a ``lax.scan`` over schedule ticks:

* tick ``t`` injects microbatch ``min(t, M-1)`` into stage 0's slot of the
  carried activation buffer (shape ``(S,) + activation``),
* every stage computes its phase on its slot (:func:`stage_map` — one vmap
  over the stage axis, or S heterogeneous per-stage functions),
* stage ``S-1``'s slot is drained as that tick's output,
* the buffer shifts by one stage (``stage_transfer(shift=1)``) for the next
  tick, zero-filling stage 0 until the next injection overwrites it.

The scan runs ``T = M + S - 1`` ticks; ticks before ``S-1`` drain pipeline
fill garbage, so the real outputs are ``outs[S-1:]`` — microbatch ``m``
emerges at tick ``m + S - 1``. The idle fraction of stage-ticks is the
classic pipeline bubble ``(S - 1) / (M + S - 1)``, which microbatching
amortizes away (:func:`pipeline_bubble_fraction`).

Under ``plan.compile`` this lowers to ONE donation-aware executable: the
scan carry (the activation buffer) is updated in place across ticks, each
slot pinned to its stage's mesh axis by the stage level's sharding
constraints, and the transfer is a collective-permute between stage shards.
``run_plan`` on the same plan is the eager bitwise oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro import core as drjax

__all__ = [
    "PipelineConfig",
    "make_pipelined_round",
    "pipeline_bubble_fraction",
]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Shape of the pipelined round.

    ``num_stages`` is the stage-kind placement's size S; ``num_microbatches``
    M is the number of microbatches fed through per round. ``stage_axes``
    optionally names the mesh axis the stage level pins (conventionally
    ``"stage"`` — see ``repro.launch.mesh.level_axes_for``)."""

    num_stages: int
    num_microbatches: int
    stage_axes: Any = None
    mesh: Any = None
    use_sharding_annotations: bool = True


def pipeline_bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Idle fraction of stage-ticks in the fill/drain schedule:
    ``(S - 1) / (M + S - 1)`` — the figure of merit ``benchmarks/pipeline``
    tracks (more microbatches -> smaller bubble)."""
    s, m = num_stages, num_microbatches
    if s < 1 or m < 1:
        raise ValueError("need num_stages >= 1 and num_microbatches >= 1")
    return (s - 1) / (m + s - 1)


def make_pipelined_round(
    stage_fns: Union[Callable, Sequence[Callable]],
    cfg: PipelineConfig,
    *,
    donate: bool = False,
):
    """Build ``round_fn(microbatches, act0) -> (outs, act_final)``.

    ``stage_fns`` is one callable (the same phase at every stage) or a
    sequence of ``num_stages`` callables (heterogeneous phases). Every phase
    must map an activation to an activation of the SAME shape/dtype — the
    carried buffer has one fixed slot per stage.

    ``microbatches`` leaves carry a leading ``(M,)`` microbatch axis;
    ``act0`` is the stage-partitioned activation buffer (leaves of shape
    ``(S,) + activation`` — zeros for a cold start). ``outs`` leaves are
    ``(M,) + activation``: microbatch m's activation after all S phases.
    Returning ``act_final`` keeps the buffer a scan carry end to end, so
    with ``donate=True`` the round is jitted with ``act0`` donated — the
    buffer is updated in place across rounds instead of copied (the round
    loop's analogue of the params donation rule in ``rounds.py``).

    When segmenting with ``build_plan``, pass ``partitioned_invars=(0, 1)``:
    the microbatch axis M is not a placement axis, so the shape heuristic
    would misread ``microbatches`` whenever M happens to equal S.
    """
    s = cfg.num_stages
    m = cfg.num_microbatches
    if s < 1 or m < 1:
        raise ValueError("need num_stages >= 1 and num_microbatches >= 1")
    if not callable(stage_fns):
        stage_fns = tuple(stage_fns)
        if len(stage_fns) != s:
            raise ValueError(
                f"got {len(stage_fns)} stage functions for "
                f"{s} stages (or pass a single callable)."
            )
    ticks = m + s - 1

    partition_axes = (
        {"stages": cfg.stage_axes} if cfg.stage_axes is not None else None
    )

    @drjax.program(
        placements={"stages": s},
        placement_kinds={"stages": "stages"},
        partition_axes=partition_axes,
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )
    def round_fn(microbatches, act0):
        def tick(act, t):
            mb = jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_index_in_dim(
                    x, jnp.minimum(t, m - 1), axis=0, keepdims=False
                ),
                microbatches,
            )
            act = jax.tree_util.tree_map(
                lambda a, v: a.at[0].set(v), act, mb
            )
            y = drjax.stage_map(stage_fns, act)
            out = jax.tree_util.tree_map(lambda x: x[s - 1], y)
            nxt = drjax.stage_transfer(y, shift=1)
            return nxt, out

        act_final, outs = jax.lax.scan(
            tick, act0, jnp.arange(ticks), length=ticks
        )
        # Ticks 0..S-2 drain fill garbage; microbatch m emerges at m + S - 1.
        outs = jax.tree_util.tree_map(lambda o: o[s - 1:], outs)
        return outs, act_final

    if donate:
        return jax.jit(round_fn, donate_argnums=(1,))
    return round_fn
