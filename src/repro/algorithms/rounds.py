"""MapReduce training rounds: local SGD / FedAvg / DiLoCo / FedSGD.

This is the paper's §4 workload, built verbatim from the building blocks:

    params_b = drjax.broadcast(global_params)           # server -> groups
    deltas   = drjax.map_fn(client_update, (params_b, round_data))
    delta    = drjax.reduce_mean(deltas)                # groups -> server
    params   = server_opt(global_params, delta)

``client_update`` runs ``num_local_steps`` optimizer steps on the group's
batches — model- and optimizer-agnostic (any ``loss_fn(params, batch)``).
Distribution: the partition axis shards over (pod, data); everything inside
``map_fn`` additionally uses the model's logical-axis annotations, so model
parallelism composes (paper: "shard computations over data partitions,
model, and within-data partitions simultaneously").

Options beyond the paper's baseline (all recorded in EXPERIMENTS.md §Perf):
 * straggler masks (over-provisioned cohorts, masked reduction);
 * delta compression (int8 with error-feedback) before the reduction;
 * weighted (FedAvg) and self-tuned (learned-weight) reductions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import core as drjax
from repro.compression import api as compression
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    partition_size: int
    num_local_steps: int = 4
    partition_axes: Any = None  # e.g. ("pod", "data") on the production mesh
    mesh: Any = None
    use_sharding_annotations: bool = True
    grad_clip: float = 0.0
    compression: Optional[str] = None  # None | "int8" | "topk"
    topk_fraction: float = 0.01
    straggler_mask: bool = False
    # Pod-hierarchical variants: number of slow-link domains. 0 = flat.
    # When > 0, partition_size counts clients PER POD and the program runs
    # under the nested {"pods": num_pods, "clients": partition_size} stack.
    num_pods: int = 0
    # Fused reduce+compress fast path for the hierarchical int8 aggregation:
    # None = auto (fuse when the compressor is recognized), False = force the
    # generic two-primitive composition, True = insist.
    fused_reduce: Optional[bool] = None


def _tree_sub(a, b):
    return jax.tree_util.tree_map(
        lambda x, y: (x.astype(jnp.float32) - y.astype(jnp.float32)), a, b
    )


def _hier_axes(cfg: LocalSGDConfig):
    """Per-placement mesh axes for the nested {pods, clients} stack.

    Accepts a mapping (passed through), a (pod, data, ...) tuple (outermost
    axis to pods, the rest to clients), or a single axis name (to clients —
    the larger dimension; pods stay logical)."""
    axes = cfg.partition_axes
    if axes is None:
        return None
    if isinstance(axes, dict):
        return axes
    if isinstance(axes, (tuple, list)) and len(axes) >= 2:
        rest = tuple(axes[1:])
        return {"pods": axes[0], "clients": rest if len(rest) > 1 else rest[0]}
    if isinstance(axes, (tuple, list)):
        axes = axes[0]
    return {"pods": None, "clients": axes}


def _make_client_update(loss_fn: Callable, client_opt: Optimizer,
                        cfg: LocalSGDConfig):
    """num_local_steps optimizer steps on one group's batches -> (delta, loss)."""

    def client_update(params0, client_data):
        opt_state = client_opt.init(params0)

        def one_step(carry, batch):
            params, opt_state = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if cfg.grad_clip:
                grads, _ = clip_by_global_norm(grads, cfg.grad_clip)
            updates, opt_state = client_opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return (params, opt_state), loss

        (params_new, _), losses = jax.lax.scan(
            one_step, (params0, opt_state), client_data
        )
        delta = _tree_sub(params_new, params0)
        if cfg.compression == "int8":
            delta = compression.int8_roundtrip(delta)
        elif cfg.compression == "topk":
            delta = compression.topk_sparsify(delta, cfg.topk_fraction)
        return delta, jnp.mean(losses)

    return client_update


def _maybe_donate(round_fn: Callable, donate: bool) -> Callable:
    """Donation rule for round functions (see ROADMAP "Compiled plan
    executor"): the carried state — params (arg 0) and server state (arg 1)
    — is donated so the hot round loop updates it in place instead of
    copying every round. Opt-in because a donated caller must rebind its
    inputs (the reference/bitwise tests reuse theirs)."""
    if not donate:
        return round_fn
    return jax.jit(round_fn, donate_argnums=(0, 1))


def make_local_sgd_round(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    cfg: LocalSGDConfig,
    *,
    donate: bool = False,
):
    """Returns round_fn(global_params, server_state, round_data[, mask]).

    ``round_data`` leaves have shape (n, num_local_steps, ...per-step batch).
    Returns (new_params, new_server_state, metrics). ``donate=True`` returns
    the round jitted with params/server_state donated (the hot-loop form).
    """
    client_update = _make_client_update(loss_fn, client_opt, cfg)

    @drjax.program(
        partition_size=cfg.partition_size,
        partition_axes=cfg.partition_axes,
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )
    def round_fn(global_params, server_state, round_data, mask=None):
        params_b = drjax.broadcast(global_params)
        deltas, losses = drjax.map_fn(client_update, (params_b, round_data))
        if cfg.straggler_mask and mask is not None:
            mean_delta = drjax.masked_reduce_mean(deltas, mask)
            mean_loss = drjax.masked_reduce_mean(losses, mask)
        else:
            mean_delta = drjax.reduce_mean(deltas)
            mean_loss = drjax.reduce_mean(losses)
        updates, new_server_state = server_opt.update(
            mean_delta, server_state, global_params
        )
        new_params = apply_updates(global_params, updates)
        metrics = {"loss": mean_loss}
        return new_params, new_server_state, metrics

    return _maybe_donate(round_fn, donate)


def make_hierarchical_local_sgd_round(
    loss_fn: Callable,
    client_opt: Optimizer,
    server_opt: Optimizer,
    cfg: LocalSGDConfig,
    *,
    donate: bool = False,
):
    """Pod-hierarchical local SGD: the nested-placement round (paper §6).

    Runs under the two-level stack ``{"pods": cfg.num_pods, "clients":
    cfg.partition_size}`` (``partition_size`` counts clients *per pod*).
    ``round_data`` leaves have shape (num_pods, clients_per_pod,
    num_local_steps, ...per-step batch); an optional straggler ``mask`` is
    (num_pods, clients_per_pod). The delta aggregation is the genuine
    two-stage reduction — ``reduce_mean@clients`` over ICI, then
    ``reduce_mean@pods`` over DCN, with ``cfg.compression`` (if set) applied
    to the per-pod partials that cross the slow leg — so the §5 plan of this
    round stages the aggregation as two placement-tagged shuffles.
    """
    if cfg.num_pods < 1:
        raise ValueError(
            "make_hierarchical_local_sgd_round needs cfg.num_pods >= 1"
        )
    # Where compression runs depends on the aggregation path. The masked
    # (straggler) reduction spans both levels in one weighted pass, so it
    # keeps the flat round's per-client compression; the unmasked path
    # compresses the pod PARTIALS instead — the value that actually crosses
    # the DCN leg — so the per-client leg runs uncompressed.
    client_cfg = (
        cfg if cfg.straggler_mask
        else dataclasses.replace(cfg, compression=None)
    )
    client_update = _make_client_update(loss_fn, client_opt, client_cfg)
    pod_compress = None
    if not cfg.straggler_mask:
        if cfg.compression == "int8":
            pod_compress = compression.int8_roundtrip
        elif cfg.compression == "topk":
            pod_compress = functools.partial(
                compression.topk_sparsify, fraction=cfg.topk_fraction
            )

    @drjax.program(
        placements={"pods": cfg.num_pods, "clients": cfg.partition_size},
        partition_axes=_hier_axes(cfg),
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )
    def round_fn(global_params, server_state, round_data, mask=None):
        params_b = drjax.broadcast(global_params)
        deltas, losses = drjax.map_fn(client_update, (params_b, round_data))
        if cfg.straggler_mask and mask is not None:
            mean_delta = drjax.masked_reduce_mean(deltas, mask)
            mean_loss = drjax.masked_reduce_mean(losses, mask)
        else:
            # Two-stage mean with the pod partials (the bytes that cross the
            # DCN leg) optionally compressed.
            mean_delta = drjax.hierarchical_reduce_mean(
                deltas, compress_fn=pod_compress, use_fused=cfg.fused_reduce
            )
            mean_loss = drjax.hierarchical_reduce_mean(losses)
        updates, new_server_state = server_opt.update(
            mean_delta, server_state, global_params
        )
        new_params = apply_updates(global_params, updates)
        metrics = {"loss": mean_loss}
        return new_params, new_server_state, metrics

    return _maybe_donate(round_fn, donate)


def make_multi_round(
    round_fn: Callable,
    num_rounds: int,
    *,
    jit: bool = False,
    donate: bool = True,
) -> Callable:
    """Stack ``num_rounds`` rounds of ``round_fn`` into one ``lax.scan``.

    ``round_fn`` is any ``(params, server_state, round_data) -> (params,
    server_state, metrics)`` round (e.g. from :func:`make_local_sgd_round`);
    ``all_data`` leaves carry a leading ``num_rounds`` axis. Because the scan
    body broadcasts and reduces every iteration, the §5 interpreter surfaces
    the trainer as a single ``LoopStage`` whose sub-plan makes the per-round
    communication explicit (one broadcast + one reduce per round) — the plan
    a federated/Beam backend would actually schedule.

    ``jit=True`` returns the trainer compiled, with the scan carry (params +
    server state) donated into the executable by default (``donate=False``
    to keep the caller's buffers alive): inside the scan XLA already updates
    the carry in place; donation extends that in-place discipline across the
    jit boundary, so N rounds trigger exactly one trace and zero carry
    copies (asserted in ``tests/test_executor.py``).
    """

    def trainer(params, server_state, all_data):
        def body(carry, round_data):
            params, server_state = carry
            params, server_state, metrics = round_fn(
                params, server_state, round_data
            )
            return (params, server_state), metrics

        (params, server_state), metrics = jax.lax.scan(
            body, (params, server_state), all_data, length=num_rounds
        )
        return params, server_state, metrics

    if jit:
        return jax.jit(trainer, donate_argnums=(0, 1) if donate else ())
    return trainer


def make_fedsgd_round(
    loss_fn: Callable,
    server_opt: Optimizer,
    cfg: LocalSGDConfig,
    *,
    learned_weights: bool = False,
):
    """Single-local-step gradient averaging (FedSGD).

    With ``learned_weights=True`` the reduction weights are a trainable
    input — the self-tuning reduction of paper §6 (gradients flow to the
    weights through MapReduce AD).
    """

    def client_grad(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return grads, loss

    @drjax.program(
        partition_size=cfg.partition_size,
        partition_axes=cfg.partition_axes,
        mesh=cfg.mesh,
        use_sharding_annotations=cfg.use_sharding_annotations,
    )
    def round_fn(global_params, server_state, batches, weights=None):
        params_b = drjax.broadcast(global_params)
        grads, losses = drjax.map_fn(client_grad, (params_b, batches))
        if learned_weights and weights is not None:
            w = jax.nn.softmax(weights) * cfg.partition_size
            mean_grad = drjax.reduce_weighted_mean(grads, w)
            mean_loss = drjax.reduce_weighted_mean(losses, w)
        else:
            mean_grad = drjax.reduce_mean(grads)
            mean_loss = drjax.reduce_mean(losses)
        neg = jax.tree_util.tree_map(lambda g: -g, mean_grad)
        updates, new_server_state = server_opt.update(
            neg, server_state, global_params
        )
        new_params = apply_updates(global_params, updates)
        return new_params, new_server_state, {"loss": mean_loss}

    return round_fn
