"""Branch-Train-Merge (Li et al. 2022) as a DrJAX program.

BTM trains one expert per data domain in parallel (*branch*, *train*) and
merges by parameter averaging (*merge*) — exactly a broadcast → map → reduce
round where the "local step count" is an entire training run. The paper lists
BTM among the algorithms expressible with its building blocks.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import core as drjax
from repro.optim.optimizers import Optimizer, apply_updates


def branch_train_merge(
    loss_fn: Callable,
    opt: Optimizer,
    partition_size: int,
    train_steps: int,
    *,
    merge: str = "mean",  # mean | weighted (by final loss)
    partition_axes: Any = None,
    mesh: Any = None,
):
    """Returns btm_fn(seed_params, domain_data) -> (merged_params, metrics).

    ``domain_data`` leaves: (n_domains, train_steps, ...batch). The merged
    model averages expert parameters; "weighted" uses softmax(-final_loss) —
    a differentiable merge (usable with MapReduce AD for merge tuning).
    """

    def train_expert(params, domain_batches):
        opt_state = opt.init(params)

        def step(carry, batch):
            p, s = carry
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            upd, s = opt.update(g, s, p)
            return (apply_updates(p, upd), s), loss

        (params, _), losses = jax.lax.scan(
            step, (params, opt_state), domain_batches
        )
        return params, losses[-1]

    @drjax.program(
        partition_size=partition_size, partition_axes=partition_axes, mesh=mesh
    )
    def btm_fn(seed_params, domain_data):
        branches = drjax.broadcast(seed_params)  # branch
        experts, final_losses = drjax.map_fn(
            train_expert, (branches, domain_data)
        )  # train
        if merge == "weighted":
            w = jax.nn.softmax(-final_losses) * partition_size
            merged = drjax.reduce_weighted_mean(experts, w)
        else:
            merged = drjax.reduce_mean(experts)  # merge
        return merged, {
            "mean_final_loss": drjax.reduce_mean(final_losses),
            "max_final_loss": drjax.reduce_max(final_losses),
        }

    return btm_fn
