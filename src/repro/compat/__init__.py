"""JAX version-portability layer.

One subsystem owns every JAX-version-sensitive surface the repo touches:
mesh construction, axis-type handling, ambient-mesh contexts, compiled-cost
analysis, and sharding-object helpers. The repo rule (see ROADMAP.md):

    No direct ``jax.sharding.AxisType`` / ``jax.make_mesh`` keyword probing /
    ``Compiled.cost_analysis`` shape handling outside ``repro/compat``.

Callers branch on capabilities (``compat.has("mesh_axis_types")``), never on
``jax.__version__``. Supported range: JAX 0.4.3x (list-shaped cost analysis,
no AxisType, ``with mesh:`` ambient contexts) through current releases
(dict cost analysis, AxisType, ``jax.set_mesh``); on older versions
new-API-only features degrade to their implicit equivalents.
"""

from .cost import (
    cost_analysis,
    cost_bytes_accessed,
    cost_flops,
    normalize_cost_analysis,
)
from .meshes import axis_type, make_mesh, set_mesh, shard_map
from .probes import capabilities, has, jax_version, reset_cache
from .shardings import (
    named_sharding,
    partition_spec,
    positional_sharding,
    replicated_sharding,
)

__all__ = [
    "axis_type",
    "capabilities",
    "cost_analysis",
    "cost_bytes_accessed",
    "cost_flops",
    "has",
    "jax_version",
    "make_mesh",
    "named_sharding",
    "normalize_cost_analysis",
    "partition_spec",
    "positional_sharding",
    "replicated_sharding",
    "reset_cache",
    "set_mesh",
    "shard_map",
]
