"""Sharding-object helpers, kept in one place so call sites survive JAX's
ongoing sharding-API churn (``PositionalSharding`` removal, ``NamedSharding``
constructor moves)."""

from __future__ import annotations

from typing import Sequence, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

from .probes import has

SpecLike = Union[PartitionSpec, Sequence, None]


def partition_spec(*axes) -> PartitionSpec:
    return PartitionSpec(*axes)


def named_sharding(mesh: jax.sharding.Mesh, spec: SpecLike = None) -> NamedSharding:
    """NamedSharding from a PartitionSpec or a plain axis sequence."""
    if spec is None:
        spec = PartitionSpec()
    elif not isinstance(spec, PartitionSpec):
        spec = PartitionSpec(*spec)
    return NamedSharding(mesh, spec)


def replicated_sharding(mesh: jax.sharding.Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def positional_sharding(devices):
    """``jax.sharding.PositionalSharding`` where it still exists; newer JAX
    removed it in favor of NamedSharding, so callers must gate on
    ``compat.has("positional_sharding")`` and provide a mesh-based path."""
    if not has("positional_sharding"):
        raise NotImplementedError(
            "this JAX has no PositionalSharding; build a mesh and use "
            "compat.named_sharding instead"
        )
    return jax.sharding.PositionalSharding(devices)
