"""Version-portable mesh construction and ambient-mesh contexts.

All mesh construction in this repo goes through :func:`make_mesh`; nothing
outside ``repro.compat`` may reference ``jax.sharding.AxisType`` or probe
``jax.make_mesh`` keywords.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Union

import jax

from .probes import has

# Axis-type names accepted by make_mesh (lowercase) -> enum member name.
_AXIS_TYPE_MEMBERS = {"auto": "Auto", "explicit": "Explicit", "manual": "Manual"}

AxisTypeLike = Union[str, object, None]


def axis_type(kind: str = "auto"):
    """Resolve ``jax.sharding.AxisType.<Kind>``; None when the enum is absent
    (pre-AxisType JAX, where every mesh axis behaves as Auto)."""
    member = _AXIS_TYPE_MEMBERS.get(str(kind).lower())
    if member is None:
        raise ValueError(
            f"unknown axis type {kind!r}; expected one of {sorted(_AXIS_TYPE_MEMBERS)}"
        )
    if not has("axis_type_enum"):
        return None
    return getattr(jax.sharding.AxisType, member)


def make_mesh(
    shape: Sequence[int],
    axes: Sequence[str],
    *,
    axis_types: Union[AxisTypeLike, Sequence[AxisTypeLike]] = "auto",
    devices=None,
) -> jax.sharding.Mesh:
    """Build a Mesh on any supported JAX version.

    ``axis_types`` accepts lowercase names ("auto" / "explicit" / "manual"),
    already-resolved enum members, a single value applied to every axis, or
    ``None``. On JAX versions without axis types the request is dropped:
    those versions have Auto-only semantics, which is what every current
    caller asks for. Falls back to ``Mesh(mesh_utils.create_device_mesh(...))``
    when ``jax.make_mesh`` itself is missing.
    """
    shape = tuple(shape)
    axes = tuple(axes)
    if has("make_mesh"):
        kwargs = {}
        if devices is not None:
            kwargs["devices"] = devices
        if axis_types is not None and has("mesh_axis_types"):
            if isinstance(axis_types, str) or not isinstance(
                axis_types, (tuple, list)
            ):
                axis_types = (axis_types,) * len(axes)
            kwargs["axis_types"] = tuple(
                axis_type(t) if isinstance(t, str) else t for t in axis_types
            )
        return jax.make_mesh(shape, axes, **kwargs)
    from jax.experimental import mesh_utils

    devs = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(devs, axes)


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = True):
    """Version-portable shard_map.

    Newer JAX exposes ``jax.shard_map`` with a ``check_vma`` flag; older
    releases have ``jax.experimental.shard_map.shard_map`` with the same flag
    named ``check_rep``. ``check`` maps onto whichever the installed version
    understands.
    """
    import inspect

    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        params = {}
    if "check_vma" in params:
        kwargs["check_vma"] = check
    elif "check_rep" in params:
        kwargs["check_rep"] = check
    elif not check:
        # Callers pass check=False when their body violates replication
        # checking (e.g. the int8 partial-sum collectives); silently running
        # with checking on would fail later with an opaque trace-time error.
        raise NotImplementedError(
            "this JAX's shard_map exposes neither check_vma nor check_rep; "
            "cannot honor check=False — teach repro.compat.shard_map its "
            "new flag name"
        )
    return fn(f, **kwargs)


@contextlib.contextmanager
def set_mesh(mesh: Optional[jax.sharding.Mesh]):
    """Ambient-mesh context across JAX versions.

    Prefers ``jax.set_mesh`` (0.6+), then ``jax.sharding.use_mesh`` (0.5.x),
    then the ``Mesh`` object's own context manager (0.4.x). ``mesh=None`` is
    a no-op so callers can write ``with compat.set_mesh(maybe_mesh): ...``.
    """
    if mesh is None:
        yield None
        return
    if has("set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    elif has("use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
