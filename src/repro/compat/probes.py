"""Capability probes for version-sensitive JAX surfaces.

Callers branch on *features* (``compat.has("mesh_axis_types")``), never on
``jax.__version__`` strings. A probe inspects the installed ``jax`` module
lazily the first time a feature is asked for and the verdict is cached;
``reset_cache()`` clears the cache so tests can monkeypatch ``jax`` to
simulate a newer/older API surface (see tests/test_compat.py).
"""

from __future__ import annotations

import inspect
from typing import Callable, Dict

import jax


def _probe_make_mesh() -> bool:
    """``jax.make_mesh`` (added 0.4.35; before that: mesh_utils + Mesh)."""
    return callable(getattr(jax, "make_mesh", None))


def _probe_axis_type_enum() -> bool:
    """``jax.sharding.AxisType`` (the Auto/Explicit/Manual enum, 0.5+)."""
    return hasattr(jax.sharding, "AxisType")


def _probe_mesh_axis_types() -> bool:
    """``jax.make_mesh(..., axis_types=...)`` keyword support."""
    if not (_probe_make_mesh() and _probe_axis_type_enum()):
        return False
    try:
        sig = inspect.signature(jax.make_mesh)
    except (TypeError, ValueError):
        return False
    return "axis_types" in sig.parameters


def _probe_set_mesh() -> bool:
    """``jax.set_mesh`` ambient-mesh context (0.6+)."""
    return callable(getattr(jax, "set_mesh", None))


def _probe_use_mesh() -> bool:
    """``jax.sharding.use_mesh`` ambient-mesh context (0.5.x)."""
    return callable(getattr(jax.sharding, "use_mesh", None))


def _probe_positional_sharding() -> bool:
    """``jax.sharding.PositionalSharding`` (removed in newer JAX)."""
    return hasattr(jax.sharding, "PositionalSharding")


_PROBES: Dict[str, Callable[[], bool]] = {
    "make_mesh": _probe_make_mesh,
    "axis_type_enum": _probe_axis_type_enum,
    "mesh_axis_types": _probe_mesh_axis_types,
    "set_mesh": _probe_set_mesh,
    "use_mesh": _probe_use_mesh,
    "positional_sharding": _probe_positional_sharding,
}

_CACHE: Dict[str, bool] = {}


def has(feature: str) -> bool:
    """True iff the installed JAX supports `feature` (see _PROBES keys)."""
    if feature not in _PROBES:
        raise KeyError(
            f"unknown compat feature {feature!r}; known: {sorted(_PROBES)}"
        )
    if feature not in _CACHE:
        _CACHE[feature] = bool(_PROBES[feature]())
    return _CACHE[feature]


def capabilities() -> Dict[str, bool]:
    """Full feature -> supported map for the installed JAX."""
    return {name: has(name) for name in sorted(_PROBES)}


def reset_cache() -> None:
    """Forget cached probe verdicts (tests monkeypatch jax, then reset)."""
    _CACHE.clear()


def jax_version() -> tuple:
    """Installed JAX version as an int tuple, for diagnostics only —
    feature decisions must go through ``has``."""
    parts = []
    for p in jax.__version__.split("."):
        digits = "".join(ch for ch in p if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)
