"""Version-portable compiled-program cost analysis.

``Compiled.cost_analysis`` changed shape across JAX versions: older releases
return a list with one properties-dict per HLO module, newer ones return the
dict directly. Everything in this repo reads costs through
:func:`cost_analysis`, which always yields a flat ``{metric: value}`` dict.
"""

from __future__ import annotations

from numbers import Number
from typing import Any, Dict, Optional


def normalize_cost_analysis(raw: Any) -> Dict[str, Any]:
    """Normalize a raw ``Compiled.cost_analysis`` result to one flat dict.

    dict -> copied as-is; list/tuple of dicts -> the single element, or a
    sum of numeric metrics when there are several modules; anything else
    (None, unexpected types) -> {}.
    """
    if isinstance(raw, dict):
        return dict(raw)
    if isinstance(raw, (list, tuple)):
        dicts = [d for d in raw if isinstance(d, dict)]
        if not dicts:
            return {}
        if len(dicts) == 1:
            return dict(dicts[0])
        merged: Dict[str, Any] = {}
        for d in dicts:
            for k, v in d.items():
                if isinstance(v, Number) and isinstance(
                    merged.get(k, 0.0), Number
                ):
                    merged[k] = merged.get(k, 0.0) + v
                else:
                    merged.setdefault(k, v)
        return merged
    return {}


def cost_analysis(compiled) -> Dict[str, Any]:
    """Flat cost dict for a compiled computation; {} when unavailable
    (some backends/versions raise instead of returning costs)."""
    try:
        raw = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 - unsupported backend == no costs
        return {}
    return normalize_cost_analysis(raw)


def cost_flops(compiled) -> float:
    return float(cost_analysis(compiled).get("flops", 0.0))


def cost_bytes_accessed(compiled) -> Optional[float]:
    """Total "bytes accessed" of a compiled program, or ``None``.

    ``None`` means the backend reports no cost model (or no such metric) —
    distinct from a genuine 0.0 measurement. Callers that previously relied
    on the silent-0.0 behavior must decide: treat ``None`` as "unavailable"
    (skip/annotate), never as "zero traffic".
    """
    value = cost_analysis(compiled).get("bytes accessed")
    return None if value is None else float(value)
