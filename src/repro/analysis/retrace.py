"""Retrace-hazard detector: fingerprint-unstable captures, found statically.

The executable cache keys on ``plan_fingerprint`` — which hashes, among the
structural components, the **values** of every captured const (see
``runtime/executor.py``). That is what makes the cache sound (two plans
with different baked-in constants must not share an executable), but it is
also the zero-retrace invariant's silent killer: a Python scalar that gets
closed over instead of passed as an input folds into the consts, varies per
call, and turns every round into a fingerprint miss → full retrace.

This pass walks every captured const and cache-key input of a plan (and all
sub-plans) and flags:

* ``retrace/object-const`` (error) — a const with object dtype; its bytes
  are id-dependent, so the fingerprint differs across *identical* values;
* ``retrace/unstable-const`` (warning) — a 0-d/1-element const: the classic
  round counter / learning rate folded into the trace. If it varies per
  call, every call recompiles; pass it as a plan input instead;
* ``retrace/large-const`` (info) — a const above 1 MiB: fingerprinting
  hashes its full bytes every ``plan.compile`` and the value is baked into
  the executable (it should probably be an input);
* ``retrace/weak-type-input`` (info) — a weak-typed plan input: the aval
  cache key includes ``weak_type``, so alternating Python scalars and
  arrays at the same position doubles the executable cache;
* ``retrace/mesh-keyed-leg`` (warning, needs ``donate_argnums``) — a
  donated executable spanning >= 2 replica placement levels: its cache key
  includes a mesh that elastic events (pod dropout/regrowth) resize, and
  donated inputs cannot be replayed on the new mesh — split the round so
  only the small cross-pod leg is donated (the elastic split).

:func:`explain_fingerprint_mismatch` is the differential half: given two
plans that *should* share an executable but do not, it pinpoints which
fingerprint component (or exactly which const) differs.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import interpreter as interp

from .findings import Finding

_LARGE_CONST_BYTES = 1 << 20


def analyze_retrace(plan, donate_argnums=()) -> List[Finding]:
    findings: List[Finding] = []
    # A donated executable on a MULTI-level replica stack is keyed by a mesh
    # that elastic events resize: donation invalidates the inputs, so after
    # a pod dropout the old-mesh executable can neither be re-used nor its
    # arguments replayed. The elastic split (runtime/executor.py:
    # ElasticHierarchicalRound) exists for exactly this — donate only the
    # small cross-pod leg and let it re-key per (avals, mesh).
    n_replica_levels = sum(
        1 for k in plan.placement_kinds if k != "stages"
    )
    if donate_argnums and n_replica_levels >= 2:
        findings.append(Finding(
            "retrace/mesh-keyed-leg", "warning",
            f"plan donates argnums {tuple(donate_argnums)} but spans "
            f"{n_replica_levels} replica placement levels: its executable "
            f"is keyed by a mesh that elastic events (pod dropout/regrowth) "
            f"can change, and donated buffers cannot be replayed on the new "
            f"mesh — split the round so only the cross-pod leg is donated "
            f"(see runtime.elastic.make_elastic_hierarchical_round)",
        ))
    for pi, p in enumerate(interp._all_plans(plan)):
        where = "top-level plan" if pi == 0 else f"sub-plan {pi}"
        for ci, (atom, val) in enumerate(p.const_env().items()):
            arr = np.asarray(val)
            label = f"const {ci} of the {where} ({arr.dtype}{list(arr.shape)})"
            if arr.dtype == object:
                findings.append(Finding(
                    "retrace/object-const", "error",
                    f"{label} has object dtype: its fingerprint bytes are "
                    f"identity-dependent, so structurally identical plans "
                    f"never share an executable",
                ))
                continue
            if arr.size <= 1:
                findings.append(Finding(
                    "retrace/unstable-const", "warning",
                    f"{label} is a scalar folded into the captured consts "
                    f"(value {arr.reshape(-1)[0] if arr.size else '<empty>'})"
                    f": plan_fingerprint hashes const VALUES, so if this "
                    f"varies per call every call misses the executable "
                    f"cache and retraces — pass it as a plan input instead",
                ))
            elif arr.nbytes > _LARGE_CONST_BYTES:
                findings.append(Finding(
                    "retrace/large-const", "info",
                    f"{label} is {arr.nbytes} bytes: fingerprinting hashes "
                    f"it on every compile and the value is baked into the "
                    f"executable; consider passing it as a plan input",
                ))
    for i, v in enumerate(plan.jaxpr.jaxpr.invars):
        if bool(getattr(v.aval, "weak_type", False)):
            findings.append(Finding(
                "retrace/weak-type-input", "info",
                f"plan input {i} is weak-typed: the executable cache key "
                f"includes weak_type, so mixing Python scalars and arrays "
                f"at this position across calls splits the cache",
            ))
    return findings


def explain_fingerprint_mismatch(plan_a, plan_b) -> List[str]:
    """Why do two plans not share an executable? One line per difference.

    Compares the plans component by component using the same decomposition
    ``plan_fingerprint`` hashes (``runtime.executor.fingerprint_parts``),
    then drills into the consts pairwise so a fingerprint-unstable capture
    is named precisely. Returns ``[]`` iff the fingerprints are equal.
    """
    from repro.runtime import executor  # lazy: analysis must not need jit

    parts_a = dict(executor.fingerprint_components(plan_a))
    parts_b = dict(executor.fingerprint_components(plan_b))
    diffs: List[str] = []
    structural = [k for k in parts_a if not k.startswith("const[")]
    for k in structural:
        if parts_a.get(k) != parts_b.get(k):
            diffs.append(f"component {k!r} differs")
    consts_a = _flat_consts(plan_a)
    consts_b = _flat_consts(plan_b)
    if len(consts_a) != len(consts_b):
        diffs.append(
            f"captured const count differs: {len(consts_a)} vs "
            f"{len(consts_b)}"
        )
    for i, ((aa, va), (ab, vb)) in enumerate(zip(consts_a, consts_b)):
        arr_a, arr_b = np.asarray(va), np.asarray(vb)
        if str(aa.aval) != str(ab.aval) or arr_a.shape != arr_b.shape or (
            arr_a.dtype != arr_b.dtype
        ):
            diffs.append(
                f"const[{i}] aval differs: {aa.aval} vs {ab.aval}"
            )
        elif arr_a.tobytes() != arr_b.tobytes():
            if arr_a.size <= 4:
                diffs.append(
                    f"const[{i}] ({arr_a.dtype}{list(arr_a.shape)}) VALUE "
                    f"differs: {arr_a.tolist()} vs {arr_b.tolist()} — a "
                    f"fingerprint-unstable capture; pass it as a plan input"
                )
            else:
                diffs.append(
                    f"const[{i}] ({arr_a.dtype}{list(arr_a.shape)}) value "
                    f"bytes differ — a fingerprint-unstable capture"
                )
    return diffs


def _flat_consts(plan):
    out = []
    for p in interp._all_plans(plan):
        out.extend(p.const_env().items())
    return out
