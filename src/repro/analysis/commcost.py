"""Communication-cost pass: per-stage wire bytes derived from the plan IR.

Replaces the napkin ``cross_pod_bytes`` spreadsheet model with numbers read
off the IR itself. For every Broadcast/Reduce/Transfer stage the pass
derives, from the eqn's operand/output avals and its placement params:

* the **link**: the eqn's addressed stack index splits the fabric — level 0
  (outermost, e.g. ``pods``) crosses the slow DCN leg, deeper levels ride
  ICI within a pod;
* the **endpoint count**: a reduce at index i collects from
  ``prod(shape[:i+1])`` groups, a broadcast at index i fans out to
  ``prod(shape[:i+1])`` destinations;
* the **per-endpoint payload** in actual wire format: a reduce tagged
  ``compress="int8"`` (the fused reduce+compress fast path) marks its
  output as int8-on-the-wire, so the next comm stage over that value
  counts ``1 byte/value + one f32 scale per PACK_COLS(=256)-block`` —
  exactly the packed wire format ``repro.compression`` ships — instead of
  the f32 nbytes. (The *unfused* roundtrip materializes f32 in the IR, so
  the IR-derived cost is honestly f32 there: compression that is invisible
  in the IR is invisible to a static pass.)

Loop stages multiply their body's (and ``while`` predicate's) costs by the
trip count; a data-dependent ``while`` counts one trip and raises an
``unknown-trip`` flag. Cond stages contribute their *most expensive*
branch to the totals (a static upper bound); every branch's stages are
still itemized, with ``counted=False`` on the losers.

:func:`cross_validate` closes the loop against the compiled program: each
plain (uncompressed) Reduce eqn is jitted standalone and its modeled
operand+output bytes compared with ``compat.cost_analysis``'s
parameter-0 accounting (``bytes accessed0{}``), within a tolerance. The
``model_scale`` knob exists for fault injection in tests — scaling the
model away from 1.0 must produce a mismatch finding, proving the check
can actually fail.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import interpreter as interp
from repro.core.interpreter import (
    Broadcast,
    CondStage,
    LoopStage,
    Reduce,
    Transfer,
    _eqn_placement,
    _is_dropvar,
    _is_literal,
)

from .findings import Finding

# One f32 scale per this many int8 values (repro.compression.PACK_COLS);
# duplicated as a plain int so the cost pass stays importable without the
# compression stack, and pinned to it in tests/test_analysis.py.
INT8_BLOCK = 256


def int8_wire_payload(values: int, block: int = INT8_BLOCK) -> float:
    """Wire bytes of ``values`` f32 numbers in the packed int8 format."""
    return values * 1.0 + math.ceil(values / block) * 4.0


@dataclasses.dataclass
class CommStageCost:
    stage: str  # named_stages anchor
    kind: str  # BROADCAST | REDUCE | TRANSFER
    op: str  # broadcast | reduce_sum | reduce_mean | reduce_max
    placement: str  # addressed placement name
    link: str  # "dcn" (outermost level) | "ici" (inner levels)
    endpoints: int  # senders (reduce) / receivers (broadcast)
    payload_bytes: float  # per-endpoint wire payload
    wire_format: str  # "native" | "int8+scales"
    multiplier: float  # loop-trip multiplier applied
    wire_bytes: float  # endpoints * payload * multiplier
    counted: bool = True  # False: a non-max cond branch (itemized only)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CommCostReport:
    per_stage: List[CommStageCost]
    dcn_bytes: float
    ici_bytes: float
    unknown_trips: bool
    findings: List[Finding] = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return self.dcn_bytes + self.ici_bytes

    def to_dict(self) -> Dict[str, Any]:
        return {
            "dcn_bytes": self.dcn_bytes,
            "ici_bytes": self.ici_bytes,
            "total_bytes": self.total_bytes,
            "unknown_trips": self.unknown_trips,
            "per_stage": [c.to_dict() for c in self.per_stage],
        }


def _nbytes(aval, start: int = 0) -> Tuple[int, float]:
    """(element count, native bytes) of ``aval.shape[start:]``."""
    values = int(np.prod(aval.shape[start:], dtype=np.int64))
    return values, values * np.dtype(aval.dtype).itemsize


def estimate_comm_cost(plan) -> CommCostReport:
    """Static per-stage wire bytes for a plan (recursive, trip-multiplied)."""
    per_stage: List[CommStageCost] = []
    findings: List[Finding] = []
    state = {"unknown": False}
    dcn, ici = _walk(plan, "", 1.0, True, per_stage, findings, state)
    return CommCostReport(
        per_stage=per_stage,
        dcn_bytes=dcn,
        ici_bytes=ici,
        unknown_trips=state["unknown"],
        findings=findings,
    )


def _walk(
    plan, prefix: str, mult: float, counted: bool,
    per_stage: List[CommStageCost], findings: List[Finding], state,
) -> Tuple[float, float]:
    dcn = ici = 0.0
    # wire format of values within THIS plan: outputs of compress-tagged
    # reduces are int8+scales until local compute touches them again.
    fmt: Dict[Any, str] = {}
    for idx, stage in enumerate(plan.stages):
        sname = f"stage_{prefix}{idx}"
        if isinstance(stage, (Broadcast, Reduce, Transfer)):
            cost = _comm_cost(stage, sname, mult, counted, fmt)
            per_stage.append(cost)
            if cost.counted:
                if cost.link == "dcn":
                    dcn += cost.wire_bytes
                else:
                    ici += cost.wire_bytes
        elif isinstance(stage, LoopStage):
            trip = stage.trip_count
            if trip is None:
                state["unknown"] = True
                findings.append(Finding(
                    "commcost/unknown-trip", "info",
                    "while-loop trip count is data-dependent; its body and "
                    "predicate are counted once (scale externally by the "
                    "expected iteration count)",
                    stage=sname,
                ))
                m2 = mult
            else:
                m2 = mult * trip
            if stage.cond_plan is not None:
                d, i = _walk(
                    stage.cond_plan, f"{prefix}{idx}_c_", m2, counted,
                    per_stage, findings, state,
                )
                dcn += d
                ici += i
            d, i = _walk(
                stage.body_plan, f"{prefix}{idx}_", m2, counted,
                per_stage, findings, state,
            )
            dcn += d
            ici += i
        elif isinstance(stage, CondStage):
            branch_totals = []
            marks = []
            for b, bp in enumerate(stage.branch_plans):
                start = len(per_stage)
                d, i = _walk(
                    bp, f"{prefix}{idx}_b{b}_", mult, counted,
                    per_stage, findings, state,
                )
                branch_totals.append((d, i))
                marks.append((start, len(per_stage)))
            if branch_totals:
                best = max(
                    range(len(branch_totals)),
                    key=lambda b: sum(branch_totals[b]),
                )
                dcn += branch_totals[best][0]
                ici += branch_totals[best][1]
                for b, (lo, hi) in enumerate(marks):
                    if b != best:
                        for c in per_stage[lo:hi]:
                            c.counted = False
    return dcn, ici


def _comm_cost(stage, sname: str, mult: float, counted: bool, fmt) -> CommStageCost:
    eqn = stage.eqn
    enames, i = _eqn_placement(eqn)
    link = "dcn" if i == 0 else "ici"
    if isinstance(stage, Transfer):
        # Stage-to-stage activation hand-off: each stage ships its slot to
        # its neighbor over ICI (the collective-permute the lowering emits),
        # regardless of where the stage level sits in the stack. Non-wrap
        # boundary stages send nothing (their payload is zero-filled
        # locally), so |shift| stages per outer group drop out of the
        # endpoint count; a wrap (ring) transfer keeps every stage busy.
        aval = eqn.invars[0].aval
        size = aval.shape[i]
        shift = abs(int(eqn.params.get("shift", 1)))
        wrap = bool(eqn.params.get("wrap", False))
        outer = int(np.prod(aval.shape[:i], dtype=np.int64))
        senders = size if wrap else max(size - min(shift, size), 0)
        endpoints = outer * senders
        _values, native = _nbytes(aval, i + 1)
        return CommStageCost(
            stage=sname,
            kind="TRANSFER",
            op="stage_transfer",
            placement=stage.placement,
            link="ici",
            endpoints=endpoints,
            payload_bytes=float(native),
            wire_format="native",
            multiplier=mult,
            wire_bytes=endpoints * float(native) * mult,
            counted=counted,
        )
    if isinstance(stage, Reduce):
        aval = eqn.invars[0].aval
        endpoints = int(np.prod(aval.shape[: i + 1], dtype=np.int64))
        values, native = _nbytes(aval, i + 1)
        operand = eqn.invars[0]
        wire_format = (
            "int8+scales"
            if not _is_literal(operand) and fmt.get(operand) == "int8+scales"
            else "native"
        )
        payload = (
            int8_wire_payload(values)
            if wire_format == "int8+scales"
            else float(native)
        )
        out_fmt = (
            "int8+scales"
            if eqn.params.get("compress") == "int8"
            else None
        )
        for o in eqn.outvars:
            if not _is_dropvar(o) and out_fmt:
                fmt[o] = out_fmt
        kind, op = "REDUCE", stage.op
    else:  # Broadcast
        aval = eqn.outvars[0].aval
        endpoints = int(np.prod(aval.shape[: i + 1], dtype=np.int64))
        values, native = _nbytes(aval, i + 1)
        operand = eqn.invars[0]
        wire_format = (
            "int8+scales"
            if not _is_literal(operand) and fmt.get(operand) == "int8+scales"
            else "native"
        )
        payload = (
            int8_wire_payload(values)
            if wire_format == "int8+scales"
            else float(native)
        )
        kind, op = "BROADCAST", "broadcast"
    return CommStageCost(
        stage=sname,
        kind=kind,
        op=op,
        placement=stage.placement,
        link=link,
        endpoints=endpoints,
        payload_bytes=payload,
        wire_format=wire_format,
        multiplier=mult,
        wire_bytes=endpoints * payload * mult,
        counted=counted,
    )


def cross_validate(
    plan, *, tol: float = 0.05, model_scale: float = 1.0,
) -> List[Finding]:
    """Check the modeled geometry against the compiled program's costs.

    Every plain (uncompressed) Reduce eqn is jitted standalone; the XLA
    cost model attributes ``operand bytes + output bytes`` to parameter 0
    of a lone reduce, which must match the modeled ``endpoints * payload +
    output nbytes`` within ``tol``. Compressed reduces are excluded — their
    lowering contains quantization machinery whose memory accounting is not
    a wire model (they are pinned against the packed wire format math in
    tests instead). ``model_scale`` multiplies the modeled side; anything
    but 1.0 is fault injection for testing the check itself.

    Emits ``commcost/model-mismatch`` (error) per failing stage, or one
    ``commcost/no-cost-model`` (info) when the backend reports no costs.
    """
    import jax
    import jax.numpy as jnp

    from repro import compat

    findings: List[Finding] = []
    candidates = 0
    saw_cost_model = False
    for name, stage, _owner in plan.named_stages():
        if not isinstance(stage, Reduce):
            continue
        if stage.eqn.params.get("compress") is not None:
            continue
        eqn = stage.eqn
        aval = eqn.invars[0].aval
        out_aval = eqn.outvars[0].aval
        prim = eqn.primitive
        subfuns, bind_params = prim.get_bind_params(dict(eqn.params))

        def fn(v, _subfuns=subfuns, _prim=prim, _params=bind_params):
            return _prim.bind(*_subfuns, v, **_params)

        x = jnp.zeros(aval.shape, aval.dtype)
        compiled = jax.jit(fn).lower(x).compile()
        cost = compat.cost_analysis(compiled)
        candidates += 1
        measured = cost.get("bytes accessed0{}")
        if measured is None:
            continue
        saw_cost_model = True
        _, in_bytes = _nbytes(aval)
        _, out_bytes = _nbytes(out_aval)
        modeled = (in_bytes + out_bytes) * model_scale
        rel = abs(modeled - float(measured)) / max(float(measured), 1.0)
        if rel > tol:
            findings.append(Finding(
                "commcost/model-mismatch", "error",
                f"{stage.op}@{stage.placement}: modeled "
                f"{modeled:.0f} bytes vs {float(measured):.0f} from "
                f"compat.cost_analysis ({rel * 100:.1f}% off, tolerance "
                f"{tol * 100:.0f}%)",
                stage=name,
            ))
    if candidates and not saw_cost_model:
        findings.append(Finding(
            "commcost/no-cost-model", "info",
            "backend reports no cost model; comm-cost cross-validation "
            "skipped",
        ))
    return findings
