"""Unified repo lint registry (``scripts/lint.py`` is the CLI).

One registry for every repo-convention check that used to live as ad-hoc
shell in ``scripts/run_tests.sh``:

* ``compat-surface`` — the ROADMAP compat rule: no version-sensitive JAX
  surface outside ``repro/compat``. Byte-for-byte the same match/filter as
  the historical inline grep, so absorbing it changes no behavior.
* ``donate-jit`` — the donation rule (``scripts/check_donation.py`` is now
  a thin shim over this rule): every ``jax.jit`` in the hot layers donates
  its carried state or carries a ``# no-donate: <reason>`` marker.
* ``no-version-branch`` — no raw ``jax.__version__`` checks outside
  ``repro/compat``; version sniffing belongs in a compat probe.
* ``jit-of-plan`` — compiled plan execution has exactly one home
  (``runtime/executor.py``): no ``jax.jit`` in the ``core`` plan/
  interpreter layer, and no jitting of ``run_plan``/``stage_fns`` stages
  anywhere else — use ``plan.compile()`` so the executable cache,
  fingerprinting and donation plumbing apply.
* ``mesh-axes-literal`` — mesh axis-name tuples have exactly one home
  (``launch/mesh.py``): no hard-coded ``("pod", "data")``-style tuples
  elsewhere in ``src/`` — import ``REPLICA_AXES`` / use the mesh helpers,
  so N-level mesh factorization changes land in one file.

Suppression: append ``# lint: disable=<rule>`` (comma-separated for
several rules) to the flagged line or the line above it. ``donate-jit``
additionally keeps its own richer ``# no-donate: <reason>`` marker, which
documents *why* — prefer it for that rule.

This module is deliberately import-light (stdlib + ``findings`` only): the
lint CLI must run without loading JAX.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Callable, Dict, List, Optional, Sequence

# The compat patterns are assembled (not written literally) so this file
# does not flag itself: the rule matches raw substrings anywhere in a line.
_COMPAT_PATTERNS = ("Axis" + "Type", "cost_" + "analysis()")

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=([\w\-, ]+)")

DONATE_KEYWORDS = {"donate_argnums", "donate_argnames"}
NO_DONATE_MARKER = "# no-donate:"


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str  # repo-relative, '/'-separated
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class LintRule:
    name: str
    description: str
    check: Callable[[str], List[LintViolation]]  # repo root -> violations


RULES: Dict[str, LintRule] = {}


def rule(name: str, description: str):
    def register(fn):
        RULES[name] = LintRule(name=name, description=description, check=fn)
        return fn

    return register


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def _py_files(*dirs: str) -> List[str]:
    out = []
    for d in dirs:
        for dirpath, _dirnames, filenames in os.walk(d):
            for name in sorted(filenames):
                if name.endswith(".py"):
                    out.append(os.path.join(dirpath, name))
    return sorted(out)


def _rel(path: str, root: str) -> str:
    return os.path.relpath(path, root).replace(os.sep, "/")


def _suppressed(lines: List[str], lineno: int, rule_name: str) -> bool:
    """``# lint: disable=<rule>`` on the flagged line or the line above."""
    for ln in (lineno - 1, lineno - 2):
        if 0 <= ln < len(lines):
            m = _SUPPRESS_RE.search(lines[ln])
            if m and rule_name in [p.strip() for p in m.group(1).split(",")]:
                return True
    return False


def run_lints(
    root: Optional[str] = None, rules: Optional[Sequence[str]] = None,
) -> List[LintViolation]:
    """Run the registry (all rules, or a subset) and filter suppressions."""
    root = root or repo_root()
    names = list(rules) if rules is not None else sorted(RULES)
    unknown = [n for n in names if n not in RULES]
    if unknown:
        raise KeyError(f"unknown lint rule(s): {unknown}; have {sorted(RULES)}")
    violations: List[LintViolation] = []
    line_cache: Dict[str, List[str]] = {}
    for name in names:
        for v in RULES[name].check(root):
            path = os.path.join(root, v.path)
            if path not in line_cache:
                try:
                    with open(path) as fh:
                        line_cache[path] = fh.read().splitlines()
                except OSError:
                    line_cache[path] = []
            if not _suppressed(line_cache[path], v.line, v.rule):
                violations.append(v)
    return violations


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


@rule(
    "compat-surface",
    "no version-sensitive JAX API outside repro/compat (ROADMAP compat rule)",
)
def _compat_surface(root: str) -> List[LintViolation]:
    # Reproduces the historical run_tests.sh grep exactly: match the raw
    # substrings in any src/**/*.py line; drop a match when the grep-style
    # "path:line:content" haystack contains "compat" anywhere.
    out: List[LintViolation] = []
    for path in _py_files(os.path.join(root, "src")):
        rel = _rel(path, root)
        with open(path) as fh:
            for lineno, line in enumerate(fh.read().splitlines(), 1):
                if not any(p in line for p in _COMPAT_PATTERNS):
                    continue
                if "compat" in f"{rel}:{lineno}:{line}":
                    continue
                out.append(LintViolation(
                    rule="compat-surface", path=rel, line=lineno,
                    message=(
                        "version-sensitive JAX API used outside "
                        f"repro/compat: {line.strip()}"
                    ),
                ))
    return out


def _is_jax_jit(call: ast.Call) -> bool:
    f = call.func
    return (
        isinstance(f, ast.Attribute)
        and f.attr == "jit"
        and isinstance(f.value, ast.Name)
        and f.value.id == "jax"
    )


@rule(
    "donate-jit",
    "every jax.jit in src/repro/{algorithms,launch} donates its carried "
    "state or carries a '# no-donate: <reason>' marker",
)
def _donate_jit(root: str) -> List[LintViolation]:
    out: List[LintViolation] = []
    scan = (
        os.path.join(root, "src", "repro", "algorithms"),
        os.path.join(root, "src", "repro", "launch"),
    )
    for path in _py_files(*scan):
        rel = _rel(path, root)
        with open(path) as fh:
            src = fh.read()
        lines = src.splitlines()
        for node in ast.walk(ast.parse(src, filename=path)):
            if not (isinstance(node, ast.Call) and _is_jax_jit(node)):
                continue
            if any(kw.arg in DONATE_KEYWORDS for kw in node.keywords):
                continue
            # opt-out marker on the call line or the line above it
            lo = max(node.lineno - 2, 0)
            hi = min(node.end_lineno, len(lines))
            if any(NO_DONATE_MARKER in ln for ln in lines[lo:hi]):
                continue
            out.append(LintViolation(
                rule="donate-jit", path=rel, line=node.lineno,
                message=(
                    "jax.jit without donate_argnums — donate the carried "
                    "state, or mark the call with "
                    f"'{NO_DONATE_MARKER} <reason>' if no arg is "
                    "round-to-round state"
                ),
            ))
    return out


@rule(
    "no-version-branch",
    "no raw jax.__version__ checks outside repro/compat (use a compat probe)",
)
def _no_version_branch(root: str) -> List[LintViolation]:
    out: List[LintViolation] = []
    for path in _py_files(os.path.join(root, "src")):
        rel = _rel(path, root)
        if "/compat/" in rel:
            continue
        with open(path) as fh:
            src = fh.read()
        for node in ast.walk(ast.parse(src, filename=path)):
            if (
                isinstance(node, ast.Attribute)
                and node.attr == "__version__"
                and isinstance(node.value, ast.Name)
                and node.value.id == "jax"
            ):
                out.append(LintViolation(
                    rule="no-version-branch", path=rel, line=node.lineno,
                    message=(
                        "raw jax.__version__ branch outside repro/compat — "
                        "version sniffing belongs in a repro.compat probe"
                    ),
                ))
    return out


# Assembled via frozenset (an ast.Set in this file, never an ast.Tuple) so
# the rule's own definition cannot flag itself.
_MESH_AXIS_NAMES = frozenset({"pod", "data", "superpod", "stage", "model"})
_MESH_AXES_HOME = "src/repro/launch/mesh.py"


@rule(
    "mesh-axes-literal",
    "no hard-coded mesh axis-name tuples (e.g. a pod/data pair) outside "
    "launch/mesh.py — import REPLICA_AXES or use the mesh helpers",
)
def _mesh_axes_literal(root: str) -> List[LintViolation]:
    out: List[LintViolation] = []
    for path in _py_files(os.path.join(root, "src")):
        rel = _rel(path, root)
        if rel == _MESH_AXES_HOME:
            continue
        with open(path) as fh:
            src = fh.read()
        for node in ast.walk(ast.parse(src, filename=path)):
            if not isinstance(node, (ast.Tuple, ast.List)):
                continue
            if len(node.elts) < 2:
                continue
            if not all(
                isinstance(e, ast.Constant)
                and isinstance(e.value, str)
                and e.value in _MESH_AXIS_NAMES
                for e in node.elts
            ):
                continue
            names = tuple(e.value for e in node.elts)  # type: ignore[union-attr]
            out.append(LintViolation(
                rule="mesh-axes-literal", path=rel, line=node.lineno,
                message=(
                    f"hard-coded mesh axis tuple {names} — mesh axis-name "
                    "tuples live in launch/mesh.py (import REPLICA_AXES or "
                    "use level_axes_for/partition_axes_for)"
                ),
            ))
    return out


_PLAN_STAGE_NAMES = ("run_plan", "stage_fns")


@rule(
    "jit-of-plan",
    "no jax.jit in the core plan layer, and no jitting of plan stages "
    "(run_plan/stage_fns) outside runtime/executor.py — use plan.compile()",
)
def _jit_of_plan(root: str) -> List[LintViolation]:
    out: List[LintViolation] = []
    for path in _py_files(os.path.join(root, "src", "repro")):
        rel = _rel(path, root)
        if rel == "src/repro/runtime/executor.py":
            continue
        in_core = rel.startswith("src/repro/core/")
        with open(path) as fh:
            src = fh.read()
        for node in ast.walk(ast.parse(src, filename=path)):
            if not (isinstance(node, ast.Call) and _is_jax_jit(node)):
                continue
            args_src = " ".join(
                ast.unparse(a) for a in list(node.args) + [
                    kw.value for kw in node.keywords
                ]
            )
            jits_stage = any(n in args_src for n in _PLAN_STAGE_NAMES)
            if in_core:
                out.append(LintViolation(
                    rule="jit-of-plan", path=rel, line=node.lineno,
                    message=(
                        "jax.jit in the core plan/interpreter layer — "
                        "compiled plan execution lives in "
                        "runtime/executor.py (plan.compile())"
                    ),
                ))
            elif jits_stage:
                out.append(LintViolation(
                    rule="jit-of-plan", path=rel, line=node.lineno,
                    message=(
                        "jitting a plan stage outside runtime/executor.py — "
                        "use plan.compile() so the executable cache, "
                        "fingerprinting and donation plumbing apply"
                    ),
                ))
    return out
