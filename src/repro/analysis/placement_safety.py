"""Placement-safety verifier: the full static pass behind ``check_locality``.

``plan.check_locality()`` asserts one invariant (no communication primitive
hidden inside a LocalCompute stage). This pass re-propagates the placement
lattice over the *whole* plan — every stage, at every nesting depth,
including ``CondStage`` branches and a ``while``'s predicate ``cond_plan`` —
and verifies:

* **comm-free local stages** (the ``check_locality`` invariant, reported as
  a finding instead of an assertion, so one run surfaces every violation);
* **lattice monotonicity**: a Broadcast moves its operand exactly one level
  *down* the placement stack (depth i → i+1) and a Reduce exactly one level
  *up* (depth i+1 → i); re-broadcasting a level a value already carries, or
  reducing an outer level of a deeper value, leaves the stack-prefix
  lattice and is an error (``build_plan`` raises on these at construction —
  the pass re-derives them so hand-assembled or mutated plans are covered);
* **broadcast/reduce placement pairing**: ``Broadcast.source`` /
  ``Reduce.dest`` must name the addressed level's parent (``"server"`` at
  the outermost level). MapReduce AD transposes a broadcast into a reduce
  *at the same level* and vice versa, so a mispaired stage would transpose
  into communication on the wrong link — checking the pairing statically
  checks AD transposability ahead of ``jax.grad``;
* **placement-kind agreement**: broadcast/reduce may only address a
  *replica*-kind level and a stage transfer only a *stage*-kind level
  (``placement/wrong-kind-comm``) — the abstract eval rejects these at
  trace time, so a violation here means the plan was hand-assembled or
  mutated; a ``Transfer`` additionally gets the operand-depth and
  stage-tag pairing checks of the other comm stages (its AD transpose is
  the reverse transfer at the SAME level, so the pairing check again
  guards transposability);
* **loop-carry stability**: a loop carry's body-output placement may not
  sit deeper on the lattice than its body-input placement (``build_plan``
  solves carries to a fixed point; instability here means the plan was
  edited after construction and the loop would migrate values per
  iteration);
* a ``while`` predicate that does not land at the server (the driver owns
  control flow; a partitioned predicate cannot steer it).

Flat-API hierarchical reductions regroup ``(n, ...)`` to ``(P, n/P, ...)``
and bind comm eqns against a *derived* two-level stack whose names differ
from the plan's placement names. At that regroup boundary the operand-depth
checks are information-free (the lattice chains are incomparable by
construction), so the pass reports one ``placement/regroup-boundary`` info
finding per plan and propagates placements exactly as ``build_plan`` does.
"""

from __future__ import annotations

from typing import Any, Dict, List

from repro.core import interpreter as interp
from repro.core.interpreter import (
    Broadcast,
    CondStage,
    LocalCompute,
    LoopStage,
    PlacementSet,
    Reduce,
    Transfer,
    _contains_comm,
    _eqn_placement,
    _eqn_subjaxprs,
    _is_dropvar,
    _is_literal,
    _join,
)

from .findings import Finding


def check_placement_safety(plan) -> List[Finding]:
    """Run the placement-safety pass over ``plan`` and all sub-plans."""
    findings: List[Finding] = []
    _check_plan(plan, "", findings)
    return findings


def _eqn_kind(stage) -> str:
    """Kind of the level a comm eqn addresses, from the eqn's own context
    (covers derived stacks, whose names differ from the plan's)."""
    pctx = stage.eqn.params.get("pctx")
    if pctx is None:
        return "replicas"
    _, i = _eqn_placement(stage.eqn)
    return getattr(pctx.placements[i], "kind", "replicas")


def _check_plan(plan, prefix: str, findings: List[Finding]) -> None:
    names = tuple(n for n, _ in plan.placements)
    env: Dict[Any, PlacementSet] = {}
    for v, p in zip(plan.jaxpr.jaxpr.invars, plan.invar_placements):
        env[v] = p
    for v in plan.jaxpr.jaxpr.constvars:
        env[v] = ()
    for v in plan.extra_consts:
        env[v] = ()

    def pl(a) -> PlacementSet:
        if _is_literal(a):
            return ()
        return env.get(a, ())

    regroup_reported = False

    for idx, stage in enumerate(plan.stages):
        sname = f"stage_{prefix}{idx}"
        if isinstance(stage, LocalCompute):
            for eqn in stage.eqns:
                if eqn.primitive.name in interp._COMM or any(
                    _contains_comm(sub.jaxpr) for sub in _eqn_subjaxprs(eqn)
                ):
                    findings.append(Finding(
                        "placement/comm-in-local", "error",
                        f"communication primitive ({eqn.primitive.name}) "
                        f"inside a {stage.kind} stage: this control flow is "
                        f"not staged as explicit MapReduce communication",
                        stage=sname,
                    ))
                p: PlacementSet = ()
                for a in eqn.invars:
                    p = _join(p, pl(a))
                for o in eqn.outvars:
                    if not _is_dropvar(o):
                        env[o] = p
                if stage.at_groups != bool(p):
                    findings.append(Finding(
                        "placement/local-kind-mismatch", "warning",
                        f"eqn {eqn.primitive.name} joins to lattice depth "
                        f"{len(p)} but sits in a {stage.kind} stage",
                        stage=sname,
                    ))
        elif isinstance(stage, Broadcast):
            enames, i = _eqn_placement(stage.eqn)
            derived = enames != names
            in_pl = pl(stage.eqn.invars[0])
            if _eqn_kind(stage) != "replicas":
                findings.append(Finding(
                    "placement/wrong-kind-comm", "error",
                    f"broadcast@{enames[i]} addresses a stage-kind level: "
                    f"pipeline stages communicate by stage_transfer, not "
                    f"broadcast/reduce",
                    stage=sname,
                ))
            if derived:
                if not regroup_reported:
                    regroup_reported = True
                    findings.append(Finding(
                        "placement/regroup-boundary", "info",
                        f"comm eqns bind against a derived stack "
                        f"{'/'.join(enames)} inside a "
                        f"{'/'.join(names) or 'server'} plan (flat-API "
                        f"hierarchical regroup); operand-depth checks are "
                        f"relaxed at this boundary",
                        stage=sname,
                    ))
            else:
                if len(in_pl) > i and in_pl[: i + 1] == enames[: i + 1]:
                    findings.append(Finding(
                        "placement/rebroadcast", "error",
                        f"broadcast@{enames[i]} of a value already placed at "
                        f"{'/'.join(in_pl)}: duplicates a level the value "
                        f"carries, leaving the prefix lattice",
                        stage=sname,
                    ))
                elif in_pl != enames[:i]:
                    findings.append(Finding(
                        "placement/broadcast-operand", "warning",
                        f"broadcast@{enames[i]} expects its operand at "
                        f"{'/'.join(enames[:i]) or 'server'}, lattice says "
                        f"{'/'.join(in_pl) or 'server'}",
                        stage=sname,
                    ))
            expected_src = "server" if i == 0 else enames[i - 1]
            if stage.placement != enames[i] or stage.source != expected_src:
                findings.append(Finding(
                    "placement/pairing", "error",
                    f"Broadcast stage tagged {stage.source}->"
                    f"{stage.placement} but its eqn addresses level "
                    f"{enames[i]} (parent {expected_src}); the AD transpose "
                    f"would emit a reduce at the wrong level",
                    stage=sname,
                ))
            for o in stage.eqn.outvars:
                if not _is_dropvar(o):
                    env[o] = enames[: i + 1]
        elif isinstance(stage, Reduce):
            enames, i = _eqn_placement(stage.eqn)
            derived = enames != names
            in_pl = pl(stage.eqn.invars[0])
            if _eqn_kind(stage) != "replicas":
                findings.append(Finding(
                    "placement/wrong-kind-comm", "error",
                    f"{stage.op}@{enames[i]} addresses a stage-kind level: "
                    f"pipeline stages communicate by stage_transfer, not "
                    f"broadcast/reduce",
                    stage=sname,
                ))
            if derived:
                if not regroup_reported:
                    regroup_reported = True
                    findings.append(Finding(
                        "placement/regroup-boundary", "info",
                        f"comm eqns bind against a derived stack "
                        f"{'/'.join(enames)} inside a "
                        f"{'/'.join(names) or 'server'} plan (flat-API "
                        f"hierarchical regroup); operand-depth checks are "
                        f"relaxed at this boundary",
                        stage=sname,
                    ))
            else:
                if len(in_pl) > i + 1 and in_pl[: i + 1] == enames[: i + 1]:
                    findings.append(Finding(
                        "placement/outer-reduce", "error",
                        f"{stage.op}@{enames[i]} reduces an outer level of a "
                        f"value placed at {'/'.join(in_pl)}: the result "
                        f"(inner levels without their parent) is not a stack "
                        f"prefix",
                        stage=sname,
                    ))
                elif in_pl != enames[: i + 1]:
                    findings.append(Finding(
                        "placement/reduce-operand", "warning",
                        f"{stage.op}@{enames[i]} expects its operand at "
                        f"{'/'.join(enames[: i + 1])}, lattice says "
                        f"{'/'.join(in_pl) or 'server'}",
                        stage=sname,
                    ))
            expected_dest = "server" if i == 0 else enames[i - 1]
            if stage.placement != enames[i] or stage.dest != expected_dest:
                findings.append(Finding(
                    "placement/pairing", "error",
                    f"Reduce stage tagged {stage.placement}->{stage.dest} "
                    f"but its eqn addresses level {enames[i]} (parent "
                    f"{expected_dest}); the AD transpose would emit a "
                    f"broadcast at the wrong level",
                    stage=sname,
                ))
            for o in stage.eqn.outvars:
                if not _is_dropvar(o):
                    env[o] = enames[:i]
        elif isinstance(stage, Transfer):
            enames, i = _eqn_placement(stage.eqn)
            in_pl = pl(stage.eqn.invars[0])
            if _eqn_kind(stage) != "stages":
                findings.append(Finding(
                    "placement/wrong-kind-comm", "error",
                    f"stage_transfer@{enames[i]} addresses a "
                    f"replica-kind level: replicas communicate by "
                    f"broadcast/reduce, not neighbor transfer",
                    stage=sname,
                ))
            if enames == names and in_pl != enames[: i + 1]:
                findings.append(Finding(
                    "placement/transfer-operand", "warning",
                    f"stage_transfer@{enames[i]} expects its operand at "
                    f"{'/'.join(enames[: i + 1])}, lattice says "
                    f"{'/'.join(in_pl) or 'server'}",
                    stage=sname,
                ))
            if stage.placement != enames[i]:
                findings.append(Finding(
                    "placement/pairing", "error",
                    f"Transfer stage tagged @{stage.placement} but its eqn "
                    f"addresses level {enames[i]}; the AD transpose would "
                    f"emit the reverse transfer at the wrong level",
                    stage=sname,
                ))
            for o in stage.eqn.outvars:
                if not _is_dropvar(o):
                    env[o] = enames[: i + 1]
        elif isinstance(stage, LoopStage):
            _check_loop(plan, stage, idx, prefix, env, pl, findings)
        elif isinstance(stage, CondStage):
            for b, bp in enumerate(stage.branch_plans):
                _check_plan(bp, f"{prefix}{idx}_b{b}_", findings)
            for j, o in enumerate(stage.eqn.outvars):
                if _is_dropvar(o):
                    continue
                p: PlacementSet = ()
                for bp in stage.branch_plans:
                    p = _join(p, bp.outvar_placements[j])
                env[o] = p


def _check_loop(plan, stage, idx: int, prefix: str, env, pl, findings) -> None:
    sname = f"stage_{prefix}{idx}"
    eqn = stage.eqn
    body = stage.body_plan
    if stage.loop_kind == "scan":
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        carry_in = body.invar_placements[nc : nc + ncar]
        carry_out = body.outvar_placements[:ncar]
        num_ys = len(eqn.outvars) - ncar
        out_pl = list(carry_in) + [()] * num_ys
    else:  # while
        cn, bn = eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
        carry_in = body.invar_placements[bn:]
        carry_out = body.outvar_placements
        out_pl = list(carry_in)
        if stage.cond_plan is not None:
            _check_plan(stage.cond_plan, f"{prefix}{idx}_c_", findings)
            if stage.cond_plan.outvar_placements[0] != ():
                findings.append(Finding(
                    "placement/while-pred-placed", "warning",
                    f"while predicate lands at "
                    f"{'/'.join(stage.cond_plan.outvar_placements[0])}, not "
                    f"the server: the driver cannot steer a partitioned "
                    f"predicate",
                    stage=sname,
                ))
        operands = eqn.invars[cn : cn + bn] + eqn.invars[cn + bn :]
        body_expect = body.invar_placements
        for j, (a, exp) in enumerate(zip(operands, body_expect)):
            if _join(pl(a), exp) != exp:
                findings.append(Finding(
                    "placement/loop-input", "warning",
                    f"while operand {j} placed at "
                    f"{'/'.join(pl(a)) or 'server'} but the body binder "
                    f"expects at most {'/'.join(exp) or 'server'}",
                    stage=sname,
                ))
    for j, (ci, co) in enumerate(zip(carry_in, carry_out)):
        if _join(ci, co) != ci:
            findings.append(Finding(
                "placement/loop-carry-unstable", "error",
                f"loop carry {j} enters the body at "
                f"{'/'.join(ci) or 'server'} but exits at "
                f"{'/'.join(co)}: the carry climbs the lattice per "
                f"iteration (build_plan's fixed point was not applied)",
                stage=sname,
            ))
    _check_plan(body, f"{prefix}{idx}_", findings)
    for o, p in zip(eqn.outvars, out_pl):
        if not _is_dropvar(o):
            env[o] = p
