"""Finding/report types shared by every static-analysis pass.

A *finding* is one diagnosed fact about a plan (or the repo, for lint
rules): a stable machine-readable ``code`` (``"<pass>/<defect>"``), a
severity, a human explanation, and an optional anchor (the
``named_stages`` name of the stage it points at).

Severities:

* ``error``   — the plan violates an invariant the runtime relies on
  (communication hidden in a local stage, a donated buffer read after its
  aliased output is produced). ``AnalysisReport.ok`` is False.
* ``warning`` — legal but almost certainly not what the author wants
  (a dropped donation, a fingerprint-unstable capture). Does not flip
  ``ok``: the oracle-suite programs must analyze *clean of errors*, while
  hazard heuristics stay visible.
* ``info``    — structural notes (a flat→nested regroup boundary, a large
  captured const).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str  # "<pass>/<defect>", e.g. "placement/comm-in-local"
    severity: str  # error | warning | info
    message: str
    stage: Optional[str] = None  # named_stages anchor, e.g. "stage_2_b0_1"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")

    @property
    def pass_name(self) -> str:
        return self.code.split("/", 1)[0]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        where = f" [{self.stage}]" if self.stage else ""
        return f"{self.severity}: {self.code}{where}: {self.message}"


@dataclasses.dataclass
class AnalysisReport:
    """Aggregated result of ``plan.analyze()``.

    ``findings`` holds every pass's findings in pass order;
    ``comm_cost`` is the communication-cost pass's structured output
    (:class:`repro.analysis.commcost.CommCostReport`) when that pass ran.
    """

    findings: List[Finding] = dataclasses.field(default_factory=list)
    comm_cost: Optional[Any] = None

    @property
    def ok(self) -> bool:
        """True when no *error*-severity finding was produced."""
        return not self.errors

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    def by_code(self, code: str) -> List[Finding]:
        return [f for f in self.findings if f.code == code]

    def raise_if_errors(self) -> None:
        if self.errors:
            raise AssertionError(
                "plan analysis failed:\n"
                + "\n".join(f"  {f}" for f in self.errors)
            )

    def to_json(self) -> str:
        payload: Dict[str, Any] = {
            "ok": self.ok,
            "findings": [f.to_dict() for f in self.findings],
        }
        if self.comm_cost is not None:
            payload["comm_cost"] = self.comm_cost.to_dict()
        return json.dumps(payload, indent=2)

    def __str__(self) -> str:
        if not self.findings:
            return "AnalysisReport: clean"
        head = "AnalysisReport: " + (
            "OK" if self.ok else f"{len(self.errors)} error(s)"
        )
        return head + "\n" + "\n".join(f"  {f}" for f in self.findings)
