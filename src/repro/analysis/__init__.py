"""Static analysis for MapReduce plans + the unified repo lint registry.

Two halves (ROADMAP "Static analysis" conventions):

* **Plan-IR analyses** — passes that run on a :class:`MapReducePlan`
  without executing it, surfaced as ``plan.analyze()``:

  - :func:`check_placement_safety` — the full placement-lattice pass
    (comm-free local stages at all depths, broadcast/reduce monotonicity
    and pairing, loop-carry stability);
  - :func:`analyze_donation` — static donation/aliasing over
    ``plan.compile``'s lowering (use-after-donate, dropped donations with
    the why, loop-carry donate-eligibility);
  - :func:`analyze_retrace` — fingerprint-unstable captures (the
    zero-retrace invariant's silent killers), plus
    :func:`explain_fingerprint_mismatch` for differential diagnosis;
  - :func:`estimate_comm_cost` — per-stage wire bytes from the IR (DCN vs
    ICI by placement level, int8 ``compress`` tags applied), with
    :func:`cross_validate_comm_cost` checking the geometry against
    ``compat.cost_analysis`` on compiled programs.

* **Lint framework** — ``repro.analysis.lints`` (run via
  ``scripts/lint.py``): a rule registry with per-line suppression and JSON
  output, absorbing the compat grep and the donation lint.

Heavy submodules load lazily (PEP 562) so ``from repro.analysis import
lints`` — the lint CLI's only need — stays JAX-free.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from .findings import AnalysisReport, Finding

__all__ = [
    "AnalysisReport",
    "Finding",
    "analyze_plan",
    "analyze_donation",
    "analyze_retrace",
    "check_placement_safety",
    "estimate_comm_cost",
    "cross_validate_comm_cost",
    "explain_fingerprint_mismatch",
    "lints",
]

_LAZY = {
    "check_placement_safety": ("placement_safety", "check_placement_safety"),
    "analyze_donation": ("donation", "analyze_donation"),
    "analyze_retrace": ("retrace", "analyze_retrace"),
    "explain_fingerprint_mismatch": ("retrace", "explain_fingerprint_mismatch"),
    "estimate_comm_cost": ("commcost", "estimate_comm_cost"),
    "cross_validate_comm_cost": ("commcost", "cross_validate"),
    "lints": ("lints", None),
}

if TYPE_CHECKING:  # pragma: no cover - typing only
    from . import lints  # noqa: F401
    from .commcost import cross_validate as cross_validate_comm_cost  # noqa: F401
    from .commcost import estimate_comm_cost  # noqa: F401
    from .donation import analyze_donation  # noqa: F401
    from .placement_safety import check_placement_safety  # noqa: F401
    from .retrace import analyze_retrace  # noqa: F401
    from .retrace import explain_fingerprint_mismatch  # noqa: F401


def __getattr__(name):
    entry = _LAZY.get(name)
    if entry is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    module = importlib.import_module(f".{entry[0]}", __name__)
    value = module if entry[1] is None else getattr(module, entry[1])
    globals()[name] = value
    return value


def analyze_plan(
    plan,
    *,
    donate_argnums=(),
    cross_validate: bool = False,
    comm_cost: bool = True,
) -> AnalysisReport:
    """Run every plan-IR pass over ``plan`` and aggregate the findings.

    ``donate_argnums`` feeds the donation/aliasing pass (pass the same
    tuple you would hand ``plan.compile``). ``cross_validate=True``
    additionally jits each plain reduce standalone and checks the comm
    model against ``compat.cost_analysis`` (slow: one compile per comm
    stage). The report's :attr:`~AnalysisReport.ok` is True iff no pass
    produced an *error* — the oracle-suite bar; warnings and infos are
    hazard heuristics and structural notes.
    """
    from . import commcost, donation, placement_safety, retrace

    report = AnalysisReport()
    report.findings.extend(placement_safety.check_placement_safety(plan))
    report.findings.extend(
        donation.analyze_donation(plan, donate_argnums=donate_argnums)
    )
    report.findings.extend(
        retrace.analyze_retrace(plan, donate_argnums=donate_argnums)
    )
    if comm_cost:
        cost = commcost.estimate_comm_cost(plan)
        report.comm_cost = cost
        report.findings.extend(cost.findings)
    if cross_validate:
        report.findings.extend(commcost.cross_validate(plan))
    return report


def donation_report(compiled_plan) -> AnalysisReport:
    """Donation/aliasing report for a ``CompiledPlan`` (its argnums applied)."""
    from . import donation

    report = AnalysisReport()
    report.findings.extend(donation.analyze_donation(
        compiled_plan.plan, donate_argnums=compiled_plan.donate_argnums
    ))
    return report
