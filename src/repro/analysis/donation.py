"""Donation/aliasing analysis over the compiled plan's lowering.

``plan.compile(donate_argnums=...)`` hands the donated flat args to one
``jax.jit``; XLA then tries to alias each donated input buffer to an output
of matching shape/dtype and silently *drops* the donation (with a runtime
warning, at best) when nothing matches. This pass re-derives the aliasing
decision statically from the plan IR and reports, ahead of compilation:

* ``donation/bad-argnum`` (error) — the argnum does not name a plan input;
* ``donation/use-after-donate`` (error) — a later stage (or the plan's own
  output list) reads a donated input *after* the stage that defines the
  output its buffer aliases. Inside one executable XLA schedules around
  this; across the staged MapReduce boundary (Beam/federated backends, or
  a future per-stage dispatch split) the read would observe an
  overwritten buffer — the plan-level discipline is that a donated input's
  last read is the stage producing its alias;
* ``donation/dropped`` (warning) — no un-aliased output matches the donated
  input's shape/dtype, with the *why* spelled out (what the outputs look
  like), instead of XLA's silent drop;
* ``donation/unused`` (warning) — a donated input no stage reads;
* ``donation/carry-not-eligible`` (warning) — a ``LoopStage`` carry whose
  initial value is read again after the loop (or returned directly), so
  the lowered ``lax.scan``/``while_loop`` cannot update the carry buffer
  in place and every round pays a copy. Checked for every loop at every
  depth, independent of ``donate_argnums``.

The aliasing model mirrors XLA's first-fit matching on (shape, dtype) in
output order; it is deliberately conservative and explains itself rather
than guessing at backend-specific layouts.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

from repro.core import interpreter as interp
from repro.core.interpreter import LoopStage, _is_literal

from .findings import Finding


def _aval_key(atom) -> Tuple:
    aval = atom.aval
    return (tuple(aval.shape), str(aval.dtype))


def _shape_str(atom) -> str:
    aval = atom.aval
    return f"{aval.dtype}[{','.join(str(d) for d in aval.shape)}]"


def analyze_donation(plan, donate_argnums: Sequence[int] = ()) -> List[Finding]:
    findings: List[Finding] = []
    invars = plan.jaxpr.jaxpr.invars
    io = plan.stage_io()
    n_stages = len(io)

    # Per top-level stage: where each atom is last read / first defined.
    last_read: Dict[Any, int] = {}
    def_stage: Dict[Any, int] = {}
    for i, (_stage, reads, _outs) in enumerate(io):
        for a in reads:
            last_read[a] = i
        for w in interp._stage_writes(_stage):
            def_stage.setdefault(w, i)
    for a in plan.out_atoms:
        if not _is_literal(a):
            last_read[a] = n_stages  # returning a value reads it

    claimed: set = set()
    for d in sorted(set(int(x) for x in donate_argnums)):
        if d < 0 or d >= len(invars):
            findings.append(Finding(
                "donation/bad-argnum", "error",
                f"donate_argnums includes {d} but the plan has only "
                f"{len(invars)} flat inputs",
            ))
            continue
        v = invars[d]
        if v not in last_read:
            findings.append(Finding(
                "donation/unused", "warning",
                f"donated input {d} ({_shape_str(v)}) is never read: the "
                f"donation frees nothing the program was going to keep",
            ))
            continue
        alias = None
        for j, o in enumerate(plan.out_atoms):
            if _is_literal(o) or j in claimed:
                continue
            if _aval_key(o) == _aval_key(v):
                alias = (j, o)
                claimed.add(j)
                break
        if alias is None:
            outs = ", ".join(
                "literal" if _is_literal(o) else _shape_str(o)
                for o in plan.out_atoms
            )
            findings.append(Finding(
                "donation/dropped", "warning",
                f"donated input {d} ({_shape_str(v)}) aliases no output: "
                f"every output is either shape/dtype-incompatible or "
                f"already aliased to an earlier donated input (outputs: "
                f"[{outs}]). XLA drops the donation silently; either stop "
                f"donating this arg or return its updated value",
            ))
            continue
        j, o = alias
        if o is v:
            continue  # identity passthrough: the alias IS the last read
        d_def = def_stage.get(o, -1)
        reads_after = last_read.get(v, -1)
        if reads_after > d_def:
            where = (
                "the plan's outputs" if reads_after == n_stages
                else f"stage_{reads_after}"
            )
            findings.append(Finding(
                "donation/use-after-donate", "error",
                f"donated input {d} ({_shape_str(v)}) aliases output {j}, "
                f"defined at stage_{d_def}, but is still read by {where}: "
                f"the read observes a buffer the alias may have overwritten",
                stage=f"stage_{d_def}" if d_def >= 0 else None,
            ))
    findings.extend(_check_carries(plan))
    return findings


def _check_carries(plan) -> List[Finding]:
    """Donate-eligibility of every loop carry, at every nesting depth."""
    findings: List[Finding] = []
    _walk_carries(plan, "", findings)
    return findings


def _walk_carries(plan, prefix: str, findings: List[Finding]) -> None:
    last_read: Dict[Any, int] = {}
    for i, (_stage, reads, _outs) in enumerate(plan.stage_io()):
        for a in reads:
            last_read[a] = i
    final = set(a for a in plan.out_atoms if not _is_literal(a))
    for idx, stage in enumerate(plan.stages):
        if isinstance(stage, LoopStage):
            sname = f"stage_{prefix}{idx}"
            eqn = stage.eqn
            if stage.loop_kind == "scan":
                nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
                carries = eqn.invars[nc : nc + ncar]
            else:
                cn, bn = (
                    eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
                )
                carries = eqn.invars[cn + bn :]
            for j, a in enumerate(carries):
                if _is_literal(a):
                    continue
                reasons = []
                if last_read.get(a, -1) > idx:
                    reasons.append(
                        f"read again at stage_{prefix}{last_read[a]}"
                    )
                if a in final:
                    reasons.append("returned as a plan output")
                if reasons:
                    findings.append(Finding(
                        "donation/carry-not-eligible", "warning",
                        f"loop carry {j} init ({_shape_str(a)}) is "
                        f"{' and '.join(reasons)}: the lowered loop cannot "
                        f"update the carry buffer in place, so every call "
                        f"pays a copy of it",
                        stage=sname,
                    ))
            if stage.cond_plan is not None:
                _walk_carries(stage.cond_plan, f"{prefix}{idx}_c_", findings)
            if stage.body_plan is not None:
                _walk_carries(stage.body_plan, f"{prefix}{idx}_", findings)
        elif hasattr(stage, "branch_plans"):
            for b, bp in enumerate(stage.branch_plans):
                _walk_carries(bp, f"{prefix}{idx}_b{b}_", findings)
