"""Analytic roofline model per (arch × cell × mesh).

Why this exists: XLA's cost analysis on a compiled module counts each
``while``-loop body ONCE, so any scan-over-layers program under-reports
FLOPs/bytes by ~num_layers×, and collectives inside the loop likewise. The
dry-run therefore records BOTH: (a) the compiled HLO evidence (which
collectives exist, their shapes, the schedule — structure), and (b) this
analytic model (standard MFU/roofline accounting) for magnitudes. The model
is validated against HLO ``cost_analysis`` on unscanned configs in
``tests/test_roofline.py`` — where XLA counts everything, the two agree.

All quantities are PER DEVICE unless suffixed ``_global``.

Conventions (bf16 activations/params, fp32 optimizer):
 * train FLOPs = 3× forward (fwd + 2× bwd) + remat recompute;
 * attention scores cost 4·B·S²·hd·Hq per layer forward (QKᵀ + PV),
   scaled by ``causal_factor`` (1.0 = full-block baseline schedule; 0.5 =
   block-skipping / flash schedule);
 * TP collectives: 2 all-reduces per layer fwd (attn out + mlp out), ring
   cost 2·(m-1)/m · bytes; backward doubles; decode/prefill = fwd only;
 * FSDP: per-layer param all-gather (fwd + bwd recompute) + grad
   reduce-scatter;
 * MoE: all-to-all dispatch+combine, 2 directions, k experts per token.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.launch.hlo_cost import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.models import registry
from repro.models.blocks import layer_kinds


@dataclasses.dataclass
class MeshModel:
    chips: int
    data: int  # total data-parallel ways (pod*data)
    model: int

    @classmethod
    def single(cls):
        return cls(chips=256, data=16, model=16)

    @classmethod
    def multi(cls):
        return cls(chips=512, data=32, model=16)


def _bytes_per_param(dtype: str = "bfloat16") -> int:
    return 2


def _attn_flops_fwd_global(cfg, batch: int, sq: int, skv: int,
                           causal_factor: float) -> float:
    """QK^T + PV matmul flops, all attention layers."""
    kinds = layer_kinds(cfg)
    n_attn = sum(1 for k in kinds if k == "attention")
    if cfg.is_encoder_decoder:
        n_attn = cfg.encoder_layers + cfg.num_layers  # self-attn
    per_layer = 4.0 * batch * sq * skv * cfg.head_dim * cfg.num_heads
    total = n_attn * per_layer * causal_factor
    if cfg.is_encoder_decoder:
        # decoder cross-attention: Sq_dec x Skv_mem
        total += 4.0 * cfg.num_layers * batch * sq * skv * cfg.head_dim * cfg.num_heads
    if cfg.attention == "local" and cfg.window_size:
        # windowed layers see at most `window` keys
        eff = min(cfg.window_size, skv)
        total = n_attn * 4.0 * batch * sq * eff * cfg.head_dim * cfg.num_heads
    return total


def _linear_recurrence_flops_fwd_global(cfg, batch: int, s: int) -> float:
    kinds = layer_kinds(cfg)
    out = 0.0
    if cfg.family == "ssm":
        # WKV: chunked form ~ O(S·N) matmuls per head ≈ 4·S·C·N per head
        h = cfg.d_model // cfg.rwkv_head_dim
        n = cfg.rwkv_head_dim
        chunk = 64
        out += cfg.num_layers * batch * h * (
            4.0 * s * chunk * n + 2.0 * s * n * n
        )
    n_rec = sum(1 for k in kinds if k == "recurrent")
    if n_rec:
        out += n_rec * batch * s * cfg.lru_width * 8.0  # elementwise scan ops
    return out


def causal_pair_fraction(seq: int, q_block: int, kv_block: int) -> float:
    """Fraction of (q-block, kv-block) pairs the flash schedule computes for
    causal attention (exactly matches attention._visible_pairs)."""
    nq = -(-seq // q_block)
    nk = -(-seq // kv_block)
    pairs = sum(
        1
        for i in range(nq)
        for j in range(nk)
        if j * kv_block <= i * q_block + q_block - 1
    )
    return pairs / max(nq * nk, 1)


def flops_cell(cfg, kind: str, batch: int, seq: int,
               causal_factor: float = None,
               remat: str = None) -> Dict[str, float]:
    """Global FLOPs for one step of this cell."""
    remat = remat if remat is not None else cfg.remat
    if causal_factor is None:
        if cfg.attn_impl in ("blocked", "flash") and cfg.attention == "global":
            # flash schedule skips fully-masked block pairs
            causal_factor = causal_pair_fraction(seq, cfg.q_block, cfg.kv_block)
        else:
            causal_factor = 1.0
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = batch * (seq + max(seq // 8, 16)) if cfg.is_encoder_decoder \
            else batch * seq
        dense_fwd = 2.0 * n_active * tokens
        attn_fwd = _attn_flops_fwd_global(cfg, batch, seq, seq, causal_factor)
        rec_fwd = _linear_recurrence_flops_fwd_global(cfg, batch, seq)
        fwd = dense_fwd + attn_fwd + rec_fwd
        recompute = 0.0
        if remat == "full":
            recompute = dense_fwd + rec_fwd  # attention recompute is inside
            # the flash VJP backward, counted in its 3.5x multiplier below
        elif remat == "dots":
            recompute = rec_fwd + 0.1 * dense_fwd
        # flash attention backward recomputes scores: fwd + 2.5x fwd
        total = 3.0 * (dense_fwd + rec_fwd) + 3.5 * attn_fwd + recompute
        return {"fwd": fwd, "total": total, "tokens": float(tokens)}
    if kind == "prefill":
        tokens = batch * seq
        dense_fwd = 2.0 * n_active * tokens
        attn_fwd = _attn_flops_fwd_global(cfg, batch, seq, seq, causal_factor)
        rec_fwd = _linear_recurrence_flops_fwd_global(cfg, batch, seq)
        fwd = dense_fwd + attn_fwd + rec_fwd
        return {"fwd": fwd, "total": fwd, "tokens": float(tokens)}
    # decode: 1 token per sequence against a cache of length `seq`
    dense_fwd = 2.0 * n_active * batch
    attn_fwd = _attn_flops_fwd_global(cfg, batch, 1, seq, 1.0)
    rec_fwd = _linear_recurrence_flops_fwd_global(cfg, batch, 1)
    fwd = dense_fwd + attn_fwd + rec_fwd
    return {"fwd": fwd, "total": fwd, "tokens": float(batch)}


def _kv_cache_bytes_global(cfg, batch: int, seq: int) -> float:
    kinds = layer_kinds(cfg)
    n_attn = sum(1 for k in kinds if k == "attention")
    eff = min(cfg.window_size, seq) if cfg.attention == "local" else seq
    kv = 2.0 * n_attn * batch * eff * cfg.num_kv_heads * cfg.head_dim * 2
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv_head_dim
        kv += cfg.num_layers * batch * h * cfg.rwkv_head_dim**2 * 4
    if cfg.family == "hybrid":
        n_rec = sum(1 for k in kinds if k == "recurrent")
        kv += n_rec * batch * cfg.lru_width * 4
    if cfg.is_encoder_decoder:
        kv += 2.0 * cfg.num_layers * batch * seq * cfg.num_kv_heads * cfg.head_dim * 2
    return kv


def bytes_cell(cfg, kind: str, batch: int, seq: int, mesh: MeshModel,
               remat: str = None) -> Dict[str, float]:
    """Per-device HBM bytes for one step."""
    remat = remat if remat is not None else cfg.remat
    p_bytes_g = cfg.param_count() * 2.0
    p_active_g = cfg.active_param_count() * 2.0
    act_unit = 2.0 * cfg.d_model  # bytes per token per tensor (bf16)
    layers = cfg.num_layers + (cfg.encoder_layers or 0)

    if kind == "train":
        tokens = batch * (seq + max(seq // 8, 16)) if cfg.is_encoder_decoder \
            else batch * seq
        # params sharded over all chips (FSDP+TP): read fwd + read bwd
        # (+ read for recompute), grads written+reduced, opt m/v read+write f32
        param_traffic = 3.0 * p_bytes_g + 2.0 * p_bytes_g  # reads + grad rw
        opt_traffic = 4.0 * cfg.param_count() * 4.0  # m,v read+write
        saved_per_layer = {"none": 12.0, "dots": 6.0, "full": 2.0}[remat]
        act_traffic = 2.0 * saved_per_layer * layers * tokens * act_unit
        total_g = param_traffic + opt_traffic + act_traffic
        return {"total": total_g / mesh.chips, "params_global": p_bytes_g}
    if kind == "prefill":
        tokens = batch * seq
        act_traffic = 2.0 * 4.0 * layers * tokens * act_unit
        kv = _kv_cache_bytes_global(cfg, batch, seq)
        total_g = p_active_g + act_traffic + kv
        return {"total": total_g / mesh.chips, "params_global": p_bytes_g}
    # decode: weight streaming + KV cache read
    kv = _kv_cache_bytes_global(cfg, batch, seq)
    total_g = p_active_g + kv + 4.0 * batch * layers * act_unit
    return {"total": total_g / mesh.chips, "params_global": p_bytes_g}


def collective_bytes_cell(cfg, kind: str, batch: int, seq: int,
                          mesh: MeshModel, *, fsdp: bool = None,
                          compression: float = 1.0) -> Dict[str, float]:
    """Per-device collective bytes for one step (ring cost model)."""
    if fsdp is None:
        fsdp = True if kind == "train" else (cfg.family == "moe")
    m, d = mesh.model, mesh.data
    ring_m = 2.0 * (m - 1) / m
    layers = cfg.num_layers + (cfg.encoder_layers or 0)
    kinds = layer_kinds(cfg)

    if kind == "train":
        tokens = batch * (seq + max(seq // 8, 16)) if cfg.is_encoder_decoder \
            else batch * seq
        tokens_dev = tokens / d
        act_slice = tokens_dev * cfg.d_model * 2.0
        # TP: 2 all-reduce per layer fwd, 2 bwd (activations)
        tp = 4.0 * layers * ring_m * act_slice if m > 1 else 0.0
        out = {"tp_allreduce": tp}
        p_bytes_g = cfg.param_count() * 2.0
        if fsdp:
            shard = p_bytes_g / mesh.chips
            # all-gather params fwd + bwd(recompute), reduce-scatter grads
            ag = 2.0 * (d - 1) / d * (p_bytes_g / m)
            rs = (d - 1) / d * (p_bytes_g / m) * 2.0  # grads f32/bf16 mix ~2x
            out["fsdp_allgather"] = ag
            out["grad_reducescatter"] = rs * compression
        else:
            out["grad_allreduce"] = (
                2.0 * (d - 1) / d * (p_bytes_g / m) * compression
            )
        if cfg.family == "moe" and m > 1:
            # our MoE sharding is tokens-over-data × experts-over-model:
            # dispatch/expert einsums are local; the expert-dim contraction in
            # the combine induces one activation all-reduce fwd (+2 bwd).
            out["moe_combine_allreduce"] = 3.0 * layers * ring_m * act_slice
        out["total"] = sum(out.values())
        return out

    tokens = batch * seq if kind == "prefill" else batch
    tokens_dev = tokens / d
    act_slice = tokens_dev * cfg.d_model * 2.0
    tp = 2.0 * layers * ring_m * act_slice if m > 1 else 0.0
    out = {"tp_allreduce": tp}
    if fsdp:
        p_bytes_g = cfg.param_count() * 2.0
        out["fsdp_allgather"] = (d - 1) / d * (p_bytes_g / m)
    if cfg.family == "moe" and m > 1:
        out["moe_combine_allreduce"] = 1.0 * layers * ring_m * act_slice
    out["total"] = sum(out.values())
    return out


def analytic_roofline(cfg, kind: str, batch: int, seq: int, mesh: MeshModel,
                      *, causal_factor: float = 1.0, fsdp: bool = None,
                      remat: str = None,
                      compression: float = 1.0) -> Dict[str, float]:
    if cfg.mesh_strategy == "dp":
        # model axis repurposed as data parallelism: no TP collectives
        mesh = MeshModel(chips=mesh.chips, data=mesh.chips, model=1)
    fl = flops_cell(cfg, kind, batch, seq, causal_factor, remat=remat)
    by = bytes_cell(cfg, kind, batch, seq, mesh, remat=remat)
    co = collective_bytes_cell(
        cfg, kind, batch, seq, mesh, fsdp=fsdp, compression=compression
    )
    flops_dev = fl["total"] / mesh.chips
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = by["total"] / HBM_BW
    collective_s = co["total"] / LINK_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    n_active = cfg.active_param_count()
    mf = (6.0 if kind == "train" else 2.0) * n_active * fl["tokens"]
    bound = max(terms.values())  # perfect compute/comm overlap
    bound_serial = sum(terms.values())  # no overlap
    peak_total = mesh.chips * PEAK_FLOPS
    return {
        **terms,
        "dominant": dominant,
        "flops_per_device": flops_dev,
        "bytes_per_device": by["total"],
        "collective_bytes_per_device": co["total"],
        "collective_breakdown": co,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(fl["total"], 1.0),
        "step_time_lower_bound_s": bound,
        "step_time_serial_s": bound_serial,
        # headline score: model FLOPs over peak at the roofline-bound step time
        "mfu_overlap": mf / (peak_total * bound) if bound else 0.0,
        "mfu_serial": mf / (peak_total * bound_serial) if bound_serial else 0.0,
        "tokens": fl["tokens"],
    }
