"""Compiled-program cost extraction: HLO collective parsing + roofline terms.

Import-safe (no env/device side effects) so tests and benchmarks can use it
in-process — unlike ``repro.launch.dryrun``, which must force the host device
count before JAX's first init and is only importable inside its own driver
process. Callers feed :func:`roofline_terms` the flops/bytes numbers from
``repro.compat.cost_analysis`` (the raw result shape differs across JAX
versions).
"""

from __future__ import annotations

import re
from typing import Dict

# TPU v5e constants (per task card)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
    "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    size = 1
    if dims:
        for d in dims.split(","):
            size *= int(d)
    base = next((v for k, v in _DTYPE_BYTES.items() if dt.startswith(k)), 4)
    return size * base


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)  # iota format [num_groups,group_size]
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)  # explicit {{0,1,...},...}: first group size
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count + operand bytes (per-device program).

    ``compiled.as_text()`` call sites reference operands by name only, so we
    read the *output* shape (on the lhs) and convert to operand size with the
    replica-group size g: all-gather operand = out/g; reduce-scatter operand
    = out*g; all-reduce / all-to-all / collective-permute operand = out.
    """
    stats = {k: {"count": 0, "operand_bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # NOTE: tuple output shapes may contain /*index=N*/ comments, so the
        # span between "=" and the op name must allow "=" characters.
        mop = re.search(
            r"=\s+.*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(", s)
        if not mop or mop.group(2) == "-done":
            continue
        kind = mop.group(1)
        out_bytes = sum(
            _shape_bytes(m) for m in _SHAPE_RE.finditer(mop.group(0))
        )
        g = _group_size(s)
        if kind == "all-gather":
            operand = out_bytes / g
        elif kind == "reduce-scatter":
            operand = out_bytes * g
        else:
            operand = out_bytes
        stats[kind]["count"] += 1
        stats[kind]["operand_bytes"] += operand
    return {k: v for k, v in stats.items() if v["count"]}


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float) -> Dict[str, float]:
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": collective_bytes / LINK_BW,
    }


