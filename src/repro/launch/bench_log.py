"""BENCH_<name>.json trajectory writer (one owner for the merge rule).

Each trajectory file keeps one entry per git SHA; several writers may
contribute keys to the SAME entry — for ``BENCH_hier.json`` (the default
``name="hier"``): ``benchmarks/hier_reduce.py`` ("points"),
``benchmarks/executor.py`` ("executor"), the dry-run driver's
``--hier-sweep`` ("sharded") — so the merge must update in place and never
clobber another writer's measurements. ``benchmarks/pipeline.py`` writes
its own ``BENCH_pipeline.json`` via ``name="pipeline"``. Import-safe: no
JAX, no env mutation.
"""

from __future__ import annotations

import json
import os
import subprocess
from typing import Optional


def repo_root() -> str:
    return os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
    )


def bench_path(name: str = "hier") -> str:
    return os.path.join(repo_root(), f"BENCH_{name}.json")


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo_root(),
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:  # noqa: BLE001 - not a git checkout / git missing
        return "unknown"


def _load(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def merge_entry(updates: dict, *, top_points: Optional[list] = None,
                name: str = "hier") -> str:
    """Merge ``updates`` into the current SHA's trajectory entry.

    Only the caller's keys are replaced; everything else in the entry (and
    every other SHA's entry) survives. ``top_points`` additionally mirrors
    the latest wall-clock points under the top-level ``"points"`` key for
    quick reading (hier_reduce's historical schema). A pre-trajectory file
    (bare ``{"points": ...}``) is kept as the seed entry. ``name`` selects
    the trajectory file (``BENCH_<name>.json``, default the historical
    ``hier``).
    """
    path = bench_path(name)
    data = _load(path)
    trajectory = list(data.get("trajectory", []))
    if not trajectory and "points" in data:
        trajectory = [{"sha": "seed(pre-trajectory)", "points": data["points"]}]
    sha = git_sha()
    entry = next((e for e in trajectory if e.get("sha") == sha), None)
    if entry is None:
        entry = {"sha": sha}
        trajectory.append(entry)
    entry.update(updates)
    out = {"points": data.get("points", []), "trajectory": trajectory}
    if top_points is not None:
        out["points"] = top_points
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return path
