"""End-to-end training driver.

Trains any registry architecture with either plain data-parallel AdamW or
DrJAX local-SGD/DiLoCo rounds, with checkpoint/restart fault tolerance,
straggler-masked reductions, and (optional) delta compression.

CPU-scale example (reduced config, a few hundred rounds):

    PYTHONPATH=src python -m repro.launch.train \
        --arch lm_350m --reduced --algorithm diloco \
        --rounds 200 --cohort 8 --local-steps 4 --ckpt-dir /tmp/ckpt

On a real cluster, run unmodified under `jax.distributed` with
``--mesh single|multi`` (the production meshes from launch/mesh.py).
"""

from __future__ import annotations

import argparse
import functools
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
from repro.checkpoint import CheckpointManager
from repro.data.grouped import CohortSampler, GroupedCorpus
from repro.models import registry
from repro.runtime.failure import FailureInjector, run_with_recovery
from repro.runtime.stragglers import StragglerSimulator, straggler_mask

logger = logging.getLogger(__name__)


def build_round_fn(cfg, args):
    loss_fn = functools.partial(registry.loss_fn, cfg)
    client_opt = (
        optim.adamw(args.client_lr) if args.algorithm == "diloco"
        else optim.sgd(args.client_lr)
    )
    server_opt = {
        "local_sgd": optim.fedavg_momentum(1.0),
        "fedavg": optim.fedavg_momentum(1.0, momentum=0.9),
        "diloco": optim.diloco_optimizer(0.7, 0.9),
    }[args.algorithm]
    round_cfg = LocalSGDConfig(
        partition_size=args.cohort,
        num_local_steps=args.local_steps,
        grad_clip=1.0,
        compression=args.compression,
        straggler_mask=args.stragglers,
    )
    round_fn = make_local_sgd_round(loss_fn, client_opt, server_opt, round_cfg)
    # Donate the carried state (params, server_state): the round loop below
    # rebinds both every round, so the executable updates them in place.
    return jax.jit(round_fn, donate_argnums=(0, 1)), server_opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm_350m", choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--algorithm", default="local_sgd",
                    choices=("local_sgd", "fedavg", "diloco"))
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--cohort", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--client-lr", type=float, default=0.05)
    ap.add_argument("--compression", default=None,
                    choices=(None, "int8", "topk"))
    ap.add_argument("--stragglers", action="store_true")
    ap.add_argument("--straggler-deadline-pct", type=float, default=90.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated failures at these rounds")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--chaos", action="store_true",
                    help="run the chaos soak harness instead of training: "
                         "composed fault injection (device failures, pod "
                         "dropout/regrowth, straggler deadlines, checkpoint "
                         "faults, serve traffic) with the production "
                         "invariants asserted (see repro.runtime.chaos)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    if args.chaos:
        from repro.runtime.chaos import ChaosConfig, run_chaos_soak

        report = run_chaos_soak(ChaosConfig(
            rounds=args.rounds if args.rounds != 100 else 48,
            seed=args.seed,
            checkpoint_every=min(args.ckpt_every, 8),
            ckpt_dir=None,  # soak state is throwaway; never reuse --ckpt-dir
        ))
        logger.info(
            "chaos soak survived: %d failures, %d elastic events, "
            "%d fallback restores, bitwise=%s",
            report.device_failures, len(report.elastic_events),
            report.fallback_restores, report.oracle_bitwise_equal,
        )
        print(json.dumps(report.to_json(), indent=2))
        return

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        args.seq = min(args.seq, 64)
        args.batch = min(args.batch, 4)

    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    round_fn, server_opt = build_round_fn(cfg, args)
    server_state = server_opt.init(params)

    corpus = GroupedCorpus(vocab_size=cfg.vocab_size)
    sampler = CohortSampler(corpus, cohort_size=args.cohort)
    strag = StragglerSimulator() if args.stragglers else None
    injector = FailureInjector(args.fail_at)
    mgr = CheckpointManager(args.ckpt_dir, keep_last_n=3)
    n_params = sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params)
    )
    logger.info("arch=%s params=%.2fM cohort=%d local_steps=%d",
                cfg.name, n_params / 1e6, args.cohort, args.local_steps)

    history = []

    def round_step(round_idx, state):
        injector.check(round_idx)
        params, server_state = state["params"], state["server"]
        data = sampler.round_batch(
            round_idx, args.local_steps, args.batch, args.seq
        )
        batch = {"tokens": data["tokens"], "labels": data["labels"]}
        t0 = time.time()
        if strag is not None:
            durations = strag.durations(round_idx, args.cohort)
            deadline = float(
                np.percentile(durations, args.straggler_deadline_pct)
            )
            mask = straggler_mask(durations, deadline,
                                  min_finishers=max(args.cohort // 2, 1))
            params, server_state, metrics = round_fn(
                params, server_state, batch, mask
            )
        else:
            params, server_state, metrics = round_fn(
                params, server_state, batch
            )
        loss = float(metrics["loss"])
        history.append(loss)
        if round_idx % args.log_every == 0:
            logger.info("round %d loss %.4f (%.2fs)", round_idx, loss,
                        time.time() - t0)
        return {"params": params, "server": server_state}

    init_state = {"params": params, "server": server_state}
    final, stats = run_with_recovery(
        round_step, init_state, args.rounds, mgr,
        checkpoint_every=args.ckpt_every,
    )
    logger.info("done: %d rounds, %d restarts, final loss %.4f",
                args.rounds, stats["restarts"],
                history[-1] if history else float("nan"))
    print(json.dumps({
        "arch": cfg.name,
        "algorithm": args.algorithm,
        "rounds": args.rounds,
        "restarts": stats["restarts"],
        "first_loss": history[0] if history else None,
        "final_loss": history[-1] if history else None,
    }))


if __name__ == "__main__":
    main()
