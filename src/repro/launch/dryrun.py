import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    # Append rather than overwrite: unrelated user flags survive, while a
    # caller that already forces a device count (the --hier-sweep bench
    # runs under 8 fake devices) keeps its smaller pool.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512"
    ).strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The lines above MUST precede any other import (JAX locks the device count
at first init). 512 host devices back the production meshes:

    single-pod:  (16, 16)      -> ("data", "model")      256 chips
    multi-pod:   (2, 16, 16)   -> ("pod", "data", "model") 512 chips

Per cell this driver records, to benchmarks/dryrun_results/*.json:
 * compile success, memory_analysis (bytes/device),
 * cost_analysis (HLO FLOPs / bytes accessed — per-device program),
 * the collective schedule (op counts + operand bytes, parsed from the
   post-SPMD HLO) and the three roofline terms (v5e constants).

Usage:
    python -m repro.launch.dryrun --arch qwen2_72b --cell train_4k --mesh single
    python -m repro.launch.dryrun --all            # every missing cell
    python -m repro.launch.dryrun --paper          # DrJAX local-SGD rounds
"""

import argparse
import json
import time
import traceback

import jax

from repro import compat
from repro.launch import analytic
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.launch.hlo_cost import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    parse_collectives,
    roofline_terms,
)
from repro.models import registry

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))), "benchmarks", "dryrun_results"
)

def mesh_kind_is_multi(chips: int) -> bool:
    return chips == 512


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    n_active = cfg.active_param_count()
    if kind == "train":
        if cfg.is_encoder_decoder:
            tokens = batch * (seq + max(seq // 8, 16))
        else:
            tokens = batch * seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


def _lower_cell(arch: str, cell: str, multi_pod: bool, algorithm: str):
    cfg = registry.get_config(arch)
    shape = registry.SHAPE_CELLS[cell]
    kind = shape["kind"]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    seq, gb = shape["seq_len"], shape["global_batch"]

    if kind == "train":
        if algorithm == "local_sgd":
            n_groups = 32 if multi_pod else 16
            local_batch = max(gb // n_groups, 1)
            step, param_sh, server_sh, data_sh_fn = steps_lib.make_drjax_round_step(
                cfg, mesh, partition_size=n_groups, num_local_steps=1,
            )
            specs = steps_lib.drjax_round_specs(
                cfg, partition_size=n_groups, num_local_steps=1,
                local_batch=local_batch, seq=seq,
            )
            data_sh = jax.tree_util.tree_map(data_sh_fn, specs[2])
            jitted = jax.jit(
                step, in_shardings=(param_sh, server_sh, data_sh),
                donate_argnums=(0, 1),
            )
        else:
            step, shardings_for = steps_lib.make_sgd_train_step(cfg, mesh)
            specs = steps_lib.train_input_specs(cfg, gb, seq, mesh)
            in_sh, out_sh = shardings_for(specs)
            jitted = jax.jit(
                step, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=(0, 1),
            )
        lowered = jitted.lower(*specs)
    elif kind == "prefill":
        step, shardings_for = steps_lib.make_prefill_step(cfg, mesh)
        specs = steps_lib.prefill_input_specs(cfg, gb, seq)
        # no-donate: prefill creates the caches; params serve every request
        jitted = jax.jit(step, in_shardings=shardings_for(specs))
        lowered = jitted.lower(*specs)
    else:  # decode
        step, shardings_for = steps_lib.make_decode_step(cfg, mesh)
        params, token, caches, memkv = steps_lib.decode_input_specs(cfg, gb, seq)
        param_sh, token_sh, cache_sh, memkv_sh = shardings_for(
            (params, token, caches, memkv)
        )
        if cfg.is_encoder_decoder:
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, token_sh, cache_sh, memkv_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params, token, caches, memkv)
        else:
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, token_sh, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params, token, caches)
    return cfg, mesh, lowered, kind, gb, seq


def run_cell(arch: str, cell: str, mesh_kind: str = "single",
             algorithm: str = "sgd") -> dict:
    multi_pod = mesh_kind == "multi"
    chips = 512 if multi_pod else 256
    cfg = registry.get_config(arch)
    ok, why = registry.cell_applicable(cfg, cell)
    result = {
        "arch": arch, "cell": cell, "mesh": mesh_kind,
        "algorithm": algorithm, "chips": chips,
        "timestamp": time.time(),
    }
    if not ok:
        result.update(status="skipped", reason=why)
        return result
    try:
        t0 = time.time()
        cfg, mesh, lowered, kind, gb, seq = _lower_cell(
            arch, cell, multi_pod, algorithm
        )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compat.cost_analysis(compiled)
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        coll_bytes = sum(v["operand_bytes"] for v in coll.values())
        flops = float(cost.get("flops", 0.0))
        # None = backend has no cost model — a different fact than a real
        # 0.0 measurement; keep the distinction in the recorded result and
        # feed the roofline a neutral 0.0 only in the unavailable case.
        bytes_acc = compat.cost_bytes_accessed(compiled)
        bytes_available = bytes_acc is not None
        # NOTE: XLA cost_analysis counts while-loop (scan) bodies once; these
        # values are structural evidence. Magnitudes come from the analytic
        # model below (validated against HLO on unscanned configs in tests).
        hlo_terms = roofline_terms(
            flops, bytes_acc if bytes_available else 0.0, coll_bytes
        )
        mesh_model = (
            analytic.MeshModel.multi() if mesh_kind_is_multi(chips)
            else analytic.MeshModel.single()
        )
        ana = analytic.analytic_roofline(cfg, kind, gb, seq, mesh_model)
        result.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                code_bytes=mem.generated_code_size_in_bytes,
                peak_hbm_bytes=(
                    mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes
                ),
            ),
            hlo_cost=dict(
                flops_per_device=flops,
                bytes_per_device=bytes_acc,  # None: cost model unavailable
                bytes_available=bytes_available,
                note="while-loop bodies counted once by XLA",
                **{f"term_{k}": round(v, 6) for k, v in hlo_terms.items()},
            ),
            collectives=coll,
            collective_bytes_per_device_hlo=coll_bytes,
            roofline={
                k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in ana.items()
                if k != "collective_breakdown"
            },
            collective_breakdown={
                k: round(v, 1) for k, v in ana["collective_breakdown"].items()
            },
        )
    except Exception as e:  # noqa: BLE001
        result.update(
            status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    return result


def run_hier_sweep(num_pods: int = 2, iters: int = 20, reps: int = 3) -> dict:
    """Pod-mesh sweep: flat vs hierarchical vs fused reduce, SHARDED.

    ``benchmarks/hier_reduce.py`` measures single-host wall clock; this
    sweep runs the same three aggregations on a real (pod, data) mesh — the
    fake-device pool this driver forces — with the inputs device_put onto
    their placement shardings, so the BENCH_hier trajectory also tracks a
    sharded measurement (ROADMAP "Multi-device BENCH_hier point"). Run it
    under a small pool (the benchmarks runner forces 8 devices); under the
    default 512-device pool it uses the first 8.
    """
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import core as drjax
    from repro.compression import int8_roundtrip

    devices = jax.devices()[: min(8, len(jax.devices()))]
    data_par = len(devices) // num_pods
    mesh = compat.make_mesh(
        (num_pods, data_par), mesh_lib.REPLICA_AXES,
        devices=devices[: num_pods * data_par],
    )
    clients_per_pod = data_par * 4  # several groups per device (weak scaling)
    n = num_pods * clients_per_pod
    d = 1 << 12
    paxes = {"pods": "pod", "clients": "data"}

    @drjax.program(partition_size=n, partition_axes=mesh_lib.REPLICA_AXES,
                   mesh=mesh)
    def flat(xs):
        return drjax.reduce_mean(xs)

    @drjax.program(placements={"pods": num_pods, "clients": clients_per_pod},
                   partition_axes=paxes, mesh=mesh)
    def hier(xs):
        return drjax.reduce_mean(xs)  # two placement-tagged stages

    @drjax.program(placements={"pods": num_pods, "clients": clients_per_pod},
                   partition_axes=paxes, mesh=mesh)
    def fused(xs):
        return drjax.hierarchical_reduce_mean(
            xs, compress_fn=int8_roundtrip
        )

    key = jax.random.PRNGKey(0)
    xs_flat = jax.device_put(
        jax.random.normal(key, (n, d), jnp.float32),
        compat.named_sharding(mesh, P(mesh_lib.REPLICA_AXES, None)),
    )
    xs_nested = jax.device_put(
        jax.random.normal(key, (num_pods, clients_per_pod, d), jnp.float32),
        compat.named_sharding(mesh, P(*mesh_lib.REPLICA_AXES, None)),
    )
    fns = [(jax.jit(flat), xs_flat),  # no-donate: bench re-reads its inputs
           (jax.jit(hier), xs_nested),  # no-donate: bench re-reads its inputs
           (jax.jit(fused), xs_nested)]  # no-donate: bench re-reads its inputs
    for fn, xs in fns:
        jax.block_until_ready(fn(xs))  # warmup/compile
    best = [float("inf")] * len(fns)
    for _ in range(reps):  # round-robin so host noise hits all variants
        for k, (fn, xs) in enumerate(fns):
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn(xs)
            jax.block_until_ready(out)
            best[k] = min(best[k], (time.perf_counter() - t0) / iters)
    from repro.launch import bench_log

    flat_us, hier_us, fused_us = (t * 1e6 for t in best)
    point = {
        "devices": len(devices),
        "mesh": {"pod": num_pods, "data": data_par},
        "n": n,
        "num_pods": num_pods,
        "payload_floats": d,
        "flat_us_per_call": flat_us,
        "hier_us_per_call": hier_us,
        "fused_us_per_call": fused_us,
        "fused_vs_flat": fused_us / flat_us,
        "hier_vs_flat": hier_us / flat_us,
    }
    path = bench_log.merge_entry({"sharded": [point]})
    print(json.dumps({"hier_sweep": point, "wrote": path}))
    return point


def result_path(arch: str, cell: str, mesh_kind: str, algorithm: str) -> str:
    tag = f"{arch}__{cell}__{mesh_kind}"
    if algorithm != "sgd":
        tag += f"__{algorithm}"
    return os.path.join(RESULTS_DIR, tag + ".json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--cell", choices=list(registry.SHAPE_CELLS))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--algorithm", choices=("sgd", "local_sgd"), default="sgd")
    ap.add_argument("--all", action="store_true",
                    help="run every missing assigned-arch cell")
    ap.add_argument("--paper", action="store_true",
                    help="dry-run the paper's local-SGD rounds (lm_350m/1b/8b)")
    ap.add_argument("--hier-sweep", action="store_true",
                    help="sharded flat/hier/fused reduce sweep on a "
                         "(pod, data) mesh; appends to BENCH_hier.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.hier_sweep:
        run_hier_sweep()
        return

    os.makedirs(RESULTS_DIR, exist_ok=True)

    def run_and_save(arch, cell, mesh_kind, algorithm):
        path = result_path(arch, cell, mesh_kind, algorithm)
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached] {os.path.basename(path)}: {prev['status']}")
                return prev
        res = run_cell(arch, cell, mesh_kind, algorithm)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        line = f"{arch} {cell} {mesh_kind} {algorithm}: {res['status']}"
        if res["status"] == "ok":
            line += (
                f" compile={res['compile_s']}s"
                f" peakHBM={res['memory']['peak_hbm_bytes']/2**30:.2f}GiB"
                f" dominant={res['roofline']['dominant']}"
                f" bound={res['roofline']['step_time_lower_bound_s']:.4f}s"
            )
        elif res["status"] == "error":
            line += " " + res["error"][:200]
        print(line, flush=True)
        return res

    if args.all:
        assigned = [a for a in registry.ARCH_IDS if not a.startswith("lm_")]
        for arch in assigned:
            for cell in registry.SHAPE_CELLS:
                for mesh_kind in ("single", "multi"):
                    run_and_save(arch, cell, mesh_kind, "sgd")
        return

    if args.paper:
        # the paper's own §4 workload: local-SGD rounds of the 350M/1B/8B
        # models, partition over ("pod",) "data" — proves the DrJAX round
        # (broadcast → vmapped local steps → reduce) lowers and shards on
        # the production meshes.
        for arch in ("lm_350m", "lm_1b", "lm_8b"):
            for mesh_kind in ("single", "multi"):
                run_and_save(arch, "train_4k", mesh_kind, "local_sgd")
        return

    if args.arch and args.cell:
        run_and_save(args.arch, args.cell, args.mesh, args.algorithm)
        return

    ap.error("pass --arch/--cell, --all, or --paper")


if __name__ == "__main__":
    main()
