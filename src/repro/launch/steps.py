"""Step-function builders: train / prefill / decode, with shardings.

This is where model, optimizer, mesh and (optionally) the DrJAX round meet:

 * ``make_sgd_train_step`` — production data+model-parallel (+FSDP) training
   step for the 40-cell dry-run table;
 * ``make_drjax_round_step`` — the paper's local-SGD/DiLoCo round, partition
   axis over ("pod", "data");
 * ``make_prefill_step`` / ``make_decode_step`` — serving steps with donated
   KV caches.

Each builder returns ``(fn, in_specs, in_shardings, out_shardings)`` ready
for ``jax.jit(...).lower(*specs)``.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
from repro.models import registry
from repro.models import partitioning
from repro.models.partitioning import axis_rules, tree_shardings
from repro.launch.mesh import REPLICA_AXES, partition_axes_for


def _is_axes_leaf(v):
    return isinstance(v, tuple) and all(
        isinstance(e, (str, type(None))) for e in v
    )


def _optimizer_axes(opt_kind: str, param_axes_tree):
    if opt_kind == "adamw":
        return {
            "step": (),
            "m": param_axes_tree,
            "v": param_axes_tree,
        }
    if opt_kind == "sgd_momentum":
        return {"step": (), "mu": param_axes_tree}
    return {"step": ()}


def _shardings(axes_tree, mesh, rules=None, spec_tree=None):
    """Axes tree -> NamedShardings; with spec_tree, dims that don't divide the
    mesh axes fall back along the rule chain (shape-aware resolution)."""
    with axis_rules(mesh, rules):
        if spec_tree is None:
            return jax.tree_util.tree_map(
                lambda ax: partitioning.named_sharding(ax),
                axes_tree,
                is_leaf=_is_axes_leaf,
            )
        return jax.tree_util.tree_map(
            lambda ax, spec: partitioning.named_sharding(ax, spec.shape),
            axes_tree,
            spec_tree,
            is_leaf=_is_axes_leaf,
        )


def _replicated(mesh):
    return NamedSharding(mesh, P())


def fsdp_rules(enable: bool):
    return {"p_fsdp": (("data",), None) if enable else (None,)}


def strategy_rules(cfg, fsdp: bool):
    """Logical-axis rules for this arch's mesh strategy.

    ``tp``: model dims shard over the "model" axis (Megatron-style), batch
    over (pod, data). Right for >=8B models where TP amortizes.
    ``dp``: the model axis is repurposed as extra data parallelism — batch
    shards over (pod, data, model), model dims replicate. Right for small
    models where per-layer TP all-reduces would dominate (see EXPERIMENTS.md
    §Perf: tp->dp moves small-model cells from collective- to compute-bound).
    """
    rules = dict(fsdp_rules(fsdp))
    if cfg.mesh_strategy == "dp":
        dp_chain = (
            REPLICA_AXES + ("model",),
            REPLICA_AXES[1:] + ("model",),
            REPLICA_AXES,
            "data",
        )
        rules.update(
            {
                "batch": dp_chain,
                "kv_batch": dp_chain,
                "heads": (None,),
                "kv_heads": (None,),
                "kv_head_dim": (None,),
                "embed": (None,),
                "ff": (None,),
                "experts": (None,),
                "vocab": (None,),
                "recurrent_width": (None,),
                "p_heads": (None,),
                "p_kv_heads": (None,),
                "p_ff": (None,),
                "p_experts": (None,),
                "p_vocab": (None,),
                "p_fsdp": ((REPLICA_AXES[1:] + ("model",),) + (("data",), None))
                if fsdp
                else (None,),
            }
        )
    return rules


# ---------------------------------------------------------------------------
# production train step (per-cell baseline)
# ---------------------------------------------------------------------------


def make_sgd_train_step(
    cfg,
    mesh,
    *,
    optimizer: str = "adamw",
    lr: float = 3e-4,
    fsdp: bool = True,
    remat: Optional[str] = None,
):
    loss_fn = functools.partial(registry.loss_fn, cfg)
    opt = optim.adamw(lr) if optimizer == "adamw" else optim.sgd(lr)
    rules = strategy_rules(cfg, fsdp)

    def train_step(params, opt_state, batch):
        with axis_rules(mesh, rules):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            updates, new_opt_state = opt.update(grads, opt_state, params)
            new_params = optim.optimizers.apply_updates(params, updates)
        return new_params, new_opt_state, loss

    p_axes = registry.param_axes(cfg)
    o_axes = _optimizer_axes(
        "adamw" if optimizer == "adamw" else "sgd", p_axes
    )
    b_axes = registry.batch_axes(cfg)

    def shardings_for(specs):
        p_spec, o_spec, b_spec = specs
        param_sh = _shardings(p_axes, mesh, rules, p_spec)
        opt_sh = _shardings(o_axes, mesh, rules, o_spec)
        batch_sh = _shardings(b_axes, mesh, rules, b_spec)
        loss_sh = _replicated(mesh)
        return (param_sh, opt_sh, batch_sh), (param_sh, opt_sh, loss_sh)

    return train_step, shardings_for


def train_input_specs(cfg, batch: int, seq: int, mesh, *, optimizer="adamw",
                      fsdp: bool = True):
    """ShapeDtypeStructs for (params, opt_state, batch)."""
    opt = optim.adamw(3e-4) if optimizer == "adamw" else optim.sgd(0.1)
    params = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg)
    )
    opt_state = jax.eval_shape(lambda: opt.init(params))
    batch_spec = registry.train_batch_spec(cfg, batch, seq)
    return params, opt_state, batch_spec


# ---------------------------------------------------------------------------
# DrJAX round step (the paper's technique, first-class)
# ---------------------------------------------------------------------------


def make_drjax_round_step(
    cfg,
    mesh,
    *,
    partition_size: int,
    num_local_steps: int = 4,
    client_lr: float = 0.05,
    server: str = "fedavg",  # fedavg | diloco | fedadam
    use_sharding_annotations: bool = True,
    compression: Optional[str] = None,
    fsdp: bool = False,
    jit_donated: bool = False,
):
    loss_fn = functools.partial(registry.loss_fn, cfg)
    server_opt = {
        "fedavg": optim.fedavg_momentum(1.0),
        "diloco": optim.diloco_optimizer(0.7, 0.9),
        "fedadam": optim.fedadam(1e-2),
    }[server]
    round_cfg = LocalSGDConfig(
        partition_size=partition_size,
        num_local_steps=num_local_steps,
        partition_axes=partition_axes_for(mesh),
        mesh=mesh,
        use_sharding_annotations=use_sharding_annotations,
        compression=compression,
    )
    inner = make_local_sgd_round(
        loss_fn, optim.sgd(client_lr), server_opt, round_cfg
    )
    rules = strategy_rules(cfg, fsdp)
    # Inside drjax.map_fn the partition axes (pod, data) belong to vmap's
    # spmd_axis_name and must NOT appear in client-side constraints. The
    # within-client batch may still shard over the remaining "model" axis
    # (dp strategy): clients × within-client parallelism compose (paper §3).
    client_batch_chain = ("model", None) if cfg.mesh_strategy == "dp" else (None,)
    rules["batch"] = client_batch_chain
    rules["kv_batch"] = client_batch_chain

    def round_step(params, server_state, round_data):
        with axis_rules(mesh, rules):
            return inner(params, server_state, round_data)

    if jit_donated:
        # The round-loop donation discipline (same as dryrun's jit of this
        # step): params + server_state are carried state and update in place.
        round_step = jax.jit(round_step, donate_argnums=(0, 1))

    p_axes = registry.param_axes(cfg)
    param_sh = _shardings(p_axes, mesh, rules)
    server_sh = _shardings(
        {"step": (), "mu": p_axes} if server == "diloco" else
        ({"step": (), "m": p_axes, "v": p_axes} if server == "fedadam" else
         {"step": ()}),
        mesh, rules,
    )
    # round data: leading clients axis over (pod, data)
    part_axes = partition_axes_for(mesh)
    lead = part_axes if isinstance(part_axes, (str, type(None))) else tuple(part_axes)

    def data_sharding(spec):
        return NamedSharding(mesh, P(lead, *([None] * (len(spec.shape) - 1))))

    return round_step, param_sh, server_sh, data_sharding


def drjax_round_specs(cfg, *, partition_size: int, num_local_steps: int,
                      local_batch: int, seq: int, server: str = "fedavg"):
    params = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg)
    )
    server_opt = {
        "fedavg": optim.fedavg_momentum(1.0),
        "diloco": optim.diloco_optimizer(),
        "fedadam": optim.fedadam(),
    }[server]
    server_state = jax.eval_shape(lambda: server_opt.init(params))
    data = {
        "tokens": jax.ShapeDtypeStruct(
            (partition_size, num_local_steps, local_batch, seq), jnp.int32
        ),
        "labels": jax.ShapeDtypeStruct(
            (partition_size, num_local_steps, local_batch, seq), jnp.int32
        ),
    }
    return params, server_state, data


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg, mesh, *, fsdp: Optional[bool] = None,
                      tp_comm: Optional[str] = None,
                      max_len: Optional[int] = None):
    if tp_comm:
        import dataclasses
        cfg = dataclasses.replace(cfg, tp_comm=tp_comm)
    fsdp = (cfg.family == "moe") if fsdp is None else fsdp
    # serving always uses TP rules: memory (weights + KV) binds at decode,
    # so caches shard over the model axis regardless of the train strategy.
    rules = fsdp_rules(fsdp)
    # max_len sizes the prefill-built KV caches for the decode loop that
    # consumes them (the serve scheduler passes prompt_len + max_new).
    inner = registry.make_prefill_fn(cfg, max_len=max_len)

    def prefill_step(params, batch):
        with axis_rules(mesh, rules):
            return inner(params, batch)

    def shardings_for(specs):
        params, batch = specs
        param_sh = _shardings(registry.param_axes(cfg), mesh, rules, params)
        batch_sh = _shardings(registry.batch_axes(cfg), mesh, rules, batch)
        return (param_sh, batch_sh)

    return prefill_step, shardings_for


def make_decode_step(cfg, mesh, *, fsdp: Optional[bool] = None):
    fsdp = (cfg.family == "moe") if fsdp is None else fsdp
    rules = fsdp_rules(fsdp)  # TP rules at serve (see make_prefill_step)
    inner = registry.make_decode_fn(cfg)

    if cfg.is_encoder_decoder:

        def decode_step(params, token, caches, memory_kv):
            with axis_rules(mesh, rules):
                return inner(params, token, caches, memory_kv)

    else:

        def decode_step(params, token, caches):
            with axis_rules(mesh, rules):
                return inner(params, token, caches)

    mod = registry.family_module(cfg)

    def shardings_for(specs):
        params, token, caches, memkv = specs
        param_sh = _shardings(registry.param_axes(cfg), mesh, rules, params)
        cache_axes = (
            mod.cache_axes(cfg) if hasattr(mod, "cache_axes")
            else _encdec_cache_axes(cfg)
        )
        with axis_rules(mesh, rules):
            token_sh = partitioning.named_sharding(("batch", None), token.shape)
            cache_sh = jax.tree_util.tree_map(
                lambda ax, spec: partitioning.named_sharding(ax, spec.shape),
                cache_axes,
                caches,
                is_leaf=_is_axes_leaf,
            )
            memkv_sh = None
            if cfg.is_encoder_decoder:
                memkv_sh = tuple(
                    partitioning.named_sharding(
                        ("layers", "kv_batch", "seq", "kv_heads", "head_dim"),
                        m.shape,
                    )
                    for m in memkv
                )
        return (param_sh, token_sh, cache_sh, memkv_sh)

    return decode_step, shardings_for


# ---------------------------------------------------------------------------
# continuous-batching serve steps (slot pool — see launch/serve.py)
# ---------------------------------------------------------------------------


def _gather_slot(pool, dims, cslot):
    """Slice one slot out of the pool as a batch-1 cache tree.

    Batch-bearing leaves keep a size-1 batch axis (``keepdims``) — exactly
    the shape ``chunk_prefill`` consumes; pos-like leaves drop their leading
    slot axis back to the per-request layout.
    """

    def one(leaf, d):
        if d == registry.POS_LEAF:
            return jax.lax.dynamic_index_in_dim(leaf, cslot, 0, keepdims=False)
        return jax.lax.dynamic_index_in_dim(leaf, cslot, d, keepdims=True)

    return jax.tree_util.tree_map(one, pool, dims)


def _scatter_slot(pool, cache, dims, cslot):
    """Write a batch-1 cache tree back into its slot (in place under jit)."""

    def one(pl, cl, d):
        if d == registry.POS_LEAF:
            return jax.lax.dynamic_update_slice_in_dim(pl, cl[None], cslot, 0)
        return jax.lax.dynamic_update_slice_in_dim(pl, cl, cslot, d)

    return jax.tree_util.tree_map(one, pool, cache, dims)


def _reset_if(first, cache):
    """Zero a gathered slot cache when ``first`` (slot reuse: stale KV /
    recurrent state / pos from the previous occupant must not leak)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.where(first, jnp.zeros_like(l), l), cache
    )


def _make_one_slot_decode(cfg):
    """Batch-1 decode of a single slot, for ``vmap`` over the slot axis.

    vmap strips the slot axis from every pool leaf; batch-bearing leaves
    re-insert a size-1 batch axis at their metadata index so the stock decode
    fn sees its normal (batch=1) layout. Per-slot decode also makes decode
    batch-size-invariant — MoE capacity assignment couples tokens across a
    batch, so decoding slots jointly would make a request's tokens depend on
    who else is in flight.
    """
    decode_fn = registry.make_decode_fn(cfg)
    dims = registry.cache_batch_dims(cfg)

    def one_slot(params, token, caches):
        caches = jax.tree_util.tree_map(
            lambda l, d: l if d == registry.POS_LEAF else jnp.expand_dims(l, d),
            caches,
            dims,
        )
        logits, new = decode_fn(params, token[None], caches)
        new = jax.tree_util.tree_map(
            lambda l, d: l if d == registry.POS_LEAF else jnp.squeeze(l, d),
            new,
            dims,
        )
        return logits[0], new

    return one_slot


def make_slot_decode_step(cfg, mesh, *, fsdp: Optional[bool] = None):
    """Decode every slot of the pool one token.

    ``slot_decode_step(params, tokens (slots, 1), pool)`` ->
    ``(next_tokens (slots, 1), pool)``. The greedy next token is computed on
    device so the scheduler can chain steps without a host round-trip; free
    slots decode garbage that the host never reads (fixed shapes beat
    masking — no recompilation as slots fill/drain).
    """
    fsdp = (cfg.family == "moe") if fsdp is None else fsdp
    rules = fsdp_rules(fsdp)  # TP rules at serve (see make_prefill_step)
    one_slot = _make_one_slot_decode(cfg)
    axes = registry.slot_vmap_axes(cfg)

    def slot_decode_step(params, tokens, pool):
        with axis_rules(mesh, rules):
            logits, pool = jax.vmap(
                one_slot, in_axes=(None, 0, axes), out_axes=(0, axes)
            )(params, tokens, pool)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return nxt, pool

    return slot_decode_step


def make_slot_chunk_step(cfg, mesh, *, fsdp: Optional[bool] = None):
    """Prefill one prompt chunk into one slot (no decode leg).

    ``slot_chunk_step(params, pool, cslot, ctokens (C,), cpos, cfirst)`` ->
    ``(chunk_token (), pool)``. Shapes specialize on the chunk length C —
    one trace per chunk bucket. ``cfirst`` (traced bool) zero-resets the slot
    before the first chunk so slot reuse never reallocates. The returned
    token is the greedy continuation after the chunk — only meaningful on a
    prompt's final chunk.
    """
    fsdp = (cfg.family == "moe") if fsdp is None else fsdp
    rules = fsdp_rules(fsdp)
    chunk_fn = registry.make_chunk_prefill_fn(cfg)
    dims = registry.cache_batch_dims(cfg)

    def slot_chunk_step(params, pool, cslot, ctokens, cpos, cfirst):
        with axis_rules(mesh, rules):
            cache = _reset_if(cfirst, _gather_slot(pool, dims, cslot))
            logits, cache = chunk_fn(params, ctokens[None], cache, cpos)
            pool = _scatter_slot(pool, cache, dims, cslot)
            ctok = jnp.argmax(logits[0], -1).astype(jnp.int32)
        return ctok, pool

    return slot_chunk_step


def make_serve_step(cfg, mesh, *, fsdp: Optional[bool] = None):
    """Fused continuous-batching step: decode all slots + one prefill chunk.

    ``serve_step(params, tokens (slots, 1), pool, cslot, ctokens (C,), cpos,
    cfirst, cemit)`` -> ``(next_tokens (slots, 1), pool)``. A newly admitted
    request's prefill chunk rides inside the same compiled step as the
    in-flight decodes, so admission never stalls decoding. The chunked
    slot's cache is gathered *before* the decode leg and scattered back
    *after* it — the decode leg's garbage write to that slot (it decodes
    every slot unconditionally) is overwritten wholesale, which is what
    makes at-most-one-request-mid-prefill a safe invariant. When ``cemit``
    is set (final chunk of a prompt) the chunk's greedy token is spliced
    into the device-side token feed at ``cslot`` so the request starts
    decoding on the very next step.
    """
    fsdp = (cfg.family == "moe") if fsdp is None else fsdp
    rules = fsdp_rules(fsdp)
    one_slot = _make_one_slot_decode(cfg)
    chunk_fn = registry.make_chunk_prefill_fn(cfg)
    dims = registry.cache_batch_dims(cfg)
    axes = registry.slot_vmap_axes(cfg)

    def serve_step(params, tokens, pool, cslot, ctokens, cpos, cfirst, cemit):
        with axis_rules(mesh, rules):
            cache = _reset_if(cfirst, _gather_slot(pool, dims, cslot))
            logits, pool = jax.vmap(
                one_slot, in_axes=(None, 0, axes), out_axes=(0, axes)
            )(params, tokens, pool)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            clogits, cache = chunk_fn(params, ctokens[None], cache, cpos)
            pool = _scatter_slot(pool, cache, dims, cslot)
            ctok = jnp.argmax(clogits[0], -1).astype(jnp.int32)
            nxt = nxt.at[cslot, 0].set(
                jnp.where(cemit, ctok, nxt[cslot, 0])
            )
        return nxt, pool

    return serve_step


def _encdec_cache_axes(cfg):
    from repro.models import attention

    base = attention.cache_axes(cfg)
    return jax.tree_util.tree_map(
        lambda ax: ("layers",) + ax, base, is_leaf=_is_axes_leaf
    )


def decode_input_specs(cfg, batch: int, max_len: int):
    params = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg)
    )
    caches, extras = registry.decode_state_spec(cfg, batch, max_len)
    token = registry.decode_token_spec(cfg, batch)
    return params, token, caches, extras.get("memory_kv")


def prefill_input_specs(cfg, batch: int, seq: int):
    params = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg)
    )
    return params, registry.prefill_spec(cfg, batch, seq)
