"""Continuous-batching serve runtime over a slot-based KV-cache pool.

CPU-scale demo (reduced config):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --reduced \
        --requests 8 --max-new 16

Two schedulers share the same compiled building blocks
(:mod:`repro.launch.steps` slot-pool steps):

* :class:`ContinuousBatchingScheduler` — the production path. Requests are
  admitted *per step* from an arrival queue; a new request's prefill chunks
  ride inside the same compiled step as the in-flight decodes (fused
  ``make_serve_step``), so admission never stalls decoding and a finished
  slot is reassigned on the next step with no reallocation.
* :class:`StaticWaveScheduler` — the baseline the benchmark compares
  against: admit a wave, prefill it, decode it in lockstep, drain it
  entirely before admitting the next wave.

Admission model
---------------
At most ONE request is mid-prefill at any time. Its slot cache is gathered
*before* the fused step's decode leg and scattered back *after* it, so the
decode leg (which decodes every slot unconditionally — fixed shapes, no
masks) can never corrupt a partial prefill. Free slots decode garbage the
host discards. A request is admitted when a slot is free and
``prompt_len + max_new <= max_len``; its slot is zero-reset by the first
chunk (``cfirst``), so slot reuse is allocation-free for the life of the
server.

Bucketing knobs
---------------
Prompts are cut into power-of-two chunks ``<= chunk`` (greedy, largest
first, NO padding — padding would corrupt recurrent rglru/rwkv state). The
executable set is therefore bounded: one fused step per chunk bucket plus
one decode-only step, regardless of traffic. ``TraceCounter`` wraps both
legs; the steady-state invariant is *flat trace counts under arbitrary
traffic* (``prefill_traces`` / ``decode_traces``), asserted in
``tests/test_serve.py`` and ``benchmarks/serve.py``.

Donation posture
----------------
The slot pool is the scheduler's round-to-round state: every compiled step
donates it (``donate_argnums``) so XLA updates the fixed ``(slots, ...)``
buffers in place — no per-token cache copies, no allocation after startup.
Params are never donated (they serve every step); the token feed is not
donated because the host still fetches the *previous* step's tokens while
the next step runs.

Async-dispatch discipline
-------------------------
The host stays one step ahead of the device: step ``t`` is dispatched
before the host does bookkeeping for step ``t-1`` (one batched
``jax.device_get`` per step — never per-request scalar pulls), so
admission, slot bookkeeping, EOS handling and detokenization-equivalents
overlap the device compute. Greedy sampling chains on device
(``next_tokens`` feeds the next step without a host round-trip).

Termination: a slot stops as soon as ``cfg.eos_id`` is emitted (the EOS
token is kept in ``generated``) or after ``max_new`` tokens.
"""

from __future__ import annotations

import argparse
import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import registry
from repro.runtime.executor import TraceCounter

DEFAULT_CHUNK = 16


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    arrival: float = 0.0  # seconds on the scheduler clock
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # timing (scheduler-clock seconds; filled by the schedulers)
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    token_times: List[float] = field(default_factory=list)


def chunk_schedule(n: int, chunk_max: int) -> List[int]:
    """Greedy binary decomposition of a prompt length into power-of-two
    chunks ``<= chunk_max``.

    Exact (no padding — padded positions would advance recurrent
    rglru/rwkv state) and bounded: every prompt length maps into the same
    ``log2(chunk_max)+1`` chunk buckets, so the compiled-step set stays
    fixed under arbitrary traffic.
    """
    if n <= 0 or chunk_max <= 0:
        raise ValueError(f"need n > 0 and chunk_max > 0, got {n}, {chunk_max}")
    out = []
    c = 1 << (chunk_max.bit_length() - 1)
    while n:
        while c > n:
            c >>= 1
        out.append(c)
        n -= c
    return out


@dataclass
class _Slot:
    req: Request
    chunks: List[int]
    pos: int = 0
    first: bool = True
    phase: str = "prefill"  # prefill | decode


class _SchedulerBase:
    """Shared slot-pool state + host-side bookkeeping.

    ``fault_hook(step_index)`` is the scheduler-level chaos hook: it is
    called once per dispatched-or-idle scheduler step with the monotonic
    1-based ``step_index`` and may raise (e.g.
    :class:`~repro.runtime.failure.SimulatedDeviceFailure`) to simulate a
    serving-fleet fault mid-run. A harness that catches the fault calls
    :meth:`reset_slots` and re-submits the unfinished requests — slot reuse
    is allocation-free (the first prefill chunk zero-resets a slot), so
    recovery never reallocates the pool and never retraces a compiled step.
    """

    def __init__(self, cfg, params, slots: int, max_len: int,
                 chunk: int = DEFAULT_CHUNK, mesh=None, fault_hook=None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len, self.chunk = slots, max_len, chunk
        self.eos_id = cfg.eos_id
        self.fault_hook = fault_hook
        self.step_index = 0  # monotonic across run() calls
        self._pool = registry.init_slot_pool(cfg, slots, max_len)
        self._tokens = jnp.zeros((slots, 1), jnp.int32)
        self._slots: List[Optional[_Slot]] = [None] * slots
        self._prefill_counter = TraceCounter()
        self._decode_counter = TraceCounter()
        decode_step = steps_lib.make_slot_decode_step(cfg, mesh)
        self._decode = jax.jit(
            self._decode_counter.wrap(decode_step), donate_argnums=(2,)
        )

    def _tick(self) -> None:
        self.step_index += 1
        if self.fault_hook is not None:
            self.fault_hook(self.step_index)

    def reset_slots(self) -> None:
        """Drop all in-flight work after a fault: free every slot and zero
        the token feed. The pool buffers are kept — a reused slot is
        zero-reset by its first chunk — and trace counters are untouched,
        so post-recovery steps hit the same executables."""
        self._slots = [None] * self.slots
        self._tokens = jnp.zeros((self.slots, 1), jnp.int32)

    @property
    def prefill_traces(self) -> int:
        """Compiled-prefill trace count: one per chunk bucket, then flat."""
        return self._prefill_counter.count

    @property
    def decode_traces(self) -> int:
        """Decode trace count: one (fixed slot shapes), then flat."""
        return self._decode_counter.count

    def _check(self, req: Request):
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}"
            )

    def _collect(self, tokens_np, meta, clock: float) -> int:
        """Apply one fetched step's tokens to the requests that produced
        them. ``meta`` is the (slot, request) list snapshotted at dispatch —
        a request that finished in the interim (one-step dispatch lag)
        contributes no further tokens. Returns #requests finished."""
        ndone = 0
        for slot, req in meta:
            if req.done:
                continue
            tok = int(tokens_np[slot, 0])
            req.generated.append(tok)
            req.token_times.append(clock)
            if req.t_first is None:
                req.t_first = clock
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(req.generated) >= req.max_new:
                req.done = True
                req.t_done = clock
                self._slots[slot] = None  # slot freed; reassigned, not realloc'd
                ndone += 1
        return ndone


class ContinuousBatchingScheduler(_SchedulerBase):
    """Per-step admission; prefill chunks fused into the decode step."""

    def __init__(self, cfg, params, slots: int, max_len: int,
                 chunk: int = DEFAULT_CHUNK, mesh=None, fault_hook=None):
        super().__init__(cfg, params, slots, max_len, chunk, mesh, fault_hook)
        serve_step = steps_lib.make_serve_step(cfg, mesh)
        # one trace per chunk bucket (ctokens shape specializes the step)
        self._serve = jax.jit(
            self._prefill_counter.wrap(serve_step), donate_argnums=(2,)
        )
        self._mid_prefill: Optional[int] = None

    def reset_slots(self) -> None:
        super().reset_slots()
        self._mid_prefill = None

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Drive all ``requests`` to completion, honoring ``arrival`` times
        on the scheduler clock (which advances by measured step wall time).
        """
        reqs = sorted(requests, key=lambda r: r.arrival)
        for r in reqs:
            self._check(r)
        clock = 0.0
        arrive_i = 0
        waiting: Deque[Request] = deque()
        pending: Deque[Tuple[jax.Array, list]] = deque()
        remaining = len(reqs)

        while remaining:
            t0 = time.perf_counter()
            self._tick()
            while arrive_i < len(reqs) and reqs[arrive_i].arrival <= clock:
                waiting.append(reqs[arrive_i])
                arrive_i += 1

            # admission: one request per step, single-mid-prefill invariant
            if self._mid_prefill is None and waiting:
                free = next(
                    (i for i, s in enumerate(self._slots) if s is None), None
                )
                if free is not None:
                    req = waiting.popleft()
                    self._slots[free] = _Slot(
                        req=req,
                        chunks=chunk_schedule(len(req.prompt), self.chunk),
                    )
                    self._mid_prefill = free

            meta = [
                (i, s.req)
                for i, s in enumerate(self._slots)
                if s is not None and s.phase == "decode"
            ]
            dispatched = True
            if self._mid_prefill is not None:
                i = self._mid_prefill
                st = self._slots[i]
                c = st.chunks.pop(0)
                ctokens = jnp.asarray(
                    st.req.prompt[st.pos : st.pos + c], jnp.int32
                )
                emit = not st.chunks
                self._tokens, self._pool = self._serve(
                    self.params,
                    self._tokens,
                    self._pool,
                    jnp.asarray(i, jnp.int32),
                    ctokens,
                    jnp.asarray(st.pos, jnp.int32),
                    jnp.asarray(st.first),
                    jnp.asarray(emit),
                )
                st.pos += c
                st.first = False
                if emit:  # chunk token spliced into the feed at slot i
                    st.phase = "decode"
                    self._mid_prefill = None
                    meta.append((i, st.req))
            elif meta:
                self._tokens, self._pool = self._decode(
                    self.params, self._tokens, self._pool
                )
            else:
                dispatched = False

            if dispatched:
                pending.append((self._tokens, meta))

            # host bookkeeping for earlier steps while this one runs on
            # device; keep exactly one step in flight
            while len(pending) > (1 if dispatched else 0):
                toks, m = pending.popleft()
                arr = np.asarray(jax.device_get(toks))  # one batched fetch
                remaining -= self._collect(arr, m, clock)

            if not dispatched and not pending:
                # idle: jump the clock to the next arrival
                if arrive_i < len(reqs):
                    clock = max(clock, reqs[arrive_i].arrival)
                continue
            clock += time.perf_counter() - t0

        return {r.rid: r.generated for r in reqs}


class StaticWaveScheduler(_SchedulerBase):
    """Wave-at-a-time baseline: admit up to ``slots`` requests, prefill them
    one by one (chunk steps into their slots), decode the wave in lockstep,
    and drain it completely before admitting the next wave. Shares the
    per-slot decode step (and chunk decomposition) with the continuous
    scheduler, so its outputs are the greedy oracle the continuous path is
    tested token-identical against — only the *scheduling* differs.
    """

    def __init__(self, cfg, params, batch: int, max_len: int,
                 chunk: int = DEFAULT_CHUNK, mesh=None, fault_hook=None):
        super().__init__(cfg, params, batch, max_len, chunk, mesh, fault_hook)
        self.batch = batch
        chunk_step = steps_lib.make_slot_chunk_step(cfg, mesh)
        self._chunk = jax.jit(
            self._prefill_counter.wrap(chunk_step), donate_argnums=(1,)
        )

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        reqs = sorted(requests, key=lambda r: r.arrival)
        for r in reqs:
            self._check(r)
        clock = 0.0
        arrive_i = 0
        waiting: Deque[Request] = deque()
        ndone = 0
        while ndone < len(reqs):
            while arrive_i < len(reqs) and reqs[arrive_i].arrival <= clock:
                waiting.append(reqs[arrive_i])
                arrive_i += 1
            if not waiting:
                clock = max(clock, reqs[arrive_i].arrival)
                continue
            wave = [waiting.popleft()
                    for _ in range(min(self.batch, len(waiting)))]
            clock = self._run_wave(wave, clock)
            ndone += len(wave)
        return {r.rid: r.generated for r in reqs}

    def run_wave(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Single-wave entry point (legacy API used by older tests)."""
        assert len(requests) <= self.batch
        self._run_wave(list(requests), 0.0)
        return {r.rid: r.generated for r in requests}

    def _run_wave(self, wave: List[Request], clock: float) -> float:
        # --- prefill, one request at a time into its slot ---
        first = np.zeros((self.slots, 1), np.int32)
        for slot, req in enumerate(wave):
            t0 = time.perf_counter()
            self._tick()
            pos, cfirst, ctok = 0, True, None
            for c in chunk_schedule(len(req.prompt), self.chunk):
                ctok, self._pool = self._chunk(
                    self.params,
                    self._pool,
                    jnp.asarray(slot, jnp.int32),
                    jnp.asarray(req.prompt[pos : pos + c], jnp.int32),
                    jnp.asarray(pos, jnp.int32),
                    jnp.asarray(cfirst),
                )
                pos += c
                cfirst = False
            self._slots[slot] = _Slot(req=req, chunks=[], phase="decode")
            # wave-granular sync: the baseline blocks once per request here
            tok = int(ctok)
            clock += time.perf_counter() - t0
            first[slot, 0] = tok
            req.generated.append(tok)
            req.token_times.append(clock)
            req.t_first = clock
            if (self.eos_id is not None and tok == self.eos_id) or req.max_new <= 1:
                req.done = True
                req.t_done = clock
                self._slots[slot] = None

        # --- lockstep decode with the one-step-lag batched-fetch loop ---
        self._tokens = jnp.asarray(first)
        prev = None
        while True:
            t0 = time.perf_counter()
            self._tick()
            meta = [
                (i, s.req) for i, s in enumerate(self._slots) if s is not None
            ]
            dispatched = bool(meta)
            if dispatched:
                self._tokens, self._pool = self._decode(
                    self.params, self._tokens, self._pool
                )
            if prev is not None:
                toks, m = prev
                arr = np.asarray(jax.device_get(toks))  # one batched fetch
                self._collect(arr, m, clock)
                prev = None
            if not dispatched:
                break
            prev = (self._tokens, meta)
            clock += time.perf_counter() - t0
        for slot in range(self.slots):
            self._slots[slot] = None
        return clock


# legacy name: the static scheduler is the old BatchScheduler's successor
BatchScheduler = StaticWaveScheduler


def poisson_trace(rng, n: int, rate: float) -> List[float]:
    """Arrival times for ``n`` requests at ``rate`` req/s (Poisson process)."""
    gaps = rng.exponential(1.0 / rate, size=n)
    return list(np.cumsum(gaps))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b", choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scheduler", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=DEFAULT_CHUNK)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = all at t=0")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder or cfg.family == "vlm":
        raise SystemExit("serve runtime targets token-only decoder archs")

    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    arrivals = (
        poisson_trace(rng, args.requests, args.rate)
        if args.rate > 0
        else [0.0] * args.requests
    )
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=(args.prompt_len,))
            .astype(np.int32),
            max_new=args.max_new,
            arrival=arrivals[i],
        )
        for i in range(args.requests)
    ]
    cls = (
        ContinuousBatchingScheduler
        if args.scheduler == "continuous"
        else StaticWaveScheduler
    )
    sched = cls(cfg, params, args.slots,
                max_len=args.prompt_len + args.max_new, chunk=args.chunk)
    t0 = time.time()
    results = sched.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    ttfts = [r.t_first - r.arrival for r in reqs]
    print(json.dumps({
        "arch": cfg.name,
        "scheduler": args.scheduler,
        "requests": len(reqs),
        "generated_tokens": total_tokens,
        "wall_s": round(dt, 2),
        "tokens_per_s": round(total_tokens / dt, 1),
        "ttft_p50_s": round(float(np.percentile(ttfts, 50)), 4),
        "prefill_traces": sched.prefill_traces,
        "decode_traces": sched.decode_traces,
        "pool_mb": round(
            registry.slot_pool_bytes(cfg, args.slots,
                                     args.prompt_len + args.max_new) / 2**20,
            2,
        ),
    }))


if __name__ == "__main__":
    main()
