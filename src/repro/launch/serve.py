"""Batched serving driver: continuous-batching loop over prefill + decode.

CPU-scale demo (reduced config):

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --reduced \
        --requests 8 --max-new 16

Production posture: the same prefill/decode step functions lower on the
16×16 / 2×16×16 meshes (see launch/dryrun.py decode cells); the scheduler
below is mesh-agnostic.
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import registry
from repro.runtime.executor import TraceCounter


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Static-batch scheduler: admits up to ``batch`` requests per wave,
    prefills them together (right-padded), then decodes in lockstep with an
    active-mask; finished slots are masked out (fixed-shape steps — no
    recompilation as requests finish).

    Both legs run compiled: prefill goes through the same
    :func:`repro.launch.steps.make_prefill_step` builder the dry-run meshes
    lower (jitted, KV caches sized to ``max_len``; one trace per distinct
    prompt length — ``prefill_traces`` exposes the count), and the decode
    step donates the KV caches so the decode loop updates them in place
    instead of copying ``batch * max_len`` of cache every token.
    """

    def __init__(self, cfg, params, batch: int, max_len: int):
        self.cfg, self.params = cfg, params
        self.batch, self.max_len = batch, max_len
        prefill_step, _ = steps_lib.make_prefill_step(
            cfg, mesh=None, max_len=max_len
        )
        self._prefill_counter = TraceCounter()
        # no-donate: params serve every wave; prefill CREATES the caches.
        self._prefill = jax.jit(self._prefill_counter.wrap(prefill_step))
        decode_step, _ = steps_lib.make_decode_step(cfg, mesh=None)
        self._decode = jax.jit(decode_step, donate_argnums=(2,))

    @property
    def prefill_traces(self) -> int:
        return self._prefill_counter.count

    def run_wave(self, requests: List[Request]) -> Dict[int, List[int]]:
        assert len(requests) <= self.batch
        lens = [len(r.prompt) for r in requests]
        s = max(lens)
        toks = np.zeros((len(requests), s), np.int32)
        for i, r in enumerate(requests):
            toks[i, : lens[i]] = r.prompt  # left-aligned
        last_logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)}
        )
        token = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
        active = np.ones((len(requests),), bool)
        steps = max(r.max_new for r in requests)
        for t in range(steps):
            for i, r in enumerate(requests):
                if active[i]:
                    r.generated.append(int(token[i, 0]))
                    if len(r.generated) >= r.max_new:
                        active[i] = False
            if not active.any():
                break
            logits, caches = self._decode(self.params, token, caches)
            token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for r in requests:
            r.done = True
        return {r.rid: r.generated for r in requests}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b", choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder or cfg.family == "vlm":
        raise SystemExit("serve demo targets decoder-only archs")

    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=(args.prompt_len,))
            .astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    sched = BatchScheduler(cfg, params, args.batch,
                           max_len=args.prompt_len + args.max_new)
    t0 = time.time()
    results = {}
    for i in range(0, len(reqs), args.batch):
        results.update(sched.run_wave(reqs[i : i + args.batch]))
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in results.values())
    print(json.dumps({
        "arch": cfg.name,
        "requests": len(reqs),
        "generated_tokens": total_tokens,
        "wall_s": round(dt, 2),
        "tokens_per_s": round(total_tokens / dt, 1),
    }))


if __name__ == "__main__":
    main()
