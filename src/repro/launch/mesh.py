"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches JAX device state — the dry-run driver must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.

All construction goes through ``repro.compat`` so the same meshes build on
any supported JAX version (axis types are applied only where the API has
them; older versions have the equivalent Auto-only semantics).
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax

from repro import compat

# Canonical replica axes of the production mesh, outermost first. This module
# is the single home for mesh axis-name tuples — everywhere else imports
# these (enforced by the ``mesh-axes-literal`` lint rule).
REPLICA_AXES = ("pod", "data")

# Mesh axis name per *replica* placement level, innermost-first: the
# innermost level always owns "data" (fast ICI), its parent "pod" (DCN), a
# grandparent "superpod". Deeper stacks get generated "repl<depth>" names.
_REPLICA_LEVEL_AXES = ("data", "pod", "superpod")


def _normalize_stack(placements) -> Tuple[Tuple[str, int, str], ...]:
    """Any placement-stack spec -> ((name, size, kind), ...), outermost first.

    Accepts a ``Mapping[name, size]`` (all levels replica-kind), a
    ``PlacementContext``, or a sequence of ``Placement``s / ``(name, size[,
    kind])`` tuples."""
    if hasattr(placements, "placements"):  # PlacementContext
        placements = placements.placements
    if isinstance(placements, Mapping):
        return tuple(
            (str(n), int(s), "replicas") for n, s in placements.items()
        )
    out = []
    for p in placements:
        if hasattr(p, "name"):  # Placement
            out.append((p.name, p.size, getattr(p, "kind", "replicas")))
        else:
            entry = tuple(p)
            kind = str(entry[2]) if len(entry) > 2 else "replicas"
            out.append((str(entry[0]), int(entry[1]), kind))
    return tuple(out)


def level_axes_for(placements) -> Tuple[str, ...]:
    """Mesh axis name for each placement level, outermost first.

    Replica levels factorize innermost-out over ``(data, pod, superpod,
    repl4, ...)`` — so a flat stack gets ``("data",)``, a 2-level stack
    ``("pod", "data")`` (byte-identical to the historical hard-coded pair),
    and a 3-level stack ``("superpod", "pod", "data")``. Stage-kind levels
    get the ``"stage"`` axis (then ``"stage2"``, ...), independent of the
    replica numbering, e.g. ``(stage, data)`` for a pipeline over
    data-parallel replicas."""
    stack = _normalize_stack(placements)
    n_replica = sum(1 for _, _, k in stack if k != "stages")
    axes = []
    replica_seen = 0
    stage_seen = 0
    for _name, _size, kind in stack:
        if kind == "stages":
            axes.append("stage" if stage_seen == 0 else f"stage{stage_seen + 1}")
            stage_seen += 1
        else:
            depth_from_inner = n_replica - 1 - replica_seen
            if depth_from_inner < len(_REPLICA_LEVEL_AXES):
                axes.append(_REPLICA_LEVEL_AXES[depth_from_inner])
            else:
                axes.append(f"repl{depth_from_inner + 1}")
            replica_seen += 1
    return tuple(axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (data, model) or 2×16×16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Optional[jax.sharding.Mesh]:
    """Best-effort mesh over whatever devices exist (CPU smoke / degraded pod)."""
    n = jax.device_count()
    if n == 1:
        return None
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))


def partition_axes_for(mesh: Optional[jax.sharding.Mesh]):
    """DrJAX partition axes on this mesh: ("pod", "data") when pods exist
    (prefixed with "superpod" on a 3-level mesh)."""
    if mesh is None:
        return None
    names = mesh.axis_names
    if "pod" in names:
        axes = REPLICA_AXES
        if "superpod" in names:
            axes = ("superpod",) + axes
        return axes
    if "data" in names:
        return "data"
    return None


def placement_axes_for(
    mesh: Optional[jax.sharding.Mesh],
    placements=None,
) -> Optional[Dict[str, str]]:
    """Per-placement mesh axes for a placement stack on this mesh.

    Without ``placements`` (legacy): the nested {"pods", "clients"} stack —
    pods pin the slow DCN ``"pod"`` axis, clients the ICI ``"data"`` axis,
    the assignment that makes the two legs of a hierarchical reduction land
    on the interconnects they were designed for. Degrades gracefully: a
    single-pod mesh leaves pods logical (no pod axis to pin).

    With ``placements`` (any spec ``_normalize_stack`` accepts): the N-level
    generalization — each level is assigned its :func:`level_axes_for` axis,
    dropping levels whose axis the mesh does not carry."""
    if mesh is None:
        return None
    names = mesh.axis_names
    if placements is None:
        axes: Dict[str, str] = {}
        if "pod" in names:
            axes["pods"] = "pod"
        if "data" in names:
            axes["clients"] = "data"
        return axes or None
    stack = _normalize_stack(placements)
    level = level_axes_for(stack)
    axes = {nm: ax for (nm, _s, _k), ax in zip(stack, level) if ax in names}
    return axes or None


def mesh_for_placements(
    placements, model_parallel: int = 1, *, devices=None
) -> jax.sharding.Mesh:
    """A mesh with one device axis per placement (plus optional "model").

    Any ordered stack factorizes: ``{"clients": n}`` yields the classic
    ``("data"[, "model"])`` mesh, ``{"pods": P, "clients": m}`` the
    ``("pod", "data"[, "model"])`` pair (the outermost placement owns the
    slowest interconnect dimension), ``{"superpods": S, "pods": P,
    "clients": m}`` the 3-level ``("superpod", "pod", "data")`` mesh, and a
    stage-kind level (pass a ``PlacementContext`` or ``(name, size, kind)``
    tuples) owns a ``"stage"`` axis — see :func:`level_axes_for` for the
    naming rule. Device count must equal the product (use the dry-run
    driver's fake devices, or shrink the placements).

    ``devices``: an explicit device subset (flat sequence or array, length
    equal to the stack product incl. model parallelism) to build the mesh
    from instead of the full ``jax.devices()`` pool. This is the elastic
    re-mapping path: after a pod drops out, pass the SURVIVING devices and
    the shrunken stack and the same N-level factorization lands on them —
    the degraded ``(pod, data)`` mesh the chaos soak reshards onto."""
    stack = _normalize_stack(placements)
    if not stack:
        raise ValueError("placements must not be empty")
    shape: Tuple[int, ...] = tuple(s for _, s, _ in stack)
    axes: Tuple[str, ...] = level_axes_for(stack)
    if model_parallel > 1:
        shape = shape + (model_parallel,)
        axes = axes + ("model",)
    if devices is not None:
        import numpy as np

        flat = list(np.asarray(devices, dtype=object).reshape(-1))
        need = 1
        for s in shape:
            need *= s
        if len(flat) != need:
            raise ValueError(
                f"devices subset has {len(flat)} devices but the placement "
                f"stack needs {need} (shape {shape})"
            )
        return compat.make_mesh(shape, axes, devices=flat)
    return compat.make_mesh(shape, axes)
