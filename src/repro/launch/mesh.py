"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches JAX device state — the dry-run driver must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.

All construction goes through ``repro.compat`` so the same meshes build on
any supported JAX version (axis types are applied only where the API has
them; older versions have the equivalent Auto-only semantics).
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (data, model) or 2×16×16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Optional[jax.sharding.Mesh]:
    """Best-effort mesh over whatever devices exist (CPU smoke / degraded pod)."""
    n = jax.device_count()
    if n == 1:
        return None
    data = n // model_parallel
    return make_mesh((data, model_parallel), ("data", "model"))


def partition_axes_for(mesh: Optional[jax.sharding.Mesh]):
    """DrJAX partition axes on this mesh: ("pod", "data") when pods exist."""
    if mesh is None:
        return None
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data")
    if "data" in names:
        return "data"
    return None


def placement_axes_for(
    mesh: Optional[jax.sharding.Mesh],
) -> Optional[Dict[str, str]]:
    """Per-placement mesh axes for a nested {"pods", "clients"} stack.

    Pods pin the slow DCN ``"pod"`` axis, clients the ICI ``"data"`` axis —
    the assignment that makes the two legs of a hierarchical reduction land
    on the interconnects they were designed for. Degrades gracefully: a
    single-pod mesh leaves pods logical (no pod axis to pin)."""
    if mesh is None:
        return None
    names = mesh.axis_names
    axes: Dict[str, str] = {}
    if "pod" in names:
        axes["pods"] = "pod"
    if "data" in names:
        axes["clients"] = "data"
    return axes or None


def mesh_for_placements(
    placements: Mapping[str, int], model_parallel: int = 1
) -> jax.sharding.Mesh:
    """A mesh with one device axis per placement (plus optional "model").

    ``{"pods": P, "clients": m}`` maps to shape ``(P, m[, model])`` with axes
    ``("pod", "data"[, "model"])`` — the outermost placement owns the
    slowest interconnect dimension. A single placement yields the classic
    ``("data"[, "model"])`` mesh. Device count must equal the product (use
    the dry-run driver's fake devices, or shrink the placements)."""
    if not placements:
        raise ValueError("placements must not be empty")
    sizes = tuple(placements.values())
    if len(sizes) == 1:
        shape: Tuple[int, ...] = sizes
        axes: Tuple[str, ...] = ("data",)
    elif len(sizes) == 2:
        shape = sizes
        axes = ("pod", "data")
    else:
        raise ValueError(
            f"at most two placement levels map onto the (pod, data) mesh; "
            f"got {len(sizes)}: {list(placements)}"
        )
    if model_parallel > 1:
        shape = shape + (model_parallel,)
        axes = axes + ("model",)
    return compat.make_mesh(shape, axes)
