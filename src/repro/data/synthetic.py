"""Flat (non-grouped) synthetic LM stream for plain data-parallel training."""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np


def synthetic_lm_batch(step: int, batch: int, seq: int, vocab: int,
                       seed: int = 0) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }


@dataclasses.dataclass
class SyntheticLMStream:
    batch: int
    seq: int
    vocab: int
    seed: int = 0
    step: int = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = synthetic_lm_batch(self.step, self.batch, self.seq, self.vocab,
                               self.seed)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])
