"""Group-partitioned data pipeline (Dataset-Grouper-style)."""

from .grouped import GroupedCorpus, CohortSampler
from .synthetic import synthetic_lm_batch, SyntheticLMStream

__all__ = [
    "GroupedCorpus",
    "CohortSampler",
    "synthetic_lm_batch",
    "SyntheticLMStream",
]
