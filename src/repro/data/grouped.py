"""Group-partitioned corpus: the paper's data model.

The paper trains local SGD on CCNews *partitioned by base URL domain*,
iterated with Dataset Grouper (Charles et al., 2023). The structural
properties that matter to the runtime are reproduced here:

 * the corpus is a keyed collection ``group_id -> stream of examples``;
 * a round samples a *cohort* of ``n`` groups (the DrJAX partition);
 * each group yields ``num_local_steps`` batches of ``(batch, seq)`` tokens;
 * iteration is deterministic in (group_id, round) — restart-safe, which the
   checkpoint manager relies on.

Content is synthetic (offline container): tokens are a cheap stateless hash
of (group, round, position) with group-dependent marginals, so different
groups have measurably different distributions (heterogeneity, like
domain-partitioned news), while remaining reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GroupedCorpus:
    """Deterministic group-keyed synthetic corpus."""

    vocab_size: int
    num_groups: int = 1 << 20  # logical key space (like URL domains)
    seed: int = 0

    def _rng(self, group_id: int, round_idx: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, group_id, round_idx])
        )

    def group_batches(
        self,
        group_id: int,
        round_idx: int,
        num_local_steps: int,
        batch: int,
        seq: int,
    ) -> np.ndarray:
        """(num_local_steps, batch, seq+1) int32 tokens for one group/round."""
        rng = self._rng(group_id, round_idx)
        # group-dependent unigram skew: a cheap stand-in for domain style
        bias = (group_id * 2654435761) % max(self.vocab_size // 4, 1)
        toks = rng.integers(
            0, self.vocab_size, size=(num_local_steps, batch, seq + 1)
        )
        skew = rng.random((num_local_steps, batch, seq + 1)) < 0.15
        toks = np.where(skew, (toks + bias) % self.vocab_size, toks)
        return toks.astype(np.int32)


@dataclasses.dataclass
class CohortSampler:
    """Samples a cohort of group ids per round (with over-provisioning).

    ``oversample`` extra groups support straggler dropping: the reduction
    masks out the slowest ``oversample`` groups without bias (see
    ``repro.runtime.stragglers``).
    """

    corpus: GroupedCorpus
    cohort_size: int
    oversample: int = 0
    seed: int = 17

    def cohort(self, round_idx: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, round_idx])
        )
        n = self.cohort_size + self.oversample
        return rng.choice(self.corpus.num_groups, size=n, replace=False)

    def round_batch(
        self,
        round_idx: int,
        num_local_steps: int,
        batch: int,
        seq: int,
    ) -> dict:
        """Stacked cohort data: tokens (n, steps, batch, seq), labels same."""
        ids = self.cohort(round_idx)
        toks = np.stack(
            [
                self.corpus.group_batches(int(g), round_idx, num_local_steps,
                                          batch, seq)
                for g in ids
            ]
        )  # (n, steps, batch, seq+1)
        return {
            "group_ids": ids,
            "tokens": jnp.asarray(toks[..., :-1]),
            "labels": jnp.asarray(toks[..., 1:]),
        }
