"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles.

Each kernel is swept over shapes and dtypes per the deliverable requirement.
``interpret=True`` executes the kernel bodies (BlockSpec tiling included) on
CPU; on TPU the same kernels lower through Mosaic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,s,hq,hkv,hd,qb,kb",
        [
            (1, 32, 4, 4, 16, 16, 16),   # MHA
            (2, 64, 8, 2, 32, 16, 16),   # GQA 4:1
            (1, 40, 8, 1, 64, 8, 16),    # MQA, ragged seq
            (2, 128, 4, 2, 16, 32, 64),  # kv_block > q_block
        ],
    )
    def test_causal(self, b, s, hq, hkv, hd, qb, kb, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, s, hq, hd)).astype(dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, hd)).astype(dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, hd)).astype(dtype)
        out = ops.flash_attention(
            q, k, v, causal=True, q_block=qb, kv_block=kb, interpret=True
        )
        r = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(r, np.float32), **_tol(dtype)
        )

    @pytest.mark.parametrize("window", [8, 24, 1000])
    def test_local_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 16))
        k = jax.random.normal(ks[1], (2, 64, 2, 16))
        v = jax.random.normal(ks[2], (2, 64, 2, 16))
        out = ops.flash_attention(
            q, k, v, causal=True, window=window, q_block=16, kv_block=16,
            interpret=True,
        )
        r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, r, rtol=2e-5, atol=2e-5)

    def test_non_causal_cross(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 24, 4, 32))
        k = jax.random.normal(ks[1], (1, 56, 2, 32))  # Skv != Sq
        v = jax.random.normal(ks[2], (1, 56, 2, 32))
        out = ops.flash_attention(
            q, k, v, causal=False, q_block=8, kv_block=16, interpret=True
        )
        r = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, r, rtol=2e-5, atol=2e-5)

    def test_matches_xla_blocked_path(self):
        """Kernel and the XLA blocked implementation agree (same algorithm)."""
        from repro.models.attention import blocked_attention

        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 48, 4, 16))
        k = jax.random.normal(ks[1], (2, 48, 2, 16))
        v = jax.random.normal(ks[2], (2, 48, 2, 16))
        a = ops.flash_attention(q, k, v, causal=True, q_block=16, kv_block=16,
                                interpret=True)
        b = blocked_attention(q, k, v, causal=True, q_block=16, kv_block=16)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


class TestLruScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,s,w,chunk,wb",
        [(1, 16, 8, 8, 8), (2, 40, 24, 16, 8), (2, 100, 32, 32, 32)],
    )
    def test_vs_ref(self, b, s, w, chunk, wb, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w))).astype(dtype)
        x = jax.random.normal(ks[1], (b, s, w)).astype(dtype)
        out = ops.lru_scan(a, x, chunk=chunk, width_block=wb, interpret=True)
        r = ref.lru_scan_ref(a, x)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(r, np.float32), **_tol(dtype)
        )

    @given(
        s=st.integers(2, 33),
        w=st.integers(1, 16),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, s, w):
        ks = jax.random.split(jax.random.PRNGKey(s * 131 + w), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, s, w)))
        x = jax.random.normal(ks[1], (1, s, w))
        out = ops.lru_scan(a, x, chunk=8, width_block=8, interpret=True)
        r = ref.lru_scan_ref(a, x)
        np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("s,w", [(2, 1), (13, 5), (33, 16)])
    def test_random_shapes_smoke(self, s, w):
        """Deterministic slice of the shape property (no hypothesis needed):
        ragged sequence lengths and widths that don't divide the blocks."""
        ks = jax.random.split(jax.random.PRNGKey(s * 131 + w), 2)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, s, w)))
        x = jax.random.normal(ks[1], (1, s, w))
        out = ops.lru_scan(a, x, chunk=8, width_block=8, interpret=True)
        r = ref.lru_scan_ref(a, x)
        np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-5)


class TestWkv6:
    @pytest.mark.parametrize(
        "b,s,h,n,chunk", [(1, 16, 1, 8, 8), (2, 48, 2, 8, 16), (1, 50, 3, 16, 16)]
    )
    def test_vs_ref(self, b, s, h, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r_ = jax.random.normal(ks[0], (b, s, h, n))
        k_ = jax.random.normal(ks[1], (b, s, h, n))
        v_ = jax.random.normal(ks[2], (b, s, h, n))
        lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) * 0.5)
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        out = ops.wkv6(r_, k_, v_, lw, u, chunk=chunk, interpret=True)
        oracle = ref.wkv6_ref(r_, k_, v_, lw, u)
        np.testing.assert_allclose(out, oracle, rtol=1e-4, atol=1e-4)

    def test_matches_model_chunked_path(self):
        from repro.models.rwkv import chunked_wkv

        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        b, s, h, n = 2, 32, 2, 8
        r_ = jax.random.normal(ks[0], (b, s, h, n))
        k_ = jax.random.normal(ks[1], (b, s, h, n))
        v_ = jax.random.normal(ks[2], (b, s, h, n))
        lw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) * 0.5)
        u = jax.random.normal(ks[4], (h, n)) * 0.1
        a = ops.wkv6(r_, k_, v_, lw, u, chunk=8, interpret=True)
        bx, _ = chunked_wkv(r_, k_, v_, lw, u, chunk=8)
        np.testing.assert_allclose(a, bx, rtol=1e-4, atol=1e-4)


class TestQuantize:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("r,c,rb", [(32, 64, 16), (100, 128, 32), (7, 256, 8)])
    def test_roundtrip(self, r, c, rb, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(0), (r, c)) * 3).astype(dtype)
        q, s = ops.quantize(x, row_block=rb, interpret=True)
        qr, sr = ref.quantize_ref(x)
        # bf16 rounding can flip ties by one quantization level
        max_q_diff = 0 if dtype == jnp.float32 else 1
        assert (
            np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32)).max()
            <= max_q_diff
        )
        back = ops.dequantize(q, s, interpret=True)
        # int8 quantization error bound: absmax/127 per row (+ bf16 eps slack)
        err = np.abs(np.asarray(back, np.float32) - np.asarray(x, np.float32))
        slack = 0.51 if dtype == jnp.float32 else 1.6
        bound = np.asarray(sr)[:, 0] * slack + 1e-6
        assert (err <= bound[:, None]).all()

    def test_quantization_error_bound_property(self):
        for seed in range(5):
            x = jax.random.normal(jax.random.PRNGKey(seed), (16, 32)) * (seed + 1)
            q, s = ops.quantize(x, row_block=8, interpret=True)
            back = ops.dequantize(q, s, interpret=True)
            scale = np.asarray(s)
            assert np.abs(np.asarray(back - x)).max() <= scale.max() * 0.51
