"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and EXPERIMENTS.md §Dry-run.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry

ASSIGNED = [a for a in registry.ARCH_IDS if not a.startswith("lm_")]


def _batch(cfg, b=2, s=16):
    return registry.make_concrete_batch(cfg, b, s, jax.random.PRNGKey(1))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_loss_finite(arch):
    cfg = registry.get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    loss = registry.loss_fn(cfg, params, _batch(cfg))
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_updates_params_no_nans(arch):
    cfg = registry.get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss_fn = functools.partial(registry.loss_fn, cfg)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        p = jax.tree_util.tree_map(
            lambda w, gw: (w.astype(jnp.float32) - 0.01 * gw.astype(jnp.float32)
                           ).astype(w.dtype), p, g)
        return p, loss

    new_params, loss = step(params)
    assert jnp.isfinite(loss)
    # params changed and stayed finite
    changed = 0
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(new_params)):
        assert jnp.all(jnp.isfinite(b.astype(jnp.float32))), arch
        if not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32)):
            changed += 1
    assert changed > 0, f"{arch}: no parameter changed"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the exact assigned shapes."""
    cfg = registry.get_config(arch)
    expected = {
        "phi35_moe": (32, 4096, 32, 8, 6400, 32064, 16, 2),
        "qwen3_moe": (94, 4096, 64, 4, 1536, 151936, 128, 8),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000, 0, 0),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544, 0, 0),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304, 0, 0),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064, 0, 0),
        "yi_34b": (60, 7168, 56, 8, 20480, 64000, 0, 0),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206, 0, 0),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000, 0, 0),
        "rwkv6_3b": (32, 2560, 40, 0, 8960, 65536, 0, 0),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size, cfg.num_experts, cfg.experts_per_token)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_param_counts_in_expected_range():
    """Sanity: derived parameter counts are near the published sizes."""
    cases = {
        "qwen2_72b": (65e9, 80e9),
        "yi_34b": (30e9, 38e9),
        "internlm2_20b": (17e9, 23e9),
        "stablelm_3b": (2.3e9, 3.6e9),
        "rwkv6_3b": (2.2e9, 3.6e9),
        "recurrentgemma_2b": (2.0e9, 3.6e9),
        "phi35_moe": (38e9, 46e9),
        "qwen3_moe": (200e9, 260e9),
    }
    for arch, (lo, hi) in cases.items():
        n = registry.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    """MoE active params are far below total (a6.6b / a22b naming)."""
    for arch, (lo, hi) in {
        "phi35_moe": (5e9, 9e9),
        "qwen3_moe": (15e9, 26e9),
    }.items():
        cfg = registry.get_config(arch)
        n = cfg.active_param_count()
        assert lo <= n <= hi, f"{arch}: active {n/1e9:.1f}B"
        assert n < cfg.param_count() / 2


@pytest.mark.parametrize("arch", ["stablelm_3b", "phi35_moe", "rwkv6_3b",
                                  "recurrentgemma_2b", "llava_next_34b"])
def test_prefill_decode_consistency(arch):
    """greedy decode after prefill == argmax of the train-mode forward."""
    cfg = registry.get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    if cfg.family == "vlm":
        rng = jax.random.PRNGKey(3)
        embeds = jax.random.normal(rng, (B, 4, cfg.d_model), jnp.float32)
        tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        from repro.models import transformer
        logits_full, _, _ = transformer.forward(
            cfg, params, tokens, embeds=embeds, mode="train"
        )
        last_from_forward = logits_full[:, -1]
        last_from_prefill, _ = transformer.prefill(
            cfg, params, tokens, embeds=embeds
        )
    else:
        tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                    cfg.vocab_size)
        from repro.models import transformer
        logits_full, _, _ = transformer.forward(cfg, params, tokens,
                                                mode="train")
        last_from_forward = logits_full[:, -1]
        last_from_prefill, _ = transformer.prefill(cfg, params, tokens)
    np.testing.assert_allclose(
        np.asarray(last_from_prefill, np.float32),
        np.asarray(last_from_forward, np.float32),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ["stablelm_3b", "rwkv6_3b",
                                  "recurrentgemma_2b"])
def test_incremental_decode_matches_full_forward(arch):
    """Decoding token-by-token reproduces the full-sequence logits."""
    cfg = registry.get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    from repro.models import transformer

    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size)
    logits_full, _, _ = transformer.forward(cfg, params, tokens, mode="train")

    # prefill on the first token only, then decode the rest step by step
    last, caches = transformer.prefill(cfg, params, tokens[:, :1], max_len=S)
    outs = [last]
    for t in range(1, S):
        last, caches = transformer.decode_step(cfg, params, tokens[:, t:t+1],
                                               caches)
        outs.append(last)
    stacked = jnp.stack(outs, axis=1)  # (B, S, V)
    np.testing.assert_allclose(
        np.asarray(stacked, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=5e-2, atol=5e-2,
    )
