"""Tests for first-class nested placements (the placement stack).

Covers the multi-placement API (`program(placements={...})`,
`placement=` addressing on broadcast/reduce/map_fn), placement-correct
MapReduce AD and batching, the placement-lattice plan IR (placement-tagged
REDUCE stages, bitwise run_plan), the hierarchical ≡ flat equivalences, and
the pod-hierarchical round variants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import optim
from repro.algorithms import (
    LocalSGDConfig,
    make_hierarchical_async_round,
    make_hierarchical_local_sgd_round,
    make_local_sgd_round,
)
from repro.core import interpreter as interp
from repro.core import placement as placement_lib


def make_nested_round(P=2, m=4):
    @drjax.program(placements={"pods": P, "clients": m})
    def nested_round(x, data):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a, b: a * b, (y, data))
        partial = drjax.reduce_mean(z, placement="clients")
        return drjax.reduce_mean(partial, placement="pods")

    return nested_round


NESTED_ARGS = (
    jnp.float32(2.0),
    jnp.arange(8, dtype=jnp.float32).reshape(2, 4),
)


class TestNestedAPI:
    def test_forward(self):
        f = make_nested_round()
        x, data = NESTED_ARGS
        np.testing.assert_allclose(f(x, data), 2.0 * data.mean(), rtol=1e-6)

    def test_default_ops_span_the_stack(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(x, data):
            y = drjax.broadcast(x)  # two primitives: server -> pods -> clients
            z = drjax.map_fn(lambda a, b: a * b, (y, data))
            return drjax.reduce_sum(z)  # two primitives: clients -> pods -> server

        x, data = NESTED_ARGS
        np.testing.assert_allclose(f(x, data), 2.0 * data.sum(), rtol=1e-6)
        counts = drjax.count_primitives(jax.make_jaxpr(f)(x, data))
        assert counts["drjax_broadcast"] == 2
        assert counts["drjax_reduce_sum"] == 2

    def test_per_pod_map(self):
        """map_fn addressed at the outer placement sees per-pod slices."""

        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(data):
            pod_stat = drjax.map_fn(
                lambda pod_rows: pod_rows.sum(), data, placement="pods"
            )
            return drjax.reduce_max(pod_stat, placement="pods")

        data = NESTED_ARGS[1]
        np.testing.assert_allclose(f(data), data.sum(axis=1).max())

    def test_broadcast_at_inner_placement(self):
        """broadcast@clients lifts a pod-partitioned value one level."""

        @drjax.program(placements={"pods": 2, "clients": 3})
        def f(pod_vals):
            per_client = drjax.broadcast(pod_vals, placement="clients")
            return drjax.reduce_sum(per_client)

        pod_vals = jnp.array([1.0, 10.0])
        np.testing.assert_allclose(f(pod_vals), 3 * 11.0)

    def test_unknown_placement_raises(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(x):
            return drjax.reduce_sum(x, placement="racks")

        with pytest.raises(KeyError, match="racks"):
            f(jnp.zeros((2, 4)))

    def test_wrong_depth_raises(self):
        """reduce@clients needs the full (pods, clients) prefix."""

        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(pod_vals):
            return drjax.reduce_sum(pod_vals, placement="clients")

        with pytest.raises(ValueError, match="does not match"):
            jax.jit(f)(jnp.zeros((2, 3)))

    def test_prefix_size_mismatch_raises(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(vals):
            return drjax.reduce_sum(vals, placement="clients")

        with pytest.raises(ValueError, match="does not match"):
            jax.jit(f)(jnp.zeros((3, 4)))

    def test_weights_shape_error_is_clear(self):
        """Satellite: weight/leaf mismatches fail with a placement-aware
        message, not deep inside a reshape."""

        @drjax.program(partition_size=3)
        def f(x, w):
            return drjax.reduce_weighted_mean(x, w)

        with pytest.raises(ValueError, match="one weight per group"):
            f(jnp.ones((3, 2)), jnp.ones((4,)))

        @drjax.program(partition_size=3)
        def g(tree, w):
            return drjax.reduce_weighted_mean(tree, w)

        with pytest.raises(ValueError, match="do not match a leaf"):
            g({"ok": jnp.ones((3,)), "bad": jnp.ones((4, 2))}, jnp.ones((3,)))

    def test_nested_weighted_mean(self):
        @drjax.program(placements={"pods": 2, "clients": 2})
        def f(x, w):
            return drjax.reduce_weighted_mean(x, w)

        x = jnp.array([[1.0, 2.0], [3.0, 4.0]])
        w = jnp.array([[1.0, 0.0], [0.0, 1.0]])
        np.testing.assert_allclose(f(x, w), (1.0 + 4.0) / 2.0)

    def test_legacy_context_surface(self):
        """Single-placement programs read the same context surface as before
        the stack refactor (the one-entry degenerate case)."""
        ctx = placement_lib.make_context(5, partition_axes="data")
        assert ctx.partition_size == 5
        assert ctx.placement == "clients"
        assert ctx.axes_tuple() == ("data",)
        assert ctx.depth == 1 and ctx.total_size() == 5

    def test_upstream_single_placement_mapping(self):
        @drjax.program(placements={"workers": 4})
        def f(x):
            return drjax.reduce_sum(drjax.broadcast(x))

        assert f(jnp.float32(2.0)) == 8.0
        assert f.drjax_context.placement == "workers"


class TestNestedAD:
    def test_grad_placement_correct(self):
        f = make_nested_round()
        x, data = NESTED_ARGS
        np.testing.assert_allclose(
            jax.grad(f)(x, data), data.mean(), rtol=1e-6
        )

    def test_grad_stays_in_primitive_set(self):
        f = make_nested_round()
        counts = drjax.count_primitives(
            jax.make_jaxpr(jax.grad(f))(*NESTED_ARGS)
        )
        # transposes: broadcast@p <-> reduce_sum@p at both levels
        assert counts["drjax_reduce_sum"] == 2
        assert counts["drjax_broadcast"] == 4

    def test_jacfwd_jacrev_agree_nested(self):
        f = make_nested_round()
        x, data = NESTED_ARGS
        fwd = jax.jacfwd(f, argnums=1)(x, data)
        rev = jax.jacrev(f, argnums=1)(x, data)
        np.testing.assert_allclose(fwd, rev, rtol=1e-5)

    def test_vmap_over_nested_program(self):
        f = make_nested_round()
        xs = jnp.arange(3, dtype=jnp.float32)
        out = jax.vmap(f, in_axes=(0, None))(xs, NESTED_ARGS[1])
        expect = xs * NESTED_ARGS[1].mean()
        np.testing.assert_allclose(out, expect, rtol=1e-6)

    def test_vmap_of_grad_hyperparameter_sweep(self):
        """Satellite: a batched hyperparameter sweep of a FULL round —
        vmap of grad over client learning rates, each row a complete
        broadcast/local-steps/reduce round."""
        n, steps = 4, 2
        data = jax.random.normal(jax.random.PRNGKey(0), (n, steps, 8))

        @drjax.program(partition_size=n)
        def round_loss(lr, w, batches):
            wb = drjax.broadcast(w)
            lrb = drjax.broadcast(lr)

            def client(w0, lr_c, xs):
                def step(w_c, x):
                    g = jax.grad(lambda w_, x_: jnp.mean((w_ * x_) ** 2))(
                        w_c, x
                    )
                    return w_c - lr_c * g, None

                w1, _ = jax.lax.scan(step, w0, xs)
                return jnp.mean((w1 * xs) ** 2)

            losses = drjax.map_fn(client, (wb, lrb, batches))
            return drjax.reduce_mean(losses)

        lrs = jnp.array([0.01, 0.05, 0.1], jnp.float32)
        w0 = jnp.float32(1.0)
        sweep = jax.vmap(jax.grad(round_loss, argnums=1), in_axes=(0, None, None))(
            lrs, w0, data
        )
        assert sweep.shape == (3,)
        for i, lr in enumerate(lrs):
            one = jax.grad(round_loss, argnums=1)(lr, w0, data)
            np.testing.assert_allclose(sweep[i], one, rtol=1e-5)
        # jit(vmap(grad)) composes too
        jitted = jax.jit(
            jax.vmap(jax.grad(round_loss, argnums=1), in_axes=(0, None, None))
        )(lrs, w0, data)
        np.testing.assert_allclose(jitted, sweep, rtol=1e-6)


class TestHierarchicalEqualsFlat:
    """Satellite: the AD-closure claim of core/hierarchical.py, tested —
    hierarchical_reduce_mean ≡ flat reduce_mean bitwise on CPU (power-of-two
    sizes and integer-valued f32 inputs make every partial sum and division
    exact, so reassociation cannot introduce ULP noise)."""

    def _progs(self):
        @drjax.program(partition_size=8)
        def hier(x, xs):
            z = drjax.map_fn(
                lambda a, b: a * b, (drjax.broadcast(x), xs)
            )
            return drjax.hierarchical_reduce_mean(z, num_supergroups=2)

        @drjax.program(partition_size=8)
        def flat(x, xs):
            z = drjax.map_fn(
                lambda a, b: a * b, (drjax.broadcast(x), xs)
            )
            return drjax.reduce_mean(z)

        return hier, flat

    def test_forward_bitwise(self):
        hier, flat = self._progs()
        x = jnp.float32(3.0)
        xs = jnp.arange(8, dtype=jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(hier(x, xs)), np.asarray(flat(x, xs))
        )

    def test_grad_bitwise(self):
        hier, flat = self._progs()
        x = jnp.float32(3.0)
        xs = jnp.arange(8, dtype=jnp.float32)
        gh = jax.grad(hier)(x, xs)
        gf = jax.grad(flat)(x, xs)
        np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf))
        # grad wrt the partitioned input too
        gh2 = jax.grad(hier, argnums=1)(x, xs)
        gf2 = jax.grad(flat, argnums=1)(x, xs)
        np.testing.assert_array_equal(np.asarray(gh2), np.asarray(gf2))

    def test_grad_under_jit_bitwise(self):
        hier, flat = self._progs()
        x = jnp.float32(3.0)
        xs = jnp.arange(8, dtype=jnp.float32)
        gh = jax.jit(jax.grad(hier))(x, xs)
        gf = jax.jit(jax.grad(flat))(x, xs)
        np.testing.assert_array_equal(np.asarray(gh), np.asarray(gf))


class TestNestedPlanIR:
    def test_hierarchical_two_tagged_reduce_stages(self):
        """Acceptance: build_plan of a hierarchical_reduce_mean program
        yields two placement-tagged REDUCE stages (clients then pods)."""

        @drjax.program(partition_size=8)
        def f(xs):
            return drjax.hierarchical_reduce_mean(xs, num_supergroups=2)

        xs = jnp.arange(8, dtype=jnp.float32)
        plan = drjax.build_plan(jax.make_jaxpr(f)(xs), 8)
        reduces = [s for s in plan.stages if isinstance(s, interp.Reduce)]
        assert [(s.placement, s.dest) for s in reduces] == [
            ("clients", "pods"),
            ("pods", "server"),
        ]
        (out,) = drjax.run_plan(plan, xs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(f(xs)))

    def test_nested_plan_structure_and_bitwise_execution(self):
        f = make_nested_round()
        spec = {"pods": 2, "clients": 4}
        plan = drjax.build_plan(jax.make_jaxpr(f)(*NESTED_ARGS), spec)
        assert plan.placements == (("pods", 2), ("clients", 4))
        assert plan.partitioned_invars == (0, 2)
        assert plan.invar_placements == ((), ("pods", "clients"))
        comm = [
            s
            for s in plan.stages
            if isinstance(s, (interp.Broadcast, interp.Reduce))
        ]
        assert [(s.kind, s.placement) for s in comm] == [
            ("BROADCAST", "pods"),
            ("BROADCAST", "clients"),
            ("REDUCE", "clients"),
            ("REDUCE", "pods"),
        ]
        (out,) = drjax.run_plan(plan, *NESTED_ARGS)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(f(*NESTED_ARGS))
        )

    def test_jit_grad_of_nested_round_stays_in_primitive_set(self):
        """Acceptance: jit(grad(...)) of a nested-placement round stays
        inside the DrJAX primitive set — checked via the plan IR (every
        communication stage is a tagged Broadcast/Reduce and no
        communication hides inside local stages), not string matching."""
        f = make_nested_round()
        spec = {"pods": 2, "clients": 4}
        jxp = jax.make_jaxpr(jax.jit(jax.grad(f)))(*NESTED_ARGS)
        plan = drjax.build_plan(jxp, spec)
        plan.check_locality()  # no comm primitive hides in local compute
        comm = [
            s
            for _, s, _ in plan.named_stages()
            if isinstance(s, (interp.Broadcast, interp.Reduce))
        ]
        assert comm, "grad plan must still communicate via DrJAX stages"
        placements = {s.placement for s in comm}
        assert placements == {"pods", "clients"}
        # the backward pass introduces reduce_sum at both levels
        back = [s for s in comm if isinstance(s, interp.Reduce)]
        assert {s.op for s in back} >= {"reduce_sum"}
        (g,) = drjax.run_plan(plan, *NESTED_ARGS)
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(jax.grad(f)(*NESTED_ARGS))
        )

    def test_jit_plan_equals_unjitted_plan(self):
        f = make_nested_round()
        spec = {"pods": 2, "clients": 4}
        p1 = drjax.build_plan(jax.make_jaxpr(f)(*NESTED_ARGS), spec)
        p2 = drjax.build_plan(
            jax.make_jaxpr(jax.jit(f))(*NESTED_ARGS), spec
        )
        assert [s.kind for s in p1.stages] == [s.kind for s in p2.stages]

    def test_nested_beam_compiles_with_defined_names(self):
        f = make_nested_round()
        spec = {"pods": 2, "clients": 4}
        plan = drjax.build_plan(jax.make_jaxpr(f)(*NESTED_ARGS), spec)
        beam_txt = plan.to_beam()
        compile(beam_txt, "<to_beam>", "exec")
        # the hierarchical reduce stages as two shuffles
        assert "beam.CombinePerKey" in beam_txt
        assert "beam.CombineGlobally" in beam_txt
        fns = plan.stage_fns()
        for name in fns:
            assert f"fns['{name}']" in beam_txt or True  # callables exist
        import re

        for m in re.finditer(r"fns\['([^']+)'\]", beam_txt):
            assert m.group(1) in fns


class TestHierarchicalRounds:
    def _loss(self):
        return lambda p, b: jnp.mean((p["w"] * b["x"] - b["y"]) ** 2)

    def _data(self, P, m, steps):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        return {
            "x": jax.random.normal(k1, (P, m, steps, 8)),
            "y": jax.random.normal(k2, (P, m, steps, 8)) * 0.1 + 1.0,
        }

    def test_hierarchical_round_matches_flat(self):
        P, m, steps = 2, 4, 2
        loss_fn = self._loss()
        server = optim.fedavg_momentum(1.0)
        hier_cfg = LocalSGDConfig(
            partition_size=m, num_local_steps=steps, num_pods=P
        )
        flat_cfg = LocalSGDConfig(partition_size=P * m, num_local_steps=steps)
        hier = make_hierarchical_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, hier_cfg
        )
        flat = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, flat_cfg)
        params = {"w": jnp.float32(0.0)}
        data = self._data(P, m, steps)
        fdata = {k: v.reshape((P * m, steps, 8)) for k, v in data.items()}
        hp, _, hm = hier(params, server.init(params), data)
        fp, _, fm = flat(params, server.init(params), fdata)
        np.testing.assert_allclose(
            float(hp["w"]), float(fp["w"]), rtol=1e-6
        )
        np.testing.assert_allclose(
            float(hm["loss"]), float(fm["loss"]), rtol=1e-6
        )

    def test_hierarchical_round_trains_under_jit(self):
        P, m, steps = 2, 2, 2
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(
            partition_size=m, num_local_steps=steps, num_pods=P
        )
        round_fn = jax.jit(
            make_hierarchical_local_sgd_round(
                self._loss(), optim.sgd(0.05), server, cfg
            )
        )
        params = {"w": jnp.float32(0.0)}
        sstate = server.init(params)
        data = self._data(P, m, steps)
        losses = []
        for _ in range(5):
            params, sstate, metrics = round_fn(params, sstate, data)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]

    def test_hierarchical_async_round_trains(self):
        P, m, steps = 2, 2, 1
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(
            partition_size=m, num_local_steps=steps, num_pods=P
        )
        round_fn, init_pending = make_hierarchical_async_round(
            self._loss(), optim.sgd(0.05), server, cfg
        )
        params = {"w": jnp.float32(0.0)}
        pending = init_pending(params)
        sstate = server.init(params)
        data = self._data(P, m, steps)
        losses = []
        for _ in range(6):
            params, pending, sstate, metrics = round_fn(
                params, pending, sstate, data
            )
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]
        assert np.isfinite(float(params["w"]))

    def test_round_plan_has_both_reduce_levels(self):
        """The §5 plan of the pod-hierarchical round stages the aggregation
        as placement-tagged REDUCEs at both levels."""
        P, m, steps = 2, 2, 1
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(
            partition_size=m, num_local_steps=steps, num_pods=P
        )
        round_fn = make_hierarchical_local_sgd_round(
            self._loss(), optim.sgd(0.05), server, cfg
        )
        params = {"w": jnp.float32(0.0)}
        sstate = server.init(params)
        data = self._data(P, m, steps)
        jxp = jax.make_jaxpr(round_fn)(params, sstate, data)
        plan = drjax.build_plan(jxp, {"pods": P, "clients": m})
        reduces = [
            s
            for _, s, _ in plan.named_stages()
            if isinstance(s, interp.Reduce)
        ]
        assert {s.placement for s in reduces} == {"pods", "clients"}
        flat_args = jax.tree_util.tree_leaves((params, sstate, data))
        outs = drjax.run_plan(plan, *flat_args)
        direct = jax.tree_util.tree_leaves(round_fn(params, sstate, data))
        for a, b in zip(outs, direct):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_num_pods_required(self):
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=1)
        with pytest.raises(ValueError, match="num_pods"):
            make_hierarchical_local_sgd_round(
                self._loss(), optim.sgd(0.1), optim.fedavg_momentum(1.0), cfg
            )


class TestNestedHierarchicalHelper:
    def test_nested_context_infers_supergroups(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(xs):
            return drjax.hierarchical_reduce_mean(xs)

        xs = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        np.testing.assert_allclose(f(xs), xs.mean(), rtol=1e-6)

    def test_nested_context_rejects_contradictory_supergroups(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(xs):
            return drjax.hierarchical_reduce_mean(xs, num_supergroups=3)

        with pytest.raises(ValueError, match="contradicts"):
            f(jnp.zeros((2, 4)))

    def test_flat_context_requires_supergroups(self):
        @drjax.program(partition_size=4)
        def f(xs):
            return drjax.hierarchical_reduce_mean(xs)

        with pytest.raises(ValueError, match="required"):
            f(jnp.zeros((4,)))


class TestLatticeGuards:
    """build_plan rejects comm primitives that would leave the prefix
    lattice instead of emitting a wrong pipeline."""

    def test_reduce_outer_level_of_deeper_value_raises(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(z):
            # wrong order: pods must be reduced AFTER clients
            return drjax.reduce_mean(z, placement="pods")

        z = jnp.ones((2, 4, 3))
        jxp = jax.make_jaxpr(f)(z)
        with pytest.raises(ValueError, match="outer level"):
            drjax.build_plan(jxp, {"pods": 2, "clients": 4})

    def test_broadcast_existing_level_raises(self):
        @drjax.program(placements={"pods": 2, "clients": 2})
        def f(z):
            # z is already pod-partitioned; re-broadcasting pods duplicates
            # the level (shape happens to typecheck because sizes coincide)
            return drjax.broadcast(z, placement="pods")

        z = jnp.ones((2, 2))
        jxp = jax.make_jaxpr(f)(z)
        with pytest.raises(ValueError, match="already"):
            drjax.build_plan(jxp, {"pods": 2, "clients": 2})

    def test_correct_order_still_plans(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def f(z):
            part = drjax.reduce_mean(z, placement="clients")
            return drjax.reduce_mean(part, placement="pods")

        z = jnp.ones((2, 4, 3))
        plan = drjax.build_plan(
            jax.make_jaxpr(f)(z), {"pods": 2, "clients": 4}
        )
        assert len(plan.communication_stages()) == 2


class TestHierarchicalCompression:
    def test_masked_hierarchical_round_keeps_client_compression(self):
        """Regression: the straggler path must not silently drop
        cfg.compression — it compresses per client (like the flat round)."""

        def loss_fn(p, b):
            return jnp.mean((p["w"] * b["x"] - b["y"]) ** 2)

        P, m, steps = 2, 2, 1
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(
            partition_size=m, num_local_steps=steps, num_pods=P,
            compression="int8", straggler_mask=True,
        )
        round_fn = make_hierarchical_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        params = {"w": jnp.float32(0.0)}
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        data = {
            "x": jax.random.normal(k1, (P, m, steps, 8)),
            "y": jax.random.normal(k2, (P, m, steps, 8)) * 0.1 + 1.0,
        }
        mask = jnp.ones((P, m), jnp.float32)
        new_params, _, metrics = round_fn(
            params, server.init(params), data, mask
        )
        assert np.isfinite(float(new_params["w"]))
        assert np.isfinite(float(metrics["loss"]))
        # an all-dropped cohort leaves params untouched, compressed or not
        zp, _, _ = round_fn(
            params, server.init(params), data, jnp.zeros((P, m), jnp.float32)
        )
        np.testing.assert_allclose(float(zp["w"]), 0.0)
