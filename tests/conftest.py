"""Shared fixtures for the test suite.

``device_pool`` replaces the old per-test ``subprocess.run(python -c ...)``
harness used by test_launch / test_sharding / test_tpcomm. Multi-device
tests need ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before
JAX's first init while the rest of the suite must keep the default single
host device, so multi-device work runs in a separate process — but one
persistent worker per session (tests/_device_worker.py), not one cold
interpreter per test: each test ships its script over a JSON-line pipe and
gets the parsed result back, sharing the worker's jax import and compilation
cache. The worker device count comes from ``REPRO_HOST_DEVICES`` (default 8,
see scripts/run_tests.sh).
"""

from __future__ import annotations

import json
import os
import select
import subprocess
import sys
import textwrap
import threading
import time

import pytest

_TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
_REPO = os.path.dirname(_TESTS_DIR)
_WORKER = os.path.join(_TESTS_DIR, "_device_worker.py")

DEFAULT_TIMEOUT_S = 900


class DevicePoolError(AssertionError):
    """A script failed inside the device-pool worker."""


class DevicePool:
    """Client for the persistent multi-device worker process."""

    def __init__(self, num_devices: int = 8):
        self.num_devices = num_devices
        self.proc = None
        self._stderr_lines: list = []
        self._spawn()

    def _spawn(self) -> None:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(_REPO, "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={self.num_devices}"
        )
        self.proc = subprocess.Popen(
            [sys.executable, "-u", _WORKER],
            stdin=subprocess.PIPE,
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            env=env,
        )
        self._stderr_lines = []
        t = threading.Thread(
            target=self._drain_stderr, args=(self.proc,), daemon=True
        )
        t.start()

    def _drain_stderr(self, proc) -> None:
        for raw in proc.stderr:
            self._stderr_lines.append(raw.decode("utf-8", "replace"))
            del self._stderr_lines[:-500]

    def stderr_tail(self, n: int = 60) -> str:
        return "".join(self._stderr_lines[-n:])

    def _read_line(self, timeout: float) -> bytes:
        """Read one protocol line from the worker with a deadline."""
        fd = self.proc.stdout.fileno()
        deadline = time.monotonic() + timeout
        chunks = []
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                self.close()
                raise DevicePoolError(
                    f"device-pool script timed out after {timeout}s; worker "
                    f"stderr tail:\n{self.stderr_tail()}"
                )
            ready, _, _ = select.select([fd], [], [], min(remaining, 1.0))
            if not ready:
                if self.proc.poll() is not None:
                    raise DevicePoolError(
                        "device-pool worker died "
                        f"(rc={self.proc.returncode}); stderr tail:\n"
                        f"{self.stderr_tail()}"
                    )
                continue
            chunk = os.read(fd, 1 << 20)
            if not chunk:
                raise DevicePoolError(
                    "device-pool worker closed stdout; stderr tail:\n"
                    f"{self.stderr_tail()}"
                )
            chunks.append(chunk)
            if b"\n" in chunk:
                return b"".join(chunks)

    def run(self, body: str, timeout: float = DEFAULT_TIMEOUT_S) -> dict:
        """Exec dedented `body` in the worker; parse its last printed line
        as JSON (the same contract the old subprocess harness had).

        If a previous script killed the worker (timeout, crash), a fresh
        one is spawned first so one bad test can't cascade into failures
        for every later multi-device test — the old per-test subprocess
        harness had that isolation, and we keep it."""
        if self.proc is None or self.proc.poll() is not None:
            self._spawn()
        payload = json.dumps({"src": textwrap.dedent(body)})
        self.proc.stdin.write(payload.encode() + b"\n")
        self.proc.stdin.flush()
        resp = json.loads(self._read_line(timeout).decode())
        if not resp["ok"]:
            raise DevicePoolError(
                "device-pool script failed:\n"
                f"{resp['error']}\ncaptured stdout:\n{resp['stdout'][-3000:]}"
            )
        out = resp["stdout"].strip()
        if not out:
            raise DevicePoolError("device-pool script printed no result line")
        return json.loads(out.splitlines()[-1])

    def close(self) -> None:
        if self.proc is not None and self.proc.poll() is None:
            try:
                self.proc.stdin.close()
                self.proc.wait(timeout=10)
            except Exception:  # noqa: BLE001
                self.proc.kill()
                self.proc.wait(timeout=10)


@pytest.fixture(scope="session")
def device_pool():
    n = int(os.environ.get("REPRO_HOST_DEVICES", "8"))
    if n not in (4, 8):
        raise pytest.UsageError(
            f"REPRO_HOST_DEVICES={n} unsupported: the multi-device tests "
            "derive their mesh shapes and logical-partition divisibility "
            "from the device count and require it to be 4 or 8"
        )
    pool = DevicePool(num_devices=n)
    yield pool
    pool.close()
