"""Chaos soak tests: schedule determinism, composed-fault invariants, the
masked elastic round's unbiasedness, and serve fault recovery."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.chaos import ChaosConfig, ChaosSchedule, run_chaos_soak
from repro.runtime.failure import SimulatedDeviceFailure


def _smoke_cfg(**kw) -> ChaosConfig:
    """The CI soak shape (seed 1: see benchmarks/chaos.py — the tail-ratio
    invariant needs the masked/sync distributions separable at 20 rounds)."""
    base = dict(
        rounds=20,
        seed=1,
        num_device_failures=1,
        num_elastic_events=1,
        num_ckpt_faults=1,
        checkpoint_every=4,
        audit_every=8,
        serve_traffic=False,
    )
    base.update(kw)
    return ChaosConfig(**base)


class TestSchedule:
    def test_deterministic_rebuild(self):
        a = ChaosSchedule.from_config(_smoke_cfg())
        b = ChaosSchedule.from_config(_smoke_cfg())
        assert a.pod_counts == b.pod_counts
        assert a.failure_rounds == b.failure_rounds
        assert a.ckpt_faults == b.ckpt_faults
        assert a.elastic_events == b.elastic_events
        for r in range(5):
            xa, ya = a.data_for_round(r, a.pod_counts[r])
            xb, yb = b.data_for_round(r, b.pod_counts[r])
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
            np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
            ma, ta, sa = a.round_mask_and_times(r, a.pod_counts[r])
            mb, tb, sb = b.round_mask_and_times(r, b.pod_counts[r])
            np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
            assert (ta, sa) == (tb, sb)

    def test_streams_independent(self):
        """Changing one stream's config leaves the others' draws alone —
        the SeedSequence([seed, stream_id, ...]) derivation rule."""
        a = ChaosSchedule.from_config(_smoke_cfg())
        b = ChaosSchedule.from_config(_smoke_cfg(num_elastic_events=3))
        assert a.failure_rounds == b.failure_rounds
        # data depends on the pod count; compare a round where they agree
        r = 0
        assert a.pod_counts[r] == b.pod_counts[r]
        xa, _ = a.data_for_round(r, a.pod_counts[r])
        xb, _ = b.data_for_round(r, b.pod_counts[r])
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))

    def test_pod_counts_bounded_and_events_match(self):
        cfg = ChaosConfig(rounds=48, num_elastic_events=6, serve_traffic=False)
        s = ChaosSchedule.from_config(cfg)
        assert all(1 <= p <= cfg.num_pods for p in s.pod_counts)
        assert s.pod_counts[0] == cfg.num_pods
        # the event list is exactly the set of transitions
        transitions = [
            (r, s.pod_counts[r - 1], s.pod_counts[r])
            for r in range(1, cfg.rounds)
            if s.pod_counts[r] != s.pod_counts[r - 1]
        ]
        assert transitions == list(s.elastic_events)
        assert len(transitions) >= 2

    def test_ckpt_faults_target_restore_points(self):
        s = ChaosSchedule.from_config(_smoke_cfg())
        assert s.ckpt_faults  # seed 1 schedules one
        for step, kind in s.ckpt_faults.items():
            assert step % 4 == 0 and step >= 4
            assert kind in ("torn", "corrupt") or kind.startswith("kill@")
            # the fault breaks the checkpoint some failure wants to restore
            assert any((r // 4) * 4 == step for r in s.failure_rounds)
        # the cycle leads with a mid-write kill: the first fault of every
        # schedule exercises the crash-consistency path
        first = s.ckpt_faults[min(s.ckpt_faults)]
        assert first.startswith("kill@")

    def test_alive_pods_track_pod_counts(self):
        s = ChaosSchedule.from_config(
            ChaosConfig(rounds=48, num_elastic_events=6, serve_traffic=False)
        )
        assert len(s.alive_pods) == 48
        for r, alive in enumerate(s.alive_pods):
            assert len(alive) == s.pod_counts[r]
            assert alive == tuple(sorted(alive))
            assert all(0 <= a < 4 for a in alive)
        # deterministic rebuild picks the same victims
        s2 = ChaosSchedule.from_config(
            ChaosConfig(rounds=48, num_elastic_events=6, serve_traffic=False)
        )
        assert s.alive_pods == s2.alive_pods

    def test_config_validation(self):
        with pytest.raises(ValueError, match="rounds"):
            ChaosSchedule.from_config(ChaosConfig(rounds=4))
        with pytest.raises(ValueError, match="max_restarts"):
            ChaosSchedule.from_config(
                ChaosConfig(num_device_failures=8, max_restarts=8)
            )
        with pytest.raises(ValueError, match="clients_per_pod"):
            ChaosSchedule.from_config(ChaosConfig(dim=2, clients_per_pod=2))


@pytest.fixture(scope="module")
def smoke_report():
    return run_chaos_soak(_smoke_cfg())


class TestSoakSmoke:
    def test_recovered_from_all_faults(self, smoke_report):
        rep = smoke_report
        assert rep.device_failures == 1
        assert rep.restarts >= 1
        assert rep.completed_steps == rep.rounds
        assert rep.ckpt_faults_injected
        assert rep.fallback_restores >= 1

    def test_bitwise_identical_to_oracle(self, smoke_report):
        assert smoke_report.oracle_bitwise_equal

    def test_zero_retraces_across_chaos(self, smoke_report):
        assert smoke_report.client_retraces == 0
        assert smoke_report.oracle_extra_traces == 0
        # cross-pod leg: one executable per distinct pod count, nothing more
        assert smoke_report.cross_compiles == len(smoke_report.pods_seen)

    def test_masked_tail_beats_synchronous(self, smoke_report):
        st = smoke_report.straggler
        assert st["p99_masked_s"] < st["p99_sync_s"]
        assert st["tail_ratio_masked"] < st["tail_ratio_sync"]
        assert st["speedup"] > 1.0

    def test_masked_mean_unbiased_on_audit_rounds(self, smoke_report):
        assert smoke_report.audit["rounds"]
        assert smoke_report.audit["max_rel_err"] < 1e-4

    def test_training_made_progress(self, smoke_report):
        assert smoke_report.loss_final < smoke_report.loss_first

    def test_report_serializes(self, smoke_report):
        d = smoke_report.to_json()
        assert json.loads(json.dumps(d)) == d
        assert set(d) == {f.name for f in dataclasses.fields(smoke_report)}


class TestFullSoak:
    @pytest.mark.slow
    def test_full_composed_soak(self):
        """The acceptance soak: >= 2 device failures, >= 2 elastic events,
        straggler deadlines every round, checkpoint faults and concurrent
        serve traffic with a scheduler fault — every production invariant
        asserted inside run_chaos_soak, re-checked here explicitly."""
        rep = run_chaos_soak(ChaosConfig())
        assert rep.device_failures >= 2
        assert len(rep.elastic_events) >= 2
        assert rep.oracle_bitwise_equal
        assert rep.client_retraces == 0
        assert rep.oracle_extra_traces == 0
        assert rep.fallback_restores >= 2
        assert rep.straggler["tail_ratio_masked"] < rep.straggler["tail_ratio_sync"]
        assert rep.serve is not None
        assert rep.serve["flat_traces"]
        assert rep.serve["completed"] == rep.serve["requests"]
        assert rep.serve["faults_injected"] == 1
        assert rep.serve["recoveries"] >= 1


class TestPhysicalMesh:
    @pytest.mark.slow
    def test_soak_reshards_real_mesh(self, device_pool):
        """Acceptance soak on the device-pool worker: pod dropout rebuilds a
        degraded (pod, data) mesh from surviving devices. >= 1 real dropout
        reshard and >= 1 regrowth, final state bitwise-equal to the
        uninterrupted oracle, zero per-client-leg retraces, exactly one
        cross-pod executable per distinct mesh."""
        out = device_pool.run(
            f"""
            import json
            import jax
            from repro.runtime.chaos import ChaosConfig, run_chaos_soak

            cfg = ChaosConfig(
                rounds=20, seed=1,
                num_pods=jax.device_count() // 2, clients_per_pod=2,
                num_device_failures=1, num_elastic_events=2,
                num_ckpt_faults=1, checkpoint_every=4, audit_every=8,
                serve_traffic=False, physical_mesh=True,
            )
            rep = run_chaos_soak(cfg, check=False)
            drops = sum(1 for (_, o, n) in rep.elastic_events if n < o)
            grows = sum(1 for (_, o, n) in rep.elastic_events if n > o)
            print(json.dumps({{
                "bitwise": rep.oracle_bitwise_equal,
                "client_retraces": rep.client_retraces,
                "oracle_extra": rep.oracle_extra_traces,
                "reshards": rep.reshards,
                "meshes_seen": rep.meshes_seen,
                "cross_compiles": rep.cross_compiles,
                "migrate_ms": rep.mesh_migrate_ms,
                "drops": drops, "grows": grows,
                "kills": rep.mid_write_kills_injected,
                "kills_survived": rep.mid_write_kills_survived,
                "audit_err": rep.audit["max_rel_err"],
            }}))
            """
        )
        assert out["bitwise"], "physical soak diverged from same-mesh oracle"
        assert out["client_retraces"] == 0
        assert out["oracle_extra"] == 0
        assert out["drops"] >= 1 and out["grows"] >= 1
        assert out["reshards"] >= out["drops"] + out["grows"]
        assert out["cross_compiles"] == out["meshes_seen"] >= 2
        assert out["migrate_ms"] > 0
        assert out["kills"] >= 1
        assert out["kills_survived"] == out["kills"]
        assert out["audit_err"] < 1e-4


class TestTimeBudget:
    def test_scale_config_to_minutes_pure(self):
        from repro.runtime.chaos import scale_config_to_minutes

        cfg = ChaosConfig(rounds=48, num_device_failures=2,
                          num_elastic_events=4, num_ckpt_faults=2,
                          minutes=2.0)
        # 0.5 s/round, 2 min budget -> 240 rounds, faults scale 5x
        scaled = scale_config_to_minutes(cfg, 0.5)
        assert scaled.rounds == 240
        assert scaled.num_device_failures == 10
        assert scaled.num_elastic_events == 20
        assert scaled.num_ckpt_faults == 10
        assert scaled.max_restarts > scaled.num_device_failures
        assert scaled.minutes is None  # scaling never re-triggers
        # tiny budget floors at the minimum soak length, faults floor at 1
        tiny = scale_config_to_minutes(
            dataclasses.replace(cfg, minutes=0.001), 10.0
        )
        assert tiny.rounds == 8
        assert tiny.num_device_failures >= 1
        assert tiny.num_ckpt_faults >= 1
        # no budget -> untouched
        assert scale_config_to_minutes(
            dataclasses.replace(cfg, minutes=None), 0.5
        ) == dataclasses.replace(cfg, minutes=None)
        scaled.validate()

    def test_minutes_budget_drives_soak_length(self, monkeypatch):
        import repro.runtime.chaos as chaos_mod

        # fake calibration: 0.1 s/round, 0.02 min = 1.2 s -> 12 rounds
        monkeypatch.setattr(chaos_mod, "_calibrate_round_s", lambda fn: 0.1)
        rep = run_chaos_soak(_smoke_cfg(minutes=0.02), check=False)
        assert rep.rounds == 12
        assert rep.minutes_budget == 0.02
        assert rep.completed_steps == 12

    def test_calibration_runs_probe_round(self):
        from repro.runtime.chaos import _calibrate_round_s

        calls = {"n": 0}

        def probe():
            calls["n"] += 1

        s = _calibrate_round_s(probe)
        assert calls["n"] == 3  # warmup + 2 timed
        assert s > 0


class TestMaskedElasticRound:
    def _build(self):
        from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
        from repro.optim.optimizers import sgd
        from repro.optim.server import fedavg_momentum
        from repro.runtime.elastic import make_elastic_hierarchical_round

        def loss(params, batch):
            x, y = batch
            pred = jnp.einsum("bd,d->b", x, params["w"]) + params["b"]
            return jnp.mean((pred - y) ** 2)

        client_opt, server_opt = sgd(0.05), fedavg_momentum(1.0, momentum=0.9)
        elastic = make_elastic_hierarchical_round(
            loss, client_opt, server_opt,
            LocalSGDConfig(partition_size=2, num_local_steps=2,
                           straggler_mask=True),
            straggler_mask=True,
        )
        flat = make_local_sgd_round(
            loss, client_opt, server_opt,
            LocalSGDConfig(partition_size=6, num_local_steps=2,
                           straggler_mask=True),
        )
        params = {"w": jnp.asarray(np.float32([0.1, -0.2, 0.3])),
                  "b": jnp.zeros((), jnp.float32)}
        sstate = server_opt.init(params)
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal((3, 2, 2, 4, 3)).astype(np.float32))
        y = jnp.asarray(rng.standard_normal((3, 2, 2, 4)).astype(np.float32))
        return elastic, flat, params, sstate, x, y

    def test_matches_flat_masked_reference_with_dropped_pod(self):
        elastic, flat, params, sstate, x, y = self._build()
        # pod 1 fully dropped; pod 2 partially
        mask = jnp.asarray([[1, 1], [0, 0], [1, 0]], jnp.float32)
        pe, _, me = elastic.step(params, sstate, {"data": (x, y), "mask": mask})
        pf, _, _ = flat(
            params, sstate,
            (x.reshape(6, 2, 4, 3), y.reshape(6, 2, 4)),
            mask.reshape(6),
        )
        for a, b in zip(jax.tree_util.tree_leaves(pe),
                        jax.tree_util.tree_leaves(pf)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-6, atol=1e-7
            )
        assert float(me["finishers"]) == 3.0

    def test_all_dropped_cohort_is_a_no_op(self):
        elastic, _, params, sstate, x, y = self._build()
        mask = jnp.zeros((3, 2), jnp.float32)
        pe, _, me = elastic.step(params, sstate, {"data": (x, y), "mask": mask})
        for a, b in zip(jax.tree_util.tree_leaves(pe),
                        jax.tree_util.tree_leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(me["finishers"]) == 0.0


class TestServeFaultRecovery:
    def test_reset_slots_recovers_without_retrace(self):
        from repro.launch.serve import ContinuousBatchingScheduler, Request
        from repro.models import registry

        cfg = registry.get_config("stablelm_3b").reduced()
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        chunk = 8
        arm = {"at": 0}

        def hook(idx):
            if arm["at"] and idx >= arm["at"]:
                arm["at"] = 0
                raise SimulatedDeviceFailure("injected serve fault")

        sched = ContinuousBatchingScheduler(
            cfg, params, slots=2, max_len=2 * chunk - 1 + 4,
            chunk=chunk, fault_hook=hook,
        )
        rng = np.random.default_rng(0)

        def req(i, n, max_new):
            return Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32),
                max_new=max_new,
            )

        sched.run([req(0, 2 * chunk - 1, 2)])  # bucket-covering warmup
        traces = (sched.prefill_traces, sched.decode_traces)

        reqs = [req(i, 5 + i, 3) for i in range(3)]
        arm["at"] = sched.step_index + 2
        with pytest.raises(SimulatedDeviceFailure):
            sched.run(reqs)
        sched.reset_slots()
        retry = [
            Request(rid=q.rid, prompt=q.prompt, max_new=q.max_new)
            for q in reqs
            if not q.done
        ]
        out = sched.run(retry)
        done = {q.rid for q in reqs if q.done} | set(out)
        assert done == {0, 1, 2}
        assert all(len(v) == 3 for v in out.values())
        # recovery reuses the warmed executables: trace counts flat
        assert (sched.prefill_traces, sched.decode_traces) == traces
