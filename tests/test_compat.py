"""Compat-layer tests.

Two halves: (a) the layer works against the *installed* JAX (whatever
version the environment has), and (b) a monkeypatched new-API present /
absent matrix pins the branch each probe selects, so a JAX upgrade or
downgrade can't silently flip behavior without a test noticing.
"""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.compat import probes as probes_lib


@pytest.fixture(autouse=True)
def _fresh_probe_cache():
    """Probe verdicts are cached; tests that monkeypatch jax must re-probe."""
    compat.reset_cache()
    yield
    compat.reset_cache()


class TestOnInstalledJax:
    def test_capabilities_are_booleans(self):
        caps = compat.capabilities()
        assert caps, "no probes registered"
        assert all(isinstance(v, bool) for v in caps.values())

    def test_unknown_feature_raises(self):
        with pytest.raises(KeyError, match="unknown compat feature"):
            compat.has("warp_drive")

    def test_jax_version_tuple(self):
        v = compat.jax_version()
        assert isinstance(v, tuple) and len(v) >= 2
        assert all(isinstance(p, int) for p in v)

    def test_make_mesh_single_device(self):
        mesh = compat.make_mesh((1,), ("data",))
        assert mesh.axis_names == ("data",)
        assert mesh.devices.shape == (1,)

    def test_make_mesh_axis_type_request_is_portable(self):
        # "auto" must build everywhere: applied where AxisType exists,
        # dropped (with identical semantics) where it doesn't.
        mesh = compat.make_mesh((1,), ("data",), axis_types="auto")
        assert mesh.shape["data"] == 1

    def test_axis_type_rejects_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown axis type"):
            compat.axis_type("automatic")

    def test_set_mesh_usable_as_ambient_context(self):
        mesh = compat.make_mesh((1,), ("data",))
        with compat.set_mesh(mesh) as active:
            assert active is mesh
            out = jax.jit(lambda x: x * 2)(jnp.ones((4,)))
        np.testing.assert_allclose(out, 2.0 * np.ones(4))

    def test_set_mesh_none_is_noop(self):
        with compat.set_mesh(None) as active:
            assert active is None

    def test_cost_analysis_normalized_to_dict(self):
        compiled = jax.jit(lambda x: x @ x).lower(jnp.ones((8, 8))).compile()
        cost = compat.cost_analysis(compiled)
        assert isinstance(cost, dict)
        assert cost.get("flops", 0) > 0
        assert compat.cost_flops(compiled) == pytest.approx(cost["flops"])
        # None (no cost model) is a legal answer, distinct from a real 0.0
        bytes_acc = compat.cost_bytes_accessed(compiled)
        assert bytes_acc is None or bytes_acc >= 0.0

    def test_cost_bytes_accessed_none_when_no_cost_model(self):
        class _NoCosts:
            def cost_analysis(self):
                raise NotImplementedError("backend reports no costs")

        assert compat.cost_bytes_accessed(_NoCosts()) is None

    def test_named_sharding_accepts_spec_or_axes(self):
        mesh = compat.make_mesh((1,), ("data",))
        from jax.sharding import PartitionSpec as P

        a = compat.named_sharding(mesh, P("data", None))
        b = compat.named_sharding(mesh, ("data", None))
        assert a.spec == b.spec == P("data", None)
        assert compat.replicated_sharding(mesh).spec == P()
        assert compat.named_sharding(mesh).spec == P()

    def test_shard_map_runs_on_installed_jax(self):
        from jax.sharding import PartitionSpec as P

        mesh = compat.make_mesh((1,), ("data",))
        fn = compat.shard_map(
            lambda x: x * 2,
            mesh=mesh,
            in_specs=(P(),),
            out_specs=P(),
            check=False,
        )
        np.testing.assert_allclose(fn(jnp.ones((4,))), 2.0 * np.ones(4))


class TestCostNormalization:
    class _Compiled:
        def __init__(self, raw):
            self._raw = raw

        def cost_analysis(self):
            if isinstance(self._raw, Exception):
                raise self._raw
            return self._raw

    def test_dict_passthrough(self):
        assert compat.normalize_cost_analysis({"flops": 3.0}) == {"flops": 3.0}

    def test_single_element_list(self):
        assert compat.cost_analysis(
            self._Compiled([{"flops": 5.0, "bytes accessed": 7.0}])
        ) == {"flops": 5.0, "bytes accessed": 7.0}

    def test_multi_module_list_sums_numeric(self):
        cost = compat.normalize_cost_analysis(
            [{"flops": 1.0, "note": "a"}, {"flops": 2.0, "bytes accessed": 4.0}]
        )
        assert cost["flops"] == 3.0
        assert cost["bytes accessed"] == 4.0
        assert cost["note"] == "a"

    def test_empty_and_none(self):
        assert compat.normalize_cost_analysis([]) == {}
        assert compat.normalize_cost_analysis(None) == {}
        assert compat.normalize_cost_analysis("garbage") == {}

    def test_raising_backend_yields_empty(self):
        assert compat.cost_analysis(
            self._Compiled(NotImplementedError("no costs on this backend"))
        ) == {}


class _FakeAxisType:
    Auto = "AUTO"
    Explicit = "EXPLICIT"
    Manual = "MANUAL"


class TestProbeMatrix:
    """Simulate newer/older JAX API surfaces by monkeypatching ``jax``."""

    def test_new_api_axis_types_forwarded(self, monkeypatch):
        recorded = {}

        def fake_make_mesh(shape, axes, *, devices=None, axis_types=None):
            recorded.update(shape=shape, axes=axes, axis_types=axis_types)
            return "NEW-MESH"

        monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
        monkeypatch.setattr(
            jax.sharding, "AxisType", _FakeAxisType, raising=False
        )
        compat.reset_cache()
        assert compat.has("mesh_axis_types")
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        assert mesh == "NEW-MESH"
        assert recorded["axis_types"] == ("AUTO", "AUTO")
        assert compat.axis_type("explicit") == "EXPLICIT"

    def test_old_api_axis_types_dropped(self, monkeypatch):
        recorded = {}

        def fake_make_mesh(shape, axes, *, devices=None, **kw):
            recorded.update(kw)
            return "OLD-MESH"

        monkeypatch.setattr(jax, "make_mesh", fake_make_mesh)
        monkeypatch.delattr(jax.sharding, "AxisType", raising=False)
        compat.reset_cache()
        assert not compat.has("axis_type_enum")
        assert not compat.has("mesh_axis_types")
        assert compat.make_mesh((8,), ("data",)) == "OLD-MESH"
        assert "axis_types" not in recorded
        assert compat.axis_type("auto") is None

    def test_no_make_mesh_falls_back_to_mesh_utils(self, monkeypatch):
        monkeypatch.delattr(jax, "make_mesh", raising=False)
        compat.reset_cache()
        assert not compat.has("make_mesh")
        mesh = compat.make_mesh((1,), ("data",))
        assert isinstance(mesh, jax.sharding.Mesh)
        assert mesh.axis_names == ("data",)

    def test_set_mesh_prefers_jax_set_mesh(self, monkeypatch):
        seen = []

        @contextlib.contextmanager
        def fake_set_mesh(mesh):
            seen.append(mesh)
            yield

        monkeypatch.setattr(jax, "set_mesh", fake_set_mesh, raising=False)
        compat.reset_cache()
        assert compat.has("set_mesh")
        with compat.set_mesh("the-mesh"):
            pass
        assert seen == ["the-mesh"]

    def test_set_mesh_use_mesh_fallback(self, monkeypatch):
        seen = []

        @contextlib.contextmanager
        def fake_use_mesh(mesh):
            seen.append(mesh)
            yield

        monkeypatch.delattr(jax, "set_mesh", raising=False)
        monkeypatch.setattr(
            jax.sharding, "use_mesh", fake_use_mesh, raising=False
        )
        compat.reset_cache()
        assert not compat.has("set_mesh")
        assert compat.has("use_mesh")
        with compat.set_mesh("the-mesh"):
            pass
        assert seen == ["the-mesh"]

    def test_set_mesh_mesh_context_fallback(self, monkeypatch):
        monkeypatch.delattr(jax, "set_mesh", raising=False)
        monkeypatch.delattr(jax.sharding, "use_mesh", raising=False)
        compat.reset_cache()

        class FakeMesh:
            entered = 0

            def __enter__(self):
                FakeMesh.entered += 1
                return self

            def __exit__(self, *exc):
                return False

        with compat.set_mesh(FakeMesh()):
            pass
        assert FakeMesh.entered == 1

    def test_positional_sharding_gated(self, monkeypatch):
        monkeypatch.delattr(
            jax.sharding, "PositionalSharding", raising=False
        )
        compat.reset_cache()
        assert not compat.has("positional_sharding")
        with pytest.raises(NotImplementedError, match="PositionalSharding"):
            compat.positional_sharding(jax.devices())

    def test_probe_cache_invalidation(self, monkeypatch):
        before = compat.has("set_mesh")
        monkeypatch.setattr(
            jax, "set_mesh", lambda m: contextlib.nullcontext(), raising=False
        )
        # cached verdict survives until reset
        assert compat.has("set_mesh") == before
        compat.reset_cache()
        assert compat.has("set_mesh")

    def test_every_probe_has_a_docstring(self):
        for name, fn in probes_lib._PROBES.items():
            assert fn.__doc__, f"probe {name!r} undocumented"
