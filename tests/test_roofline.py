"""Roofline machinery tests: HLO collective parsing, analytic-vs-HLO FLOPs
validation on unscanned configs (where XLA counts everything), and term
sanity across cells."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.launch import analytic
from repro.launch.hlo_cost import parse_collectives, roofline_terms
from repro.models import registry


class TestCollectiveParsing:
    def test_all_reduce_output_shape(self):
        hlo = (
            "%all-reduce.1 = bf16[4096,1536]{1,0} all-reduce(%add.3), "
            "replica_groups={{0,1,2,3}}, to_apply=%sum"
        )
        stats = parse_collectives(hlo)
        assert stats["all-reduce"]["count"] == 1
        assert stats["all-reduce"]["operand_bytes"] == 4096 * 1536 * 2

    def test_all_gather_divides_by_group(self):
        hlo = (
            "%ag = f32[64,128]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8], "
            "dimensions={0}"
        )
        stats = parse_collectives(hlo)
        # operand = output / group_size(4)
        assert stats["all-gather"]["operand_bytes"] == 64 * 128 * 4 / 4

    def test_reduce_scatter_multiplies(self):
        hlo = (
            "%rs = bf16[16,128]{1,0} reduce-scatter(%p0), "
            "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}"
        )
        stats = parse_collectives(hlo)
        assert stats["reduce-scatter"]["operand_bytes"] == 16 * 128 * 2 * 8

    def test_start_done_counted_once(self):
        hlo = """
        %ar0 = bf16[8]{0} all-reduce-start(%x), replica_groups={{0,1}}
        %ar1 = bf16[8]{0} all-reduce-done(%ar0)
        """
        stats = parse_collectives(hlo)
        assert stats["all-reduce"]["count"] == 1

    def test_ignores_non_collectives(self):
        assert parse_collectives("%a = f32[2]{0} add(%x, %y)") == {}


class TestRooflineTerms:
    def test_term_formulas(self):
        t = roofline_terms(197e12, 819e9, 50e9)
        assert abs(t["compute_s"] - 1.0) < 1e-9
        assert abs(t["memory_s"] - 1.0) < 1e-9
        assert abs(t["collective_s"] - 1.0) < 1e-9

    def test_causal_pair_fraction(self):
        # nq = nk = 4 equal blocks: visible pairs = 4+3+2+1 = 10 of 16
        assert analytic.causal_pair_fraction(2048, 512, 512) == 10 / 16
        # long seq converges to ~1/2
        f = analytic.causal_pair_fraction(1 << 18, 512, 1024)
        assert 0.5 < f < 0.52


class TestAnalyticVsHLO:
    """On an unscanned, unrematted, naive-attention config XLA's
    cost_analysis counts every op — analytic must agree within ~35%
    (analytic uses the flash 3.5x attention multiplier; naive AD is 3x)."""

    def test_train_flops_match(self):
        cfg = registry.get_config("lm_350m").reduced(
            num_layers=2, d_model=128, num_heads=4, head_dim=32, d_ff=512,
            vocab_size=2048, scan_layers=False, remat="none",
            attn_impl="naive", dtype="float32",
        )
        b, s = 2, 128
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        batch = registry.make_concrete_batch(cfg, b, s)

        def step(p):
            return jax.value_and_grad(
                lambda q: registry.loss_fn(cfg, q, batch)
            )(p)

        compiled = jax.jit(step).lower(params).compile()
        hlo_flops = compat.cost_analysis(compiled)["flops"]
        ana = analytic.flops_cell(cfg, "train", b, s, causal_factor=1.0,
                                  remat="none")
        ratio = ana["total"] / hlo_flops
        assert 0.65 < ratio < 1.5, f"analytic/HLO = {ratio:.2f}"

    def test_prefill_flops_match(self):
        cfg = registry.get_config("lm_350m").reduced(
            num_layers=2, d_model=128, num_heads=4, head_dim=32, d_ff=512,
            vocab_size=2048, scan_layers=False, remat="none",
            attn_impl="naive", dtype="float32",
        )
        b, s = 2, 128
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        batch = registry.make_concrete_batch(cfg, b, s)

        def fwd(p):
            return registry.loss_fn(cfg, p, batch)

        compiled = jax.jit(fwd).lower(params).compile()
        hlo_flops = compat.cost_analysis(compiled)["flops"]
        ana = analytic.flops_cell(cfg, "prefill", b, s, causal_factor=1.0)
        # prefill analytic excludes the loss/softmax; generous band
        ratio = ana["total"] / hlo_flops
        assert 0.5 < ratio < 1.5, f"analytic/HLO = {ratio:.2f}"


class TestCellSanity:
    def test_decode_is_memory_bound_for_dense(self):
        cfg = registry.get_config("qwen2_72b")
        mesh = analytic.MeshModel.single()
        r = analytic.analytic_roofline(cfg, "decode", 128, 32768, mesh)
        assert r["memory_s"] > r["compute_s"]

    def test_train_compute_vs_collective_qwen2(self):
        cfg = registry.get_config("qwen2_72b")
        mesh = analytic.MeshModel.single()
        r = analytic.analytic_roofline(cfg, "train", 256, 4096, mesh)
        # 72B dense at TP=16 on 50GB/s links: compute and TP-collective terms
        # are the two big ones
        assert r["compute_s"] > r["memory_s"]
        assert r["collective_s"] > r["memory_s"]

    def test_multi_pod_halves_compute_term(self):
        cfg = registry.get_config("qwen2_72b")
        single = analytic.analytic_roofline(
            cfg, "train", 256, 4096, analytic.MeshModel.single())
        multi = analytic.analytic_roofline(
            cfg, "train", 256, 4096, analytic.MeshModel.multi())
        np.testing.assert_allclose(
            multi["compute_s"], single["compute_s"] / 2, rtol=1e-6)

    def test_param_count_matches_init(self):
        for arch in ("stablelm_3b", "phi35_moe", "rwkv6_3b"):
            cfg = registry.get_config(arch).reduced()
            params = registry.init_params(jax.random.PRNGKey(0), cfg)
            actual = sum(
                int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params)
            )
            # account for vocab padding to multiples of 512
            import dataclasses
            padded = dataclasses.replace(
                cfg, vocab_size=-(-cfg.vocab_size // 512) * 512
            )
            expected = padded.param_count()
            assert abs(actual - expected) / expected < 0.25, (
                f"{arch}: init {actual} vs formula {expected}"
            )
