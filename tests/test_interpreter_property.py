"""Property test: for randomly composed DrJAX programs, the MapReduce-plan
executor agrees with direct execution, and gradients stay in the primitive
set (the §5 translation is semantics-preserving on a program family, not
just the paper's examples)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import core as drjax

_OPS = ("square", "tanhmul", "affine")
_REDUCERS = ("sum", "mean", "weighted")


def _map_op(name, c):
    if name == "square":
        return lambda a: a * a + c
    if name == "tanhmul":
        return lambda a: jnp.tanh(a) * (a + c)
    return lambda a: 2.0 * a - c


def _build_program(n, op_names, reducer, consts):
    @drjax.program(partition_size=n)
    def prog(x, xs):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a, b: a + b, (y, xs))
        for name, c in zip(op_names, consts):
            z = drjax.map_fn(_map_op(name, c), z)
        if reducer == "sum":
            return drjax.reduce_sum(z)
        if reducer == "mean":
            return drjax.reduce_mean(z)
        w = jnp.linspace(0.5, 1.5, n)
        return drjax.reduce_weighted_mean(z, w)

    return prog


@given(
    n=st.integers(1, 8),
    ops=st.lists(st.sampled_from(_OPS), min_size=1, max_size=4),
    reducer=st.sampled_from(_REDUCERS),
    x=st.floats(-2, 2, allow_nan=False, width=32),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_plan_executor_matches_direct(n, ops, reducer, x, seed):
    consts = np.random.default_rng(seed).uniform(-1, 1, len(ops))
    prog = _build_program(n, ops, reducer, consts)
    xs = jnp.asarray(
        np.random.default_rng(seed + 1).uniform(-1, 1, n), jnp.float32
    )
    args = (jnp.float32(x), xs)
    direct = prog(*args)
    plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), n)
    (via_plan,) = drjax.run_plan(plan, *args)
    np.testing.assert_allclose(np.asarray(via_plan), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


@given(
    n=st.integers(1, 6),
    ops=st.lists(st.sampled_from(_OPS), min_size=1, max_size=3),
    reducer=st.sampled_from(("sum", "mean")),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=15, deadline=None)
def test_gradient_program_stays_in_primitive_set(n, ops, reducer, seed):
    consts = np.random.default_rng(seed).uniform(-1, 1, len(ops))
    prog = _build_program(n, ops, reducer, consts)
    xs = jnp.zeros((n,), jnp.float32)
    gx = jax.make_jaxpr(jax.grad(prog))(jnp.float32(0.3), xs)
    counts = drjax.count_primitives(gx)
    assert any(k.startswith("drjax_") for k in counts)
    # grad plan also executes correctly
    plan = drjax.build_plan(gx, n)
    (g,) = drjax.run_plan(plan, jnp.float32(0.3), xs)
    direct = jax.grad(prog)(jnp.float32(0.3), xs)
    np.testing.assert_allclose(np.asarray(g), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


# Deterministic slices of the two properties above — exercised even when
# hypothesis is absent (the random sweeps then skip).

_SMOKE_CASES = [
    (1, ["square"], "sum", 11),
    (4, ["tanhmul", "affine"], "mean", 7),
    (6, ["affine", "square", "tanhmul"], "weighted", 3),
]


@pytest.mark.parametrize("n,ops,reducer,seed", _SMOKE_CASES)
def test_plan_executor_matches_direct_smoke(n, ops, reducer, seed):
    consts = np.random.default_rng(seed).uniform(-1, 1, len(ops))
    prog = _build_program(n, ops, reducer, consts)
    xs = jnp.asarray(
        np.random.default_rng(seed + 1).uniform(-1, 1, n), jnp.float32
    )
    args = (jnp.float32(0.7), xs)
    direct = prog(*args)
    plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), n)
    (via_plan,) = drjax.run_plan(plan, *args)
    np.testing.assert_allclose(np.asarray(via_plan), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,ops,reducer,seed", _SMOKE_CASES[:2])
def test_gradient_program_stays_in_primitive_set_smoke(n, ops, reducer, seed):
    consts = np.random.default_rng(seed).uniform(-1, 1, len(ops))
    prog = _build_program(n, ops, reducer, consts)
    xs = jnp.zeros((n,), jnp.float32)
    gx = jax.make_jaxpr(jax.grad(prog))(jnp.float32(0.3), xs)
    counts = drjax.count_primitives(gx)
    assert any(k.startswith("drjax_") for k in counts)
    plan = drjax.build_plan(gx, n)
    (g,) = drjax.run_plan(plan, jnp.float32(0.3), xs)
    direct = jax.grad(prog)(jnp.float32(0.3), xs)
    np.testing.assert_allclose(np.asarray(g), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)
