"""int8 tensor-parallel collective tests (multi-device via conftest.device_pool)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import tpcomm


def test_fallback_matches_matmul_without_mesh():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (8, 16))
    w = jax.random.normal(k2, (16, 4))
    out = tpcomm.int8_matmul_reduce(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-5)


def test_wire_byte_model():
    # m=16, bf16 AR vs int8 AG-reduce: ~3.9x fewer bytes
    bf = tpcomm.bf16_wire_bytes(4096, 8192, 16)
    i8 = tpcomm.int8_wire_bytes(4096, 8192, 16)
    assert 3.5 < bf / i8 < 4.2


@pytest.mark.slow
def test_sharded_exactness_and_s8_on_wire(device_pool):
    res = device_pool.run("""
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models import tpcomm, partitioning
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh(
            (2, jax.device_count() // 2), ("data", "model"))
        T, F, D = 16, 32, 24
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, (T, F), jnp.float32)
        w = jax.random.normal(k2, (F, D), jnp.float32)
        with partitioning.axis_rules(mesh):
            f = lambda x, w: tpcomm.int8_matmul_reduce(
                x, w, out_dtype=jnp.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
            ws = jax.device_put(w, NamedSharding(mesh, P("model", None)))
            out = jax.jit(f)(xs, ws)
            ref = x @ w
            cos = float(
                (np.asarray(out).ravel() @ np.asarray(ref).ravel())
                / (np.linalg.norm(out) * np.linalg.norm(ref)))
            hlo = jax.jit(f).lower(xs, ws).compile().as_text()
            n_s8 = sum(1 for l in hlo.splitlines()
                       if "all-gather" in l and "s8[" in l)
        print(json.dumps({"cosine": cos, "s8_allgathers": n_s8}))
    """)
    assert res["cosine"] > 0.9999
    assert res["s8_allgathers"] >= 1  # the reduction rides int8 on the wire
