"""int8 tensor-parallel collective tests (subprocess: needs >1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import tpcomm

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_fallback_matches_matmul_without_mesh():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (8, 16))
    w = jax.random.normal(k2, (16, 4))
    out = tpcomm.int8_matmul_reduce(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(out, x @ w, rtol=1e-5, atol=1e-5)


def test_wire_byte_model():
    # m=16, bf16 AR vs int8 AG-reduce: ~3.9x fewer bytes
    bf = tpcomm.bf16_wire_bytes(4096, 8192, 16)
    i8 = tpcomm.int8_wire_bytes(4096, 8192, 16)
    assert 3.5 < bf / i8 < 4.2


@pytest.mark.slow
def test_sharded_exactness_and_s8_on_wire():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models import tpcomm, partitioning
        from repro.launch import mesh as mesh_lib

        mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
        T, F, D = 16, 32, 24
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, (T, F), jnp.float32)
        w = jax.random.normal(k2, (F, D), jnp.float32)
        with partitioning.axis_rules(mesh):
            f = lambda x, w: tpcomm.int8_matmul_reduce(
                x, w, out_dtype=jnp.float32)
            xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
            ws = jax.device_put(w, NamedSharding(mesh, P("model", None)))
            out = jax.jit(f)(xs, ws)
            ref = x @ w
            cos = float(
                (np.asarray(out).ravel() @ np.asarray(ref).ravel())
                / (np.linalg.norm(out) * np.linalg.norm(ref)))
            hlo = jax.jit(f).lower(xs, ws).compile().as_text()
            n_s8 = sum(1 for l in hlo.splitlines()
                       if "all-gather" in l and "s8[" in l)
        print(json.dumps({"cosine": cos, "s8_allgathers": n_s8}))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["cosine"] > 0.9999
    assert res["s8_allgathers"] >= 1  # the reduction rides int8 on the wire
