"""MapReduce AD tests (paper §2/§3/§5; Rush et al. 2023 closure property)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import core as drjax


def loss(x, y):
    return (x - y) ** 2


def maml_loss(model, lr, task):
    g = jax.grad(loss)(model, task)
    return loss(model - lr * g, task)


def make_parallel_maml(n):
    @drjax.program(partition_size=n)
    def parallel_maml_loss(model, lr, tasks):
        model_b = drjax.broadcast(model)
        lr_b = drjax.broadcast(lr)
        losses = drjax.map_fn(maml_loss, (model_b, lr_b, tasks))
        return drjax.reduce_mean(losses)

    return parallel_maml_loss


class TestClosure:
    """The derivative of a DrJAX program is another DrJAX program."""

    def test_forward_jaxpr_preserves_primitives_snippet5(self):
        f = make_parallel_maml(3)
        jxp = jax.make_jaxpr(f)(
            jnp.float32(0.0), jnp.float32(0.1), jnp.zeros((3,), jnp.float32)
        )
        counts = drjax.count_primitives(jxp)
        assert counts.get("drjax_broadcast", 0) == 2
        assert counts.get("drjax_reduce_mean", 0) == 1

    def test_grad_jaxpr_stays_in_primitive_set_snippet6(self):
        f = make_parallel_maml(3)
        jxp = jax.make_jaxpr(jax.grad(f))(
            jnp.float32(0.0), jnp.float32(0.1), jnp.zeros((3,), jnp.float32)
        )
        counts = drjax.count_primitives(jxp)
        # Snippet 6: grad introduces reduce_sum (transpose of broadcast) while
        # keeping broadcast and reduce_mean.
        assert counts.get("drjax_reduce_sum", 0) >= 1
        assert counts.get("drjax_broadcast", 0) >= 1

    def test_jacfwd_and_jacrev_agree(self):
        f = make_parallel_maml(4)
        args = (jnp.float32(0.3), jnp.float32(0.05), jnp.arange(4, dtype=jnp.float32))
        fwd = jax.jacfwd(f)(*args)
        rev = jax.jacrev(f)(*args)
        np.testing.assert_allclose(fwd, rev, rtol=1e-5)


class TestGradCorrectness:
    def test_maml_grad_matches_numerical(self):
        f = make_parallel_maml(3)
        model, lr = jnp.float32(0.2), jnp.float32(0.1)
        tasks = jnp.array([1.0, 2.0, 3.0], jnp.float32)
        g = jax.grad(f)(model, lr, tasks)
        eps = 1e-3
        num = (f(model + eps, lr, tasks) - f(model - eps, lr, tasks)) / (2 * eps)
        np.testing.assert_allclose(g, num, rtol=1e-2)

    def test_grad_wrt_partitioned_input(self):
        @drjax.program(partition_size=3)
        def f(xs):
            return drjax.reduce_sum(drjax.map_fn(lambda a: a**2, xs))

        xs = jnp.array([1.0, 2.0, 3.0])
        np.testing.assert_allclose(jax.grad(f)(xs), 2 * xs)

    def test_grad_through_reduce_mean(self):
        @drjax.program(partition_size=5)
        def f(x):
            return drjax.reduce_mean(drjax.broadcast(x) * 3.0)

        np.testing.assert_allclose(jax.grad(f)(jnp.float32(1.0)), 3.0, rtol=1e-6)

    def test_grad_through_weighted_mean_wrt_weights(self):
        """Self-tuning reductions (paper §6): weights are learnable."""

        @drjax.program(partition_size=3)
        def f(w):
            x = jnp.array([1.0, 2.0, 4.0])
            return drjax.reduce_weighted_mean(x, jax.nn.softmax(w))

        w = jnp.zeros((3,))
        g = jax.grad(f)(w)
        assert g.shape == (3,)
        # moving weight towards group 2 (largest value) increases the mean
        assert g[2] > 0 and g[0] < 0

    def test_grad_reduce_max_subgradient(self):
        @drjax.program(partition_size=4)
        def f(xs):
            return drjax.reduce_max(xs)

        xs = jnp.array([1.0, 5.0, 3.0, 2.0])
        g = jax.grad(f)(xs)
        np.testing.assert_allclose(g, [0.0, 1.0, 0.0, 0.0])

    def test_second_order(self):
        @drjax.program(partition_size=3)
        def f(x):
            y = drjax.broadcast(x)
            return drjax.reduce_sum(drjax.map_fn(lambda a: a**3, y))

        # f(x) = 3 x^3, f''(x) = 18 x
        h = jax.grad(jax.grad(f))(jnp.float32(2.0))
        np.testing.assert_allclose(h, 36.0, rtol=1e-5)

    @given(
        n=st.integers(1, 8),
        x=st.floats(-3, 3, allow_nan=False, width=32),
    )
    @settings(max_examples=20, deadline=None)
    def test_broadcast_reduce_grad_property(self, n, x):
        """grad of x -> reduce_sum(broadcast(x)^2) is 2 n x."""

        @drjax.program(partition_size=n)
        def f(v):
            y = drjax.broadcast(v)
            return drjax.reduce_sum(drjax.map_fn(lambda a: a * a, y))

        g = jax.grad(f)(jnp.float32(x))
        np.testing.assert_allclose(g, 2 * n * x, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n,x", [(1, 0.5), (4, -2.0), (8, 3.0)])
    def test_broadcast_reduce_grad_smoke(self, n, x):
        """Deterministic slice of the property above (runs without hypothesis)."""

        @drjax.program(partition_size=n)
        def f(v):
            y = drjax.broadcast(v)
            return drjax.reduce_sum(drjax.map_fn(lambda a: a * a, y))

        g = jax.grad(f)(jnp.float32(x))
        np.testing.assert_allclose(g, 2 * n * x, rtol=1e-4, atol=1e-4)


class TestParallelMamlTraining:
    def test_maml_training_reduces_loss(self):
        """Paper Snippet 7: pairing jax.grad with an SGD step trains MAML."""
        n = 8
        f = make_parallel_maml(n)
        tasks = jnp.linspace(-1.0, 1.0, n)
        model = jnp.float32(3.0)
        lr_inner = jnp.float32(0.05)
        loss0 = f(model, lr_inner, tasks)
        grad_fn = jax.jit(jax.grad(f))
        for _ in range(50):
            model = model - 0.1 * grad_fn(model, lr_inner, tasks)
        loss1 = f(model, lr_inner, tasks)
        assert loss1 < loss0

    def test_hypergradient_on_inner_lr(self):
        """Self-tuning: differentiate the MAML loss wrt the *inner* lr."""
        n = 4
        f = make_parallel_maml(n)
        tasks = jnp.linspace(0.5, 2.0, n)
        model = jnp.float32(0.0)
        lr = jnp.float32(0.01)
        dlr = jax.grad(f, argnums=1)(model, lr, tasks)
        # larger inner lr moves the model closer to each task -> lower loss
        assert dlr < 0
