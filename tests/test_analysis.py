"""Tests for the static plan analyzer (``repro.analysis``) and the unified
lint framework.

Structure mirrors the analyzer's contract:

* every oracle-suite program analyzes **clean** (``report.ok``);
* every pass has a deliberately broken fixture it **catches** — a mutated
  plan, a bad donation, an unstable capture, a skewed cost model — so the
  checks are known to be falsifiable, not vacuously green;
* the comm-cost pass is pinned exactly against the napkin
  ``cross_pod_bytes`` model and ``models/tpcomm`` wire math (satellite:
  the three int8 wire models must agree to the byte);
* the lint registry reproduces the historical compat grep and donation
  lint (zero violations on this tree) and each rule fires on a synthetic
  violating tree.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro import core as drjax
from repro.analysis import commcost
from repro.analysis.lints import run_lints
from repro.compression import PACK_COLS, int8_roundtrip
from repro.core import interpreter as interp
from repro.models import tpcomm
from repro.runtime import executor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# program zoo (the oracle-suite shapes the analyzer must pass clean)
# ---------------------------------------------------------------------------


def flat_plan(n=8, d=None):
    @drjax.program(partition_size=n)
    def f(x, xs):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a, b: a * b, (y, xs))
        return drjax.reduce_mean(z)

    shape = (n,) if d is None else (n, d)
    args = (jnp.float32(1.0), jnp.zeros(shape, jnp.float32))
    return drjax.build_plan(jax.make_jaxpr(f)(*args), n), args


def nested_plan(P=2, m=4):
    @drjax.program(placements={"pods": P, "clients": m})
    def f(x, data):
        y = drjax.broadcast(x)
        z = drjax.map_fn(lambda a, b: a * b, (y, data))
        partial = drjax.reduce_mean(z, placement="clients")
        return drjax.reduce_mean(partial, placement="pods")

    args = (jnp.float32(2.0), jnp.zeros((P, m), jnp.float32))
    jx = jax.make_jaxpr(f)(*args)
    return drjax.build_plan(jx, {"pods": P, "clients": m}), args


def scan_round_plan(n=4, length=3):
    @drjax.program(partition_size=n)
    def f(m, ys):
        def body(m, _):
            g = drjax.reduce_mean(
                drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys)))
            return m - 0.5 * g, g

        m, gs = jax.lax.scan(body, m, None, length=length)
        return m, gs

    args = (jnp.float32(0.3), jnp.arange(float(n)))
    return drjax.build_plan(jax.make_jaxpr(f)(*args), n), args


def while_pred_comm_plan(n=4):
    """Data-dependent while whose PREDICATE reduces (adversarial nesting)."""

    @drjax.program(partition_size=n)
    def f(x, xs):
        def cond(c):
            s = drjax.reduce_mean(
                drjax.map_fn(lambda a, b: a + b, (drjax.broadcast(c), xs)))
            return s < 10.0

        return jax.lax.while_loop(cond, lambda c: c + 1.0, x)

    args = (jnp.float32(0.0), jnp.arange(float(n)))
    return drjax.build_plan(jax.make_jaxpr(f)(*args), n), args


def cond_comm_plan(n=4):
    @drjax.program(partition_size=n)
    def f(p, x, xs):
        def talk(x):
            y = drjax.broadcast(x)
            return drjax.reduce_mean(
                drjax.map_fn(lambda a, b: a * b, (y, xs)))

        return jax.lax.cond(p, talk, lambda x: x * 2.0, x)

    args = (jnp.array(True), jnp.float32(1.0), jnp.arange(float(n)))
    return drjax.build_plan(jax.make_jaxpr(f)(*args), n), args


def scan_of_cond_plan(n=4, length=5):
    """Comm inside a CondStage branch inside a LoopStage (adversarial)."""

    @drjax.program(partition_size=n)
    def f(m, ys):
        def body(m, i):
            def talk(m):
                return drjax.reduce_mean(
                    drjax.map_fn(
                        lambda a, b: a + b, (drjax.broadcast(m), ys)))

            m = jax.lax.cond(i % 2 == 0, talk, lambda m: m, m)
            return m, ()

        m, _ = jax.lax.scan(body, m, jnp.arange(length))
        return m

    args = (jnp.float32(0.0), jnp.arange(float(n)))
    return drjax.build_plan(jax.make_jaxpr(f)(*args), n), args


def fused_hier_plan(n=8, P=2, d=512):
    @drjax.program(partition_size=n)
    def f(xs):
        return drjax.hierarchical_reduce_mean(
            xs, num_supergroups=P, compress_fn=int8_roundtrip)

    args = (jnp.zeros((n, d), jnp.float32),)
    return drjax.build_plan(jax.make_jaxpr(f)(*args), n), args


ORACLE_PROGRAMS = {
    "flat": flat_plan,
    "nested": nested_plan,
    "scan_round": scan_round_plan,
    "while_pred_comm": while_pred_comm_plan,
    "cond_comm": cond_comm_plan,
    "scan_of_cond": scan_of_cond_plan,
    "fused_hier": fused_hier_plan,
}


# ---------------------------------------------------------------------------
# oracle suite: every program analyzes clean
# ---------------------------------------------------------------------------


class TestOracleSuiteClean:
    @pytest.mark.parametrize("name", sorted(ORACLE_PROGRAMS))
    def test_analyze_ok(self, name):
        plan, _ = ORACLE_PROGRAMS[name]()
        report = plan.analyze()
        assert report.ok, f"{name}: {report}"
        report.raise_if_errors()  # must be a no-op when ok

    def test_fused_hier_regroup_is_info_not_error(self):
        plan, _ = fused_hier_plan()
        report = plan.analyze()
        infos = report.by_code("placement/regroup-boundary")
        assert infos and all(f.severity == "info" for f in infos)

    def test_subplans_iterates_nested(self):
        plan, _ = scan_of_cond_plan()
        plans = plan.subplans()
        assert plans[0] is plan and len(plans) >= 3  # top + body + branches


# ---------------------------------------------------------------------------
# placement safety: broken fixtures
# ---------------------------------------------------------------------------


class TestPlacementSafety:
    def _comm_in_local_mutant(self):
        plan, _ = cond_comm_plan()
        cond_stage = next(
            s for s in plan.stages if isinstance(s, interp.CondStage))
        bp = next(
            b for b in cond_stage.branch_plans
            if any(isinstance(s, interp.Reduce) for s in b.stages))
        ri = next(
            i for i, s in enumerate(bp.stages)
            if isinstance(s, interp.Reduce))
        bp.stages[ri] = interp.LocalCompute(
            at_groups=True, eqns=[bp.stages[ri].eqn])
        return plan

    def test_comm_inside_local_via_cond_branch(self):
        """A reduce smuggled into a GROUP_COMPUTE stage inside a cond branch
        is caught at depth, with the nested stage named."""
        plan = self._comm_in_local_mutant()
        findings = analysis.check_placement_safety(plan)
        errs = [f for f in findings if f.code == "placement/comm-in-local"]
        assert len(errs) == 1
        assert errs[0].stage and "_b" in errs[0].stage  # nested branch name
        with pytest.raises(Exception):
            plan.check_locality()  # the legacy checker agrees

    def test_comm_in_local_fails_analyze_and_raises(self):
        plan = self._comm_in_local_mutant()
        report = plan.analyze(comm_cost=False)
        assert not report.ok
        with pytest.raises(AssertionError, match="comm-in-local"):
            report.raise_if_errors()

    def test_broken_pairing_detected(self):
        plan, _ = nested_plan()
        bstage = next(
            s for s in plan.stages if isinstance(s, interp.Broadcast))
        bstage.source = "clients"  # outermost broadcast must source "server"
        findings = analysis.check_placement_safety(plan)
        assert any(f.code == "placement/pairing" for f in findings)

    def test_clean_plans_have_no_placement_findings(self):
        for maker in (flat_plan, nested_plan, scan_round_plan):
            plan, _ = maker()
            assert analysis.check_placement_safety(plan) == []


# ---------------------------------------------------------------------------
# donation / aliasing
# ---------------------------------------------------------------------------


class TestDonation:
    def test_round_style_donation_clean(self):
        @drjax.program(partition_size=4)
        def f(params, xs):
            y = drjax.broadcast(params)
            z = drjax.map_fn(lambda a, b: a + b, (y, xs))
            return params + drjax.reduce_mean(z)

        args = (jnp.arange(3.0), jnp.zeros((4, 3), jnp.float32))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 4)
        assert plan.analyze(donate_argnums=(0,)).ok

    def test_use_after_donate_fixture(self):
        """Donating x whose alias target is produced BEFORE x's last read
        must be an error: the late read observes an overwritten buffer."""

        @drjax.program(partition_size=4)
        def f(x, ys):
            a = x + 1.0
            s = drjax.reduce_mean(ys)
            return a, x * s

        args = (jnp.arange(3.0), jnp.arange(4.0))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 4)
        report = plan.analyze(donate_argnums=(0,))
        assert not report.ok
        errs = report.by_code("donation/use-after-donate")
        assert len(errs) == 1 and "stage_2" in errs[0].message
        # without the donation the same plan is clean
        assert plan.analyze().ok

    def test_dropped_donation_explains_why(self):
        @drjax.program(partition_size=4)
        def f(big, xs):
            s = drjax.reduce_mean(xs)
            return s + big.sum()  # big is read, but no (3,)-shaped output

        args = (jnp.arange(3.0), jnp.arange(4.0))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 4)
        report = plan.analyze(donate_argnums=(0,))
        assert report.ok  # dropped donation is a warning, not an error
        warns = report.by_code("donation/dropped")
        assert len(warns) == 1

    def test_carry_not_eligible_when_init_escapes(self):
        """A loop carry whose init is also a plan OUTPUT cannot be donated
        into the loop in place."""

        @drjax.program(partition_size=4)
        def f(m, ys):
            def body(m, _):
                g = drjax.reduce_mean(
                    drjax.map_fn(
                        lambda a, b: a - b, (drjax.broadcast(m), ys)))
                return m - g, ()

            out, _ = jax.lax.scan(body, m, None, length=2)
            return out, m  # m escapes alongside the loop result

        args = (jnp.float32(0.3), jnp.arange(4.0))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 4)
        findings = analysis.analyze_donation(plan)
        assert any(f.code == "donation/carry-not-eligible" for f in findings)

    def test_compiled_plan_donation_report(self):
        plan, args = scan_round_plan()
        compiled = plan.compile(donate_argnums=(0,))
        report = compiled.donation_report()
        assert report.ok

    def test_bad_argnum_is_error(self):
        plan, _ = flat_plan()
        report = plan.analyze(donate_argnums=(17,))
        assert report.by_code("donation/bad-argnum")


# ---------------------------------------------------------------------------
# retrace hazards + fingerprint explanation
# ---------------------------------------------------------------------------


def _captured_scalar_plan(value):
    c = jnp.array([value], jnp.float32)  # closed over -> captured const

    @drjax.program(partition_size=4)
    def f(xs):
        z = drjax.map_fn(lambda a: a * c[0], xs)
        return drjax.reduce_mean(z)

    return drjax.build_plan(jax.make_jaxpr(f)(jnp.arange(4.0)), 4)


class TestRetrace:
    def test_unstable_const_flagged(self):
        plan = _captured_scalar_plan(0.1)
        findings = analysis.analyze_retrace(plan)
        warns = [f for f in findings if f.code == "retrace/unstable-const"]
        assert len(warns) == 1
        assert "plan input" in warns[0].message  # tells the user the fix

    def test_explain_fingerprint_mismatch_pinpoints_const(self):
        pa = _captured_scalar_plan(0.1)
        pb = _captured_scalar_plan(0.2)
        assert executor.plan_fingerprint(pa) != executor.plan_fingerprint(pb)
        diffs = analysis.explain_fingerprint_mismatch(pa, pb)
        assert len(diffs) == 1
        assert "const[0]" in diffs[0] and "VALUE differs" in diffs[0]
        # identical captures -> identical fingerprint, no diffs
        assert analysis.explain_fingerprint_mismatch(
            pa, _captured_scalar_plan(0.1)) == []

    def test_mesh_keyed_leg_warns_on_donated_multilevel_plan(self):
        """A donated executable spanning >= 2 replica levels is keyed by a
        mesh elastic events can change — flagged, pointing at the elastic
        split."""
        plan, _ = nested_plan()
        report = plan.analyze(donate_argnums=(0,))
        warns = report.by_code("retrace/mesh-keyed-leg")
        assert len(warns) == 1
        assert warns[0].severity == "warning"
        assert "elastic" in warns[0].message
        # no donation -> no hazard (nothing pins the old mesh's buffers)
        assert not plan.analyze().by_code("retrace/mesh-keyed-leg")
        # flat single-level plan: elasticity never re-keys its mesh
        fplan, _ = flat_plan()
        assert not fplan.analyze(donate_argnums=(0,)).by_code(
            "retrace/mesh-keyed-leg"
        )

    def test_fingerprint_parts_define_the_fingerprint(self):
        """The decomposition must reproduce plan_fingerprint's exact byte
        stream (the executable cache keys on it)."""
        import hashlib

        plan, _ = scan_round_plan()
        h = hashlib.sha1()
        for _name, data in executor.fingerprint_parts(plan):
            h.update(data)
        assert h.hexdigest() == executor.plan_fingerprint(plan)
        names = [n for n, _ in executor.fingerprint_components(plan)]
        assert names[:6] == [
            "placements", "placement_kinds", "partitioned_invars",
            "partitioned_outvars", "jaxpr", "stage_skeleton",
        ]


# ---------------------------------------------------------------------------
# communication cost
# ---------------------------------------------------------------------------


class TestCommCost:
    def test_flat_reduce_is_all_dcn(self):
        n, d = 8, 16
        plan, _ = flat_plan(n, d)
        cost = plan.comm_cost()
        # broadcast fans a scalar to n groups; reduce collects (n, d) f32
        assert cost.dcn_bytes == n * 4 + n * d * 4
        assert cost.ici_bytes == 0.0

    def test_nested_splits_dcn_ici(self):
        P, m = 2, 4
        plan, _ = nested_plan(P, m)
        cost = plan.comm_cost()
        # clients-level comm rides ICI; only pods-level crosses DCN
        assert cost.dcn_bytes == P * 4 + P * 4  # broadcast@pods + reduce@pods
        assert cost.ici_bytes == P * m * 4 + P * m * 4

    def test_loop_multiplies_trip_count(self):
        plan, _ = scan_round_plan(n=4, length=3)
        cost = plan.comm_cost()
        single = 4 * 4 + 4 * 4  # broadcast + reduce, n=4 f32 scalars
        assert cost.dcn_bytes == 3 * single
        assert all(c.multiplier == 3.0 for c in cost.per_stage)

    def test_while_flags_unknown_trips(self):
        plan, _ = while_pred_comm_plan()
        cost = plan.comm_cost()
        assert cost.unknown_trips
        assert any(f.code == "commcost/unknown-trip" for f in cost.findings)
        # the predicate's comm is itemized under the cond-plan namespace
        assert any("_c_" in c.stage for c in cost.per_stage)

    def test_cond_counts_max_branch(self):
        plan, _ = cond_comm_plan()
        cost = plan.comm_cost()
        # the silent branch has no comm; the talking branch is the max
        assert cost.total_bytes > 0
        assert all(c.counted for c in cost.per_stage)

    def test_fused_int8_wire_format(self):
        n, P, d = 8, 2, 512
        plan, _ = fused_hier_plan(n, P, d)
        cost = plan.comm_cost()
        dcn_stages = [c for c in cost.per_stage if c.link == "dcn"]
        assert len(dcn_stages) == 1
        (c,) = dcn_stages
        assert c.wire_format == "int8+scales"
        assert c.wire_bytes == P * (d * 1.0 + (d // PACK_COLS) * 4.0)

    def test_int8_block_pinned_to_pack_cols(self):
        assert commcost.INT8_BLOCK == PACK_COLS

    def test_cross_validate_clean_on_cpu(self):
        plan, _ = flat_plan(8, 32)
        findings = analysis.cross_validate_comm_cost(plan)
        assert not [f for f in findings if f.severity == "error"], [
            str(f) for f in findings]

    def test_cross_validate_catches_skewed_model(self):
        """Fault injection: a >5% model skew must produce a mismatch error
        (proves the cross-check can actually fail)."""
        plan, _ = flat_plan(8, 32)
        findings = analysis.cross_validate_comm_cost(plan, model_scale=1.1)
        errors = [f for f in findings if f.code == "commcost/model-mismatch"]
        no_model = [f for f in findings if f.code == "commcost/no-cost-model"]
        assert errors or no_model  # mismatch, unless backend has no costs

    def test_scan_of_cond_multiplied_and_counted(self):
        plan, _ = scan_of_cond_plan(n=4, length=5)
        cost = plan.comm_cost()
        counted = [c for c in cost.per_stage if c.counted]
        assert counted and all(c.multiplier == 5.0 for c in counted)
        assert all("_b" in c.stage for c in counted)  # inside the branch


# ---------------------------------------------------------------------------
# satellite: the three int8 wire models agree
# ---------------------------------------------------------------------------


class TestCrossPodBytesModel:
    def test_napkin_matches_analyzer_exactly(self):
        n, P, d = 8, 2, 512
        plan, _ = fused_hier_plan(n, P, d)
        static_dcn = plan.comm_cost().dcn_bytes
        napkin = drjax.cross_pod_bytes(
            4.0 * d, n=n, num_supergroups=P, compress="int8")
        assert napkin["hierarchical_bytes"] == static_dcn

    def test_int8_ratio_includes_scale_overhead(self):
        # NOT the naive 0.25: one f32 scale per 256-block rides along
        assert drjax.int8_wire_ratio() == (1.0 + 4.0 / PACK_COLS) / 4.0
        assert drjax.int8_wire_ratio() > 0.25

    def test_consistent_with_tpcomm_wire_math(self):
        """models/tpcomm ships one f32 scale per ROW of d values — i.e. the
        same formula with block=d."""
        t, d, m = 128, 4096, 8
        expected = (m - 1) / m * t * (4.0 * d) * drjax.int8_wire_ratio(
            block=d)
        assert tpcomm.int8_wire_bytes(t, d, m) == pytest.approx(expected)

    def test_compress_ratio_still_supported(self):
        a = drjax.cross_pod_bytes(1024.0, n=64, num_supergroups=4,
                                  compress_ratio=0.5)
        assert a["hierarchical_bytes"] == 4 * 1024.0 * 0.5

    def test_unknown_scheme_raises(self):
        with pytest.raises(ValueError, match="unknown compress scheme"):
            drjax.cross_pod_bytes(1.0, n=2, num_supergroups=1,
                                  compress="fp4")


# ---------------------------------------------------------------------------
# lint framework
# ---------------------------------------------------------------------------


def _write(root, rel, content):
    path = os.path.join(root, rel)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        fh.write(textwrap.dedent(content))


class TestLints:
    def test_repo_is_clean(self):
        assert run_lints() == []

    def test_unknown_rule_raises(self):
        with pytest.raises(KeyError, match="no-such-rule"):
            run_lints(rules=["no-such-rule"])

    def test_compat_surface_rule(self, tmp_path):
        root = str(tmp_path)
        # assembled so THIS test file never contains the banned substrings
        banned = "Axis" + "Type"
        _write(root, "src/repro/models/bad.py", f"x = jax.{banned}.Auto\n")
        _write(root, "src/repro/compat/ok.py", f"x = jax.{banned}.Auto\n")
        vs = run_lints(root=root, rules=["compat-surface"])
        assert [v.path for v in vs] == ["src/repro/models/bad.py"]

    def test_donate_jit_rule_and_marker(self, tmp_path):
        root = str(tmp_path)
        _write(root, "src/repro/algorithms/bad.py", """\
            import jax
            step = jax.jit(lambda s: s)
        """)
        _write(root, "src/repro/algorithms/ok.py", """\
            import jax
            step = jax.jit(lambda s: s, donate_argnums=(0,))
            # no-donate: serving path, params reused across calls
            serve = jax.jit(lambda s: s)
        """)
        vs = run_lints(root=root, rules=["donate-jit"])
        assert [(v.path, v.line) for v in vs] == [
            ("src/repro/algorithms/bad.py", 2)]
        assert "donate the carried state" in vs[0].message

    def test_no_version_branch_rule(self, tmp_path):
        root = str(tmp_path)
        _write(root, "src/repro/runtime/bad.py", """\
            import jax
            NEW = jax.__version__ >= "0.5"
        """)
        _write(root, "src/repro/compat/probes.py", """\
            import jax
            NEW = jax.__version__ >= "0.5"
        """)
        vs = run_lints(root=root, rules=["no-version-branch"])
        assert [v.path for v in vs] == ["src/repro/runtime/bad.py"]

    def test_jit_of_plan_rule(self, tmp_path):
        root = str(tmp_path)
        _write(root, "src/repro/core/bad.py", """\
            import jax
            fast = jax.jit(lambda x: x)
        """)
        _write(root, "src/repro/launch/bad2.py", """\
            import jax
            fast = jax.jit(run_plan, donate_argnums=(0,))
        """)
        _write(root, "src/repro/runtime/executor.py", """\
            import jax
            fast = jax.jit(run_plan)
        """)
        vs = run_lints(root=root, rules=["jit-of-plan"])
        assert sorted(v.path for v in vs) == [
            "src/repro/core/bad.py", "src/repro/launch/bad2.py"]

    def test_mesh_axes_literal_rule(self, tmp_path):
        root = str(tmp_path)
        _write(root, "src/repro/runtime/bad.py", """\
            AXES = ("pod", "data")
        """)
        _write(root, "src/repro/launch/mesh.py", """\
            REPLICA_AXES = ("pod", "data")
            DEEP = ("superpod", "pod", "data")
        """)
        _write(root, "src/repro/models/ok.py", """\
            spec = ("batch", "model")
            one = ("data",)
        """)
        vs = run_lints(root=root, rules=["mesh-axes-literal"])
        assert [(v.path, v.line) for v in vs] == [
            ("src/repro/runtime/bad.py", 1)]
        assert "launch/mesh.py" in vs[0].message

    def test_suppression_marker(self, tmp_path):
        root = str(tmp_path)
        _write(root, "src/repro/core/bad.py", """\
            import jax
            # lint: disable=jit-of-plan
            fast = jax.jit(lambda x: x)
        """)
        assert run_lints(root=root, rules=["jit-of-plan"]) == []

    def test_cli_json_output(self):
        out = subprocess.run(
            [sys.executable, os.path.join(REPO, "scripts", "lint.py"),
             "--json"],
            capture_output=True, text=True, check=True,
        )
        report = json.loads(out.stdout)
        assert report["ok"] and report["violations"] == []
        assert set(report["rules"]) >= {"compat-surface", "donate-jit"}

    def test_check_donation_shim(self):
        out = subprocess.run(
            [sys.executable,
             os.path.join(REPO, "scripts", "check_donation.py")],
            capture_output=True, text=True, check=True,
        )
        assert out.stdout.strip() == "donation lint: OK"

    def test_lints_importable_without_jax(self):
        """The lint CLI path must not load JAX (it runs before the suite)."""
        code = (
            "import sys; sys.path.insert(0, 'src');"
            "from repro.analysis import lints;"
            "assert 'jax' not in sys.modules, 'lints dragged in jax'"
        )
        subprocess.run(
            [sys.executable, "-c", code], cwd=REPO, check=True)


# ---------------------------------------------------------------------------
# report surface
# ---------------------------------------------------------------------------


class TestReportSurface:
    def test_to_json_roundtrip(self):
        plan, _ = fused_hier_plan()
        report = plan.analyze()
        blob = json.loads(report.to_json())
        assert blob["ok"] is True
        assert blob["comm_cost"]["dcn_bytes"] == report.comm_cost.dcn_bytes

    def test_warnings_do_not_flip_ok(self):
        plan = _captured_scalar_plan(0.5)
        report = plan.analyze()
        assert report.ok and report.warnings
