"""Persistent multi-device script runner (driven by tests/conftest.py).

The parent launches this with ``XLA_FLAGS=--xla_force_host_platform_device_
count=N`` in the environment; JAX locks the device count at first init, so
the whole point of this process is to pay interpreter startup + jax import +
compilation-cache warmup ONCE per test session instead of once per test.

Protocol (JSON lines, one request -> one response):
  request:  {"src": "<python source>"}
  response: {"ok": bool, "stdout": "<captured prints>", "error": "<traceback>"}

Each script runs under ``exec`` with a fresh globals dict (no state leaks
between tests) but a shared ``sys.modules`` (imports after the first script
are instant). Printed output is captured and returned, never written to the
protocol channel.
"""

import contextlib
import io
import json
import os
import sys
import traceback


def main() -> None:
    # The JSON protocol owns a private dup of the original stdout fd; fd 1
    # itself is repointed at stderr so fd-level writes from exec'd scripts
    # (nested subprocesses, native XLA logging) land in the parent's stderr
    # drain instead of desyncing the protocol channel. Python-level prints
    # are still captured per-script via redirect_stdout below.
    stdout = os.fdopen(os.dup(sys.stdout.fileno()), "w", buffering=1)
    os.dup2(sys.stderr.fileno(), sys.stdout.fileno())
    stdin = sys.stdin
    while True:
        line = stdin.readline()
        if not line:
            return
        line = line.strip()
        if not line:
            continue
        req = json.loads(line)
        buf = io.StringIO()
        resp = {"ok": True, "error": ""}
        try:
            code = compile(req["src"], "<device-pool>", "exec")
            with contextlib.redirect_stdout(buf):
                exec(code, {"__name__": "__device_pool__"})
        except KeyboardInterrupt:
            raise
        except BaseException:  # noqa: BLE001 - report everything to the parent
            resp = {"ok": False, "error": traceback.format_exc()}
        resp["stdout"] = buf.getvalue()
        stdout.write(json.dumps(resp) + "\n")
        stdout.flush()


if __name__ == "__main__":
    main()
