"""Optional-hypothesis shim.

``hypothesis`` is a property-testing extra, not a runtime dependency. Test
modules that mix property tests with plain unit tests import ``given`` /
``settings`` / ``st`` from here: with hypothesis installed this module is a
passthrough; without it, each ``@given`` test skips itself at call time via
``pytest.importorskip("hypothesis")`` while the plain tests (including the
deterministic smoke variants of the key identities) keep running.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` at decoration time; any
        attribute access or call returns itself, so strategy expressions in
        ``@given(...)`` arguments evaluate without hypothesis."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # *args-only signature: pytest resolves no fixtures from it, and
            # it accepts ``self`` when the test lives in a class.
            def skip_without_hypothesis(*a, **k):
                pytest.importorskip("hypothesis")

            skip_without_hypothesis.__name__ = getattr(
                fn, "__name__", "property_test"
            )
            skip_without_hypothesis.__doc__ = fn.__doc__
            return skip_without_hypothesis

        return deco
