"""Tests for the jaxpr → MapReducePlan interpreter (paper §5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro.core import interpreter as interp


def loss(x, y):
    return (x - y) ** 2


def maml_loss(model, lr, task):
    g = jax.grad(loss)(model, task)
    return loss(model - lr * g, task)


def make_parallel_maml(n):
    @drjax.program(partition_size=n)
    def parallel_maml_loss(model, lr, tasks):
        model_b = drjax.broadcast(model)
        lr_b = drjax.broadcast(lr)
        losses = drjax.map_fn(maml_loss, (model_b, lr_b, tasks))
        return drjax.reduce_mean(losses)

    return parallel_maml_loss


ARGS3 = (jnp.float32(0.1), jnp.float32(0.05), jnp.array([1.0, 2.0, 3.0]))


class TestPlanStructure:
    def test_forward_plan_stages(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(f)(*ARGS3), 3)
        kinds = [getattr(s, "kind", None) for s in plan.stages]
        assert kinds == [
            "BROADCAST",
            "BROADCAST",
            "GROUP_COMPUTE",
            "REDUCE",
        ]
        reduce_stage = plan.stages[-1]
        assert reduce_stage.op == "reduce_mean"

    def test_grad_plan_contains_reduce_sum(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(jax.grad(f))(*ARGS3), 3)
        ops = [s.op for s in plan.stages if isinstance(s, interp.Reduce)]
        assert "reduce_sum" in ops  # transpose of broadcast, paper Snippet 6

    def test_locality_invariant(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(f)(*ARGS3), 3)
        plan.check_locality()  # must not raise

    def test_input_placement_detection(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(f)(*ARGS3), 3)
        assert plan.partitioned_invars == (False, False, True)


class TestPlanExecution:
    """run_plan == direct execution: the translation is semantics-preserving."""

    def test_forward(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(f)(*ARGS3), 3)
        (out,) = drjax.run_plan(plan, *ARGS3)
        np.testing.assert_allclose(out, f(*ARGS3), rtol=1e-6)

    def test_gradient(self):
        f = make_parallel_maml(3)
        gf = jax.grad(f)
        plan = drjax.build_plan(jax.make_jaxpr(gf)(*ARGS3), 3)
        (out,) = drjax.run_plan(plan, *ARGS3)
        np.testing.assert_allclose(out, gf(*ARGS3), rtol=1e-6)

    def test_multi_output_program(self):
        @drjax.program(partition_size=4)
        def f(x, ys):
            xb = drjax.broadcast(x)
            prod = drjax.map_fn(lambda a, b: a * b, (xb, ys))
            return drjax.reduce_sum(prod), drjax.reduce_max(ys)

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0, 4.0]))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 4)
        outs = drjax.run_plan(plan, *args)
        direct = f(*args)
        np.testing.assert_allclose(outs[0], direct[0])
        np.testing.assert_allclose(outs[1], direct[1])


class TestEmitters:
    def test_text_emitter(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(f)(*ARGS3), 3)
        txt = plan.to_text()
        assert "BROADCAST server->groups" in txt
        assert "REDUCE_MEAN groups->server" in txt

    def test_beam_emitter(self):
        f = make_parallel_maml(3)
        plan = drjax.build_plan(jax.make_jaxpr(f)(*ARGS3), 3)
        beam = plan.to_beam()
        assert "range(3)" in beam  # one PCollection element per group
        assert "beam.CombineGlobally(_reduce_mean)" in beam
        # local stages call the real sliced callables, and every fn the
        # pipeline references actually exists
        fns = plan.stage_fns()
        assert "fns['stage_2']" in beam
        assert "stage_2" in fns
        # broadcasts are named side inputs, not dangling references
        assert "beam.pvalue.AsSingleton" in beam

    def test_count_primitives(self):
        f = make_parallel_maml(3)
        counts = drjax.count_primitives(jax.make_jaxpr(f)(*ARGS3))
        assert counts == {"drjax_broadcast": 2, "drjax_reduce_mean": 1}


class TestJitBoundary:
    def test_primitives_survive_inside_jit_jaxpr(self):
        """Primitives are preserved even when the program is nested in pjit."""

        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(drjax.broadcast(x) * 2.0)

        jitted = jax.jit(f)
        jxp = jax.make_jaxpr(jitted)(jnp.float32(1.0))
        counts = drjax.count_primitives(jxp)
        assert counts.get("drjax_broadcast") == 1
        assert counts.get("drjax_reduce_sum") == 1
