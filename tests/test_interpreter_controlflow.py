"""Tests for the control-flow-aware plan builder (interpreter v2).

Covers the §5 acceptance bar: jitted programs yield the same plan as unjitted
ones; scans/whiles/conds whose bodies communicate become explicit
LOOP/COND stages with sub-plans; `run_plan` matches direct execution bitwise
on CPU for the shipped round functions; and `to_beam()` output contains no
undefined names.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import optim
from repro.algorithms.async_rounds import make_async_local_sgd_round
from repro.algorithms.rounds import (
    LocalSGDConfig,
    make_local_sgd_round,
    make_multi_round,
)
from repro.core import interpreter as interp


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def stage_kinds(plan):
    return [s.kind for s in plan.stages]


def assert_bitwise(plan, fn, args):
    """run_plan output == direct execution, bitwise, on CPU."""
    flat = jax.tree_util.tree_leaves(args)
    outs = drjax.run_plan(plan, *flat)
    direct = jax.tree_util.tree_leaves(fn(*args))
    assert len(outs) == len(direct)
    for a, b in zip(outs, direct):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_GENERATED_NAME = re.compile(
    r"\b(?:t|o|r|bc|g|s|c|lit|x|undef|i|in_)\d+\b"
    r"|\b(?:carry|ys)[\d_]+\b|\bnum_iters_[\w]+\b"
)


def assert_no_undefined_names(beam_text):
    """Every generated identifier in to_beam() is assigned before use."""
    compile(beam_text, "<to_beam>", "exec")  # must at least be valid Python
    assert "undef" not in beam_text and "(bug?)" not in beam_text
    defined = set()
    for lineno, line in enumerate(beam_text.splitlines()):
        code = line.split("#")[0]
        m = re.match(r"\s*(?:for\s+(\w+)\s+in\b|([A-Za-z_]\w*)\s*=[^=])", code)
        lhs = (m.group(1) or m.group(2)) if m else None
        for tok_m in _GENERATED_NAME.finditer(code):
            tok = tok_m.group(0)
            if tok == lhs or tok in defined:
                continue
            raise AssertionError(
                f"undefined name {tok!r} used on line {lineno}: {line!r}"
            )
        if lhs:
            defined.add(lhs)


def quadratic_setup(n=4, steps=2, dim=3):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (dim,)),
        "b": jnp.float32(0.0),
    }
    data = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (n, steps, 8, dim)),
        "y": jax.random.normal(jax.random.PRNGKey(2), (n, steps, 8)),
    }
    return loss_fn, params, data


# ---------------------------------------------------------------------------
# jit transparency
# ---------------------------------------------------------------------------


class TestJitTransparency:
    def test_jit_plan_equals_unjitted_plan(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(drjax.broadcast(x) * 2.0)

        x = jnp.float32(1.5)
        plain = drjax.build_plan(jax.make_jaxpr(f)(x), 3)
        jitted = drjax.build_plan(jax.make_jaxpr(jax.jit(f))(x), 3)
        assert stage_kinds(jitted) == stage_kinds(plain)
        assert stage_kinds(jitted) == ["BROADCAST", "GROUP_COMPUTE", "REDUCE"]
        assert_bitwise(jitted, f, (x,))

    def test_nested_jit(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            xb = drjax.broadcast(x)
            z = drjax.map_fn(lambda a, b: a * b + 1.0, (xb, ys))
            return drjax.reduce_mean(z)

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        jitted = drjax.build_plan(
            jax.make_jaxpr(jax.jit(jax.jit(f)))(*args), 3
        )
        assert stage_kinds(jitted) == ["BROADCAST", "GROUP_COMPUTE", "REDUCE"]
        assert_bitwise(jitted, f, args)

    def test_jit_with_gradient(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            xb = drjax.broadcast(x)
            z = drjax.map_fn(lambda a, b: (a - b) ** 2, (xb, ys))
            return drjax.reduce_mean(z)

        args = (jnp.float32(0.5), jnp.array([1.0, 2.0, 3.0]))
        gf = jax.grad(f)
        plan = drjax.build_plan(jax.make_jaxpr(jax.jit(gf))(*args), 3)
        ops = [s.op for s in plan.stages if isinstance(s, interp.Reduce)]
        assert "reduce_sum" in ops  # transpose of broadcast
        assert_bitwise(plan, gf, args)


# ---------------------------------------------------------------------------
# loops / conds with in-loop communication
# ---------------------------------------------------------------------------


class TestLoopStages:
    def _two_round_prog(self):
        @drjax.program(partition_size=3)
        def two_rounds(m, ys):
            def body(m, _):
                grads = drjax.map_fn(
                    lambda mm, y: mm - y, (drjax.broadcast(m), ys)
                )
                g = drjax.reduce_mean(grads)
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, None, length=2)
            return m, gs

        return two_rounds, (jnp.float32(0.3), jnp.array([1.0, 2.0, 3.0]))

    def test_scan_with_comm_becomes_loop_stage(self):
        prog, args = self._two_round_prog()
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        assert stage_kinds(plan) == ["LOOP"]
        loop = plan.stages[0]
        assert loop.loop_kind == "scan"
        assert loop.trip_count == 2
        assert stage_kinds(loop.body_plan) == [
            "BROADCAST",
            "GROUP_COMPUTE",
            "REDUCE",
            "SERVER_COMPUTE",
        ]

    def test_loop_stage_executes_bitwise(self):
        prog, args = self._two_round_prog()
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        assert_bitwise(plan, prog, args)

    def test_jitted_scan_same_plan(self):
        prog, args = self._two_round_prog()
        plain = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        jitted = drjax.build_plan(jax.make_jaxpr(jax.jit(prog))(*args), 3)
        assert stage_kinds(jitted) == stage_kinds(plain)
        assert stage_kinds(jitted.stages[0].body_plan) == stage_kinds(
            plain.stages[0].body_plan
        )
        assert_bitwise(jitted, prog, args)

    def test_in_loop_communication_is_explicit(self):
        prog, args = self._two_round_prog()
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        comm = plan.communication_stages(recursive=True)
        kinds = [s.kind for s in comm]
        assert "BROADCAST" in kinds and "REDUCE" in kinds
        # top-level has none: all communication lives inside the loop
        assert plan.communication_stages(recursive=False) == []
        txt = plan.to_text()
        assert "LOOP[scan] trip_count=2" in txt
        assert "BROADCAST server->groups" in txt

    def test_scan_without_comm_stays_local(self):
        """A purely local client loop must NOT become a LoopStage."""

        @drjax.program(partition_size=3)
        def f(x, ys):
            def client(y):
                def step(c, _):
                    return c * 0.5 + y, c

                out, _ = jax.lax.scan(step, y, None, length=3)
                return out

            z = drjax.map_fn(client, ys)
            return drjax.reduce_sum(z)

        args = (jnp.float32(0.0), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 3)
        assert "LOOP" not in stage_kinds(plan)
        assert_bitwise(plan, f, args)

    def test_repeated_inline_of_cached_jaxpr(self):
        """jit caches one jaxpr per function; inlining it at two call sites
        must alpha-rename, not alias the second call's values over the
        first's."""
        summarize = jax.jit(lambda xs: drjax.reduce_mean(xs))

        @drjax.program(partition_size=3)
        def f(a, b):
            return (
                summarize(drjax.broadcast(a)),
                summarize(drjax.broadcast(b)),
            )

        args = (jnp.float32(1.0), jnp.float32(5.0))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 3)
        outs = drjax.run_plan(plan, *args)
        assert [float(o) for o in outs] == [1.0, 5.0]

    def test_while_cond_communication_is_explicit(self):
        """Communication inside the while predicate (adaptive stopping) must
        appear in the plan, not vanish into an opaque cond_jaxpr."""

        @drjax.program(partition_size=4)
        def adaptive(x, ys):
            def cond_fn(c):
                i, acc = c
                spread = drjax.reduce_max(
                    drjax.map_fn(
                        lambda a, b: a * b, (drjax.broadcast(acc), ys)
                    )
                )
                return (spread < 10.0) & (i < 10)

            def body_fn(c):
                i, acc = c
                g = drjax.reduce_mean(
                    drjax.map_fn(
                        lambda a, b: a + b, (drjax.broadcast(acc), ys)
                    )
                )
                return i + 1, acc + 0.5 * g

            i, acc = jax.lax.while_loop(cond_fn, body_fn, (0, x))
            return acc

        args = (jnp.float32(0.5), jnp.array([1.0, 2.0, 3.0, 4.0]))
        plan = drjax.build_plan(jax.make_jaxpr(adaptive)(*args), 4)
        (loop,) = [s for s in plan.stages if isinstance(s, interp.LoopStage)]
        assert loop.cond_plan is not None
        ops = [
            getattr(s, "op", "")
            for s in plan.communication_stages(recursive=True)
        ]
        assert "reduce_max" in ops  # the per-iteration predicate reduce
        assert "cond:" in plan.to_text()
        assert_bitwise(plan, adaptive, args)

    def test_while_with_comm(self):
        @drjax.program(partition_size=4)
        def prog(x, ys):
            def cond_fn(c):
                i, acc = c
                return i < 3

            def body_fn(c):
                i, acc = c
                contrib = drjax.reduce_sum(
                    drjax.map_fn(
                        lambda a, b: a * b, (drjax.broadcast(acc), ys)
                    )
                )
                return i + 1, acc + 0.1 * contrib

            i, acc = jax.lax.while_loop(cond_fn, body_fn, (0, x))
            return acc

        args = (jnp.float32(0.5), jnp.array([1.0, 2.0, 3.0, 4.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 4)
        loops = [s for s in plan.stages if isinstance(s, interp.LoopStage)]
        assert len(loops) == 1
        assert loops[0].loop_kind == "while"
        assert loops[0].trip_count is None
        assert_bitwise(plan, prog, args)

    def test_cond_with_comm(self):
        @drjax.program(partition_size=4)
        def prog(flag, x, ys):
            def comm(ops):
                x, ys = ops
                return drjax.reduce_sum(
                    drjax.map_fn(lambda a, b: a * b, (drjax.broadcast(x), ys))
                )

            def local(ops):
                x, ys = ops
                return x * 2.0

            return jax.lax.cond(flag, comm, local, (x, ys))

        ys = jnp.array([1.0, 2.0, 3.0, 4.0])
        plan = drjax.build_plan(
            jax.make_jaxpr(prog)(True, jnp.float32(2.0), ys), 4
        )
        conds = [s for s in plan.stages if isinstance(s, interp.CondStage)]
        assert len(conds) == 1
        assert len(conds[0].branch_plans) == 2
        for flag in (True, False):
            assert_bitwise(plan, prog, (flag, jnp.float32(2.0), ys))


# ---------------------------------------------------------------------------
# plans of the shipped algorithms (under jit)
# ---------------------------------------------------------------------------


class TestShippedAlgorithmPlans:
    def _round(self):
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, cfg)
        return round_fn, params, server.init(params), data

    def test_local_sgd_round_under_jit(self):
        round_fn, params, sstate, data = self._round()
        jxp = jax.make_jaxpr(jax.jit(round_fn))(params, sstate, data)
        plan = drjax.build_plan(jxp, 4)
        kinds = stage_kinds(plan)
        # broadcast params -> client compute -> reduce deltas+loss -> server
        assert kinds[0] == "BROADCAST"
        assert "GROUP_COMPUTE" in kinds
        assert "REDUCE" in kinds
        assert kinds[-1] == "SERVER_COMPUTE"
        assert kinds.index("BROADCAST") < kinds.index("GROUP_COMPUTE")
        assert kinds.index("GROUP_COMPUTE") < kinds.index("REDUCE")
        assert_bitwise(plan, round_fn, (params, sstate, data))

    def test_async_round_under_jit(self):
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        pending = init_pending(params)
        sstate = server.init(params)
        jxp = jax.make_jaxpr(jax.jit(round_fn))(params, pending, sstate, data)
        plan = drjax.build_plan(jxp, 4)
        kinds = stage_kinds(plan)
        # server applies the stale delta BEFORE broadcasting
        assert kinds[0] == "SERVER_COMPUTE"
        assert "BROADCAST" in kinds and "REDUCE" in kinds
        assert_bitwise(plan, round_fn, (params, pending, sstate, data))

    def test_multi_round_trainer_has_loop_stage(self):
        round_fn, params, sstate, data = self._round()
        num_rounds = 3
        trainer = make_multi_round(round_fn, num_rounds)
        all_data = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * num_rounds), data
        )
        jxp = jax.make_jaxpr(jax.jit(trainer))(params, sstate, all_data)
        plan = drjax.build_plan(jxp, 4)
        loops = [s for s in plan.stages if isinstance(s, interp.LoopStage)]
        assert len(loops) == 1
        assert loops[0].trip_count == num_rounds
        body_kinds = stage_kinds(loops[0].body_plan)
        assert "BROADCAST" in body_kinds and "REDUCE" in body_kinds
        assert_bitwise(plan, trainer, (params, sstate, all_data))


# ---------------------------------------------------------------------------
# stage_fns (jaxpr slicing) + beam emitter
# ---------------------------------------------------------------------------


class TestStageFns:
    def test_group_stage_fn_is_callable(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            xb = drjax.broadcast(x)
            z = drjax.map_fn(lambda a, b: a * b + 1.0, (xb, ys))
            return drjax.reduce_sum(z)

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 3)
        fns = plan.stage_fns()
        # exactly one local stage: the vmapped group compute
        (name,) = fns
        fn = fns[name]
        assert len(fn.input_vars) == 2
        assert len(fn.output_vars) == 1
        xb = np.broadcast_to(np.float32(2.0), (3,))
        ys = np.asarray(args[1])
        # one input is the broadcast output, the other the partitioned plan
        # input; distinguish them by membership in the plan invars
        ins = []
        for v in fn.input_vars:
            if v in plan.jaxpr.jaxpr.invars:
                ins.append(ys)
            else:
                ins.append(xb)
        (out,) = fn(*ins)
        np.testing.assert_allclose(out, xb * ys + 1.0)

    def test_stage_fns_cover_loop_bodies(self):
        @drjax.program(partition_size=3)
        def prog(m, ys):
            def body(m, _):
                g = drjax.reduce_mean(
                    drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys))
                )
                return m - g, None

            m, _ = jax.lax.scan(body, m, None, length=2)
            return m

        args = (jnp.float32(0.0), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        fns = plan.stage_fns()
        # loop body local stages are named stage_0_<i>
        assert any(k.startswith("stage_0_") for k in fns)


class TestBeamEmitter:
    def _maml_plan(self):
        def loss(x, y):
            return (x - y) ** 2

        def maml_loss(model, lr, task):
            g = jax.grad(loss)(model, task)
            return loss(model - lr * g, task)

        @drjax.program(partition_size=3)
        def f(model, lr, tasks):
            model_b = drjax.broadcast(model)
            lr_b = drjax.broadcast(lr)
            losses = drjax.map_fn(maml_loss, (model_b, lr_b, tasks))
            return drjax.reduce_mean(losses)

        args = (jnp.float32(0.1), jnp.float32(0.05), jnp.array([1.0, 2.0, 3.0]))
        return drjax.build_plan(jax.make_jaxpr(f)(*args), 3)

    def test_no_undefined_names_flat(self):
        assert_no_undefined_names(self._maml_plan().to_beam())

    def test_no_undefined_names_loop(self):
        @drjax.program(partition_size=3)
        def prog(m, ys):
            def body(m, _):
                g = drjax.reduce_mean(
                    drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys))
                )
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, None, length=2)
            return m, gs

        args = (jnp.float32(0.3), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        beam_text = plan.to_beam()
        assert_no_undefined_names(beam_text)
        assert "for i0 in range(2):" in beam_text

    def test_no_undefined_names_shipped_round(self):
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"] + params["b"]
            return jnp.mean((pred - batch["y"]) ** 2)

        loss_fn_, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn = make_local_sgd_round(
            loss_fn_, optim.sgd(0.05), server, cfg
        )
        sstate = server.init(params)
        jxp = jax.make_jaxpr(jax.jit(round_fn))(params, sstate, data)
        plan = drjax.build_plan(jxp, 4)
        assert_no_undefined_names(plan.to_beam())

    def test_stage_fn_names_match_beam_references(self):
        plan = self._maml_plan()
        beam_text = plan.to_beam()
        fns = plan.stage_fns()
        for ref in re.findall(r"fns\['([^']+)'\]", beam_text):
            assert ref in fns, f"beam references unknown stage fn {ref!r}"

    def test_beam_consts_contract(self):
        plan = self._maml_plan()
        beam_text = plan.to_beam()
        n_refs = len(set(re.findall(r"consts\[(\d+)\]", beam_text)))
        assert n_refs <= len(plan.beam_consts())

    def test_beam_consts_dedup_matches_emitter_index(self):
        """A const captured by a helper inlined in two plans must be listed
        once (the emitter's index table dedups; beam_consts must agree)."""
        const = jnp.array([1.0, 2.0, 3.0])
        helper = jax.jit(lambda xs: drjax.reduce_sum(xs * const))

        @drjax.program(partition_size=3)
        def g(a, all_b):
            top = helper(drjax.broadcast(a))

            def body(m, b):
                return m + helper(drjax.broadcast(b)), None

            m, _ = jax.lax.scan(body, top, all_b)
            return m

        args = (jnp.float32(1.0), jnp.arange(2, dtype=jnp.float32))
        plan = drjax.build_plan(jax.make_jaxpr(g)(*args), 3)
        beam_text = plan.to_beam()
        refs = {int(i) for i in re.findall(r"consts\[(\d+)\]", beam_text)}
        consts = plan.beam_consts()
        assert all(r < len(consts) for r in refs)
        # the shared const appears exactly once
        assert len(consts) == 1
        assert_bitwise(plan, g, args)

    def test_loop_xs_and_ys_emission(self):
        """Scan xs/ys plumbing: slice lambdas bind the iteration index as a
        default arg (not late-bound), partitioned xs slices are re-keyed per
        group, and consumed stacked ys become a real stacked PCollection."""

        @drjax.program(partition_size=3)
        def prog(m, all_data):
            def body(m, data):
                g = drjax.reduce_mean(
                    drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), data))
                )
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, all_data)
            return m + jnp.sum(gs), gs

        args = (jnp.float32(0.3), jnp.arange(6, dtype=jnp.float32).reshape(2, 3))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        beam_text = plan.to_beam()
        assert_no_undefined_names(beam_text)
        # iteration index captured via default arg, not the loop variable
        assert "_i=i0" in beam_text
        # the (T=2, n=3) xs input is @SERVER; its per-round slice is
        # partitioned, so it must be re-keyed into a per-group PCollection
        assert "beam.FlatMap(lambda v: list(enumerate(v)))" in beam_text
        # consumed ys are stacked into one value, not left as a raw list
        assert "beam.Flatten()" in beam_text
        assert "np.stack([v for _, v in sorted(rows)])" in beam_text
        # executor still agrees with direct execution (op-by-op vs fused
        # scan body can differ in the last ulp, hence allclose not bitwise)
        outs = drjax.run_plan(plan, *args)
        for a, b in zip(outs, prog(*args)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6
            )

    def test_partitioned_ys_consumed_downstream(self):
        """A scan body emitting a partitioned per-iteration output: the
        (T, n, ...) stack is server-placed (time axis leads), so downstream
        consumption is SERVER_COMPUTE, and the Beam emitter collects the
        groups into a stacked value rather than leaking raw PCollections."""

        @drjax.program(partition_size=3)
        def prog(m, ys):
            def body(m, _):
                z = drjax.map_fn(
                    lambda a, b: a * b, (drjax.broadcast(m), ys)
                )
                g = drjax.reduce_mean(z)
                return m - 0.1 * g, z

            m, zs = jax.lax.scan(body, m, None, length=2)
            return m, jnp.sum(zs)

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        assert stage_kinds(plan) == ["LOOP", "SERVER_COMPUTE"]
        outs = drjax.run_plan(plan, *args)
        for a, b in zip(outs, prog(*args)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        beam_text = plan.to_beam()
        assert_no_undefined_names(beam_text)
        # group ys are collected to a stacked server value inside the loop
        assert "collect groups to a stacked server value" in beam_text

    def test_reduce_of_broadcast_emits_replica_combine(self):
        """reduce(broadcast(x)) must combine n replicas of the server value,
        not call list() on a side-input object."""

        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(drjax.broadcast(x))

        plan = drjax.build_plan(jax.make_jaxpr(f)(jnp.float32(2.0)), 3)
        beam_text = plan.to_beam()
        assert_no_undefined_names(beam_text)
        assert "_reduce_sum([v] * 3)" in beam_text
        assert "list(bc" not in beam_text
        (out,) = drjax.run_plan(plan, jnp.float32(2.0))
        np.testing.assert_allclose(out, 6.0)

    def test_reverse_scan_emits_reversed_iteration(self):
        @drjax.program(partition_size=3)
        def prog(m, ys):
            def body(m, _):
                g = drjax.reduce_mean(
                    drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys))
                )
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, None, length=2, reverse=True)
            return m, gs

        args = (jnp.float32(0.3), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        beam_text = plan.to_beam()
        assert "reversed(range(2))" in beam_text
        assert_no_undefined_names(beam_text)
        assert_bitwise(plan, prog, args)

    def test_unstageable_comm_fails_loudly(self):
        """Communication hidden in a higher-order primitive the builder
        cannot stage (custom_linear_solve) must raise, not silently become
        a mislabeled LocalCompute stage."""

        @drjax.program(partition_size=3)
        def f(x, ys):
            def matvec(v):
                return v * 2.0

            def solve(mv, b):
                # a global reduce buried where the builder can't stage it
                return b / drjax.reduce_sum(drjax.broadcast(x))

            return jax.lax.custom_linear_solve(
                matvec, drjax.reduce_sum(ys), solve, solve
            )

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        with pytest.raises(AssertionError, match="not representable"):
            drjax.build_plan(jax.make_jaxpr(f)(*args), 3)

    def test_literal_src_exotic_dtypes(self):
        """bf16 literals must not emit np.bfloat16 (doesn't exist) or
        truncate the value to an int."""
        from repro.core.interpreter import _literal_src

        src = _literal_src(jnp.bfloat16(1.5))
        val = eval(src.split("#")[0], {"np": np})  # noqa: S307 - test-only
        assert float(val) == 1.5
        assert eval(_literal_src(jnp.float32(2.5)), {"np": np}) == np.float32(2.5)
        assert eval(_literal_src(np.int32(7)), {"np": np}) == 7
        assert eval(_literal_src(np.bool_(True)), {"np": np}) is True
