"""Tests for the compiled plan executor (PR-5).

Acceptance bar: ``plan.compile(...)(*args)`` is BITWISE-equal to
``run_plan`` on CPU for every control-flow program class; executables are
cached by (fingerprint, mesh, avals) so hot loops trigger exactly one trace
across N rounds — and across an elastic pod-count shrink the per-client leg
never recompiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import optim
from repro.algorithms.async_rounds import make_async_local_sgd_round
from repro.algorithms.rounds import (
    LocalSGDConfig,
    make_hierarchical_local_sgd_round,
    make_local_sgd_round,
    make_multi_round,
)
from repro.core import interpreter as interp
from repro.runtime import executor as executor_lib
from repro.runtime.elastic import make_elastic_hierarchical_round
from repro.runtime.executor import TraceCounter, compile_plan, fuse_stages


def assert_compiled_bitwise(plan, args, **compile_kwargs):
    compiled = plan.compile(**compile_kwargs)
    outs = compiled(*args)
    ref = drjax.run_plan(plan, *args)
    assert len(outs) == len(ref)
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    return compiled


def quadratic_setup(n=4, steps=2, dim=3):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (dim,)),
        "b": jnp.float32(0.0),
    }
    data = {
        "x": jax.random.normal(jax.random.PRNGKey(1), (n, steps, 8, dim)),
        "y": jax.random.normal(jax.random.PRNGKey(2), (n, steps, 8)),
    }
    return loss_fn, params, data


# ---------------------------------------------------------------------------
# bitwise parity with run_plan (the §5 oracle), per control-flow class
# ---------------------------------------------------------------------------


class TestCompiledBitwise:
    def test_flat_broadcast_reduce(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            xb = drjax.broadcast(x)
            z = drjax.map_fn(lambda a, b: a * b + 1.0, (xb, ys))
            return drjax.reduce_mean(z)

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 3)
        assert_compiled_bitwise(plan, args)

    def test_gradient_program(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            xb = drjax.broadcast(x)
            z = drjax.map_fn(lambda a, b: (a - b) ** 2, (xb, ys))
            return drjax.reduce_mean(z)

        args = (jnp.float32(0.5), jnp.array([1.0, 2.0, 3.0]))
        gf = jax.grad(f)
        plan = drjax.build_plan(jax.make_jaxpr(jax.jit(gf))(*args), 3)
        assert_compiled_bitwise(plan, args)

    @pytest.mark.parametrize("loops", ["native", "unroll", "auto"])
    def test_scan_loop_stage(self, loops):
        @drjax.program(partition_size=3)
        def prog(m, ys):
            def body(m, _):
                g = drjax.reduce_mean(
                    drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys))
                )
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, None, length=2)
            return m, gs

        args = (jnp.float32(0.3), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        assert_compiled_bitwise(plan, args, loops=loops)

    def test_scan_with_xs_and_consumed_ys(self):
        @drjax.program(partition_size=3)
        def prog(m, all_data):
            def body(m, data):
                g = drjax.reduce_mean(
                    drjax.map_fn(
                        lambda a, b: a - b, (drjax.broadcast(m), data)
                    )
                )
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, all_data)
            return m + jnp.sum(gs), gs

        args = (
            jnp.float32(0.3),
            jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        )
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        # This is the interpreter oracle's documented last-ulp case (see
        # test_interpreter_controlflow.test_loop_xs_and_ys_emission): XLA's
        # fusion of the post-scan consumption reassociates one add chain, so
        # op-by-op and fused execution differ in the final ulp. The same
        # 1-ulp bar applies to the compiled executor; every program the
        # oracle holds bitwise stays bitwise here too (tests above/below).
        compiled = plan.compile()
        ref = drjax.run_plan(plan, *args)
        for a, b in zip(compiled(*args), ref):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-7
            )

    def test_reverse_scan(self):
        @drjax.program(partition_size=3)
        def prog(m, ys):
            def body(m, _):
                g = drjax.reduce_mean(
                    drjax.map_fn(lambda a, b: a - b, (drjax.broadcast(m), ys))
                )
                return m - 0.5 * g, g

            m, gs = jax.lax.scan(body, m, None, length=2, reverse=True)
            return m, gs

        args = (jnp.float32(0.3), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 3)
        assert_compiled_bitwise(plan, args)

    def test_while_with_comm(self):
        @drjax.program(partition_size=4)
        def prog(x, ys):
            def cond_fn(c):
                i, acc = c
                return i < 3

            def body_fn(c):
                i, acc = c
                contrib = drjax.reduce_sum(
                    drjax.map_fn(
                        lambda a, b: a * b, (drjax.broadcast(acc), ys)
                    )
                )
                return i + 1, acc + 0.1 * contrib

            i, acc = jax.lax.while_loop(cond_fn, body_fn, (0, x))
            return acc

        # same args as the controlflow oracle test (the bitwise bar is
        # defined over the oracle suite's programs)
        args = (jnp.float32(0.5), jnp.array([1.0, 2.0, 3.0, 4.0]))
        plan = drjax.build_plan(jax.make_jaxpr(prog)(*args), 4)
        assert_compiled_bitwise(plan, args)

    def test_while_with_comm_in_predicate(self):
        @drjax.program(partition_size=4)
        def adaptive(x, ys):
            def cond_fn(c):
                i, acc = c
                spread = drjax.reduce_max(
                    drjax.map_fn(
                        lambda a, b: a * b, (drjax.broadcast(acc), ys)
                    )
                )
                return (spread < 10.0) & (i < 10)

            def body_fn(c):
                i, acc = c
                g = drjax.reduce_mean(
                    drjax.map_fn(
                        lambda a, b: a + b, (drjax.broadcast(acc), ys)
                    )
                )
                return i + 1, acc + 0.5 * g

            i, acc = jax.lax.while_loop(cond_fn, body_fn, (0, x))
            return acc

        args = (jnp.float32(0.5), jnp.array([1.0, 2.0, 3.0, 4.0]))
        plan = drjax.build_plan(jax.make_jaxpr(adaptive)(*args), 4)
        assert_compiled_bitwise(plan, args)

    def test_cond_with_comm_both_branches(self):
        @drjax.program(partition_size=4)
        def prog(flag, x, ys):
            def comm(ops):
                x, ys = ops
                return drjax.reduce_sum(
                    drjax.map_fn(
                        lambda a, b: a * b, (drjax.broadcast(x), ys)
                    )
                )

            def local(ops):
                x, ys = ops
                return x * 2.0

            return jax.lax.cond(flag, comm, local, (x, ys))

        ys = jnp.array([1.0, 2.0, 3.0, 4.0])
        plan = drjax.build_plan(
            jax.make_jaxpr(prog)(True, jnp.float32(2.0), ys), 4
        )
        for flag in (True, False):
            assert_compiled_bitwise(
                plan, (jnp.asarray(flag), jnp.float32(2.0), ys)
            )

    def test_local_sgd_round(self):
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, cfg)
        sstate = server.init(params)
        plan = drjax.build_plan(
            jax.make_jaxpr(jax.jit(round_fn))(params, sstate, data), 4
        )
        flat = jax.tree_util.tree_leaves((params, sstate, data))
        assert_compiled_bitwise(plan, flat)

    def test_async_round(self):
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn, init_pending = make_async_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        pending = init_pending(params)
        sstate = server.init(params)
        plan = drjax.build_plan(
            jax.make_jaxpr(jax.jit(round_fn))(params, pending, sstate, data),
            4,
        )
        flat = jax.tree_util.tree_leaves((params, pending, sstate, data))
        assert_compiled_bitwise(plan, flat)

    def test_multi_round_trainer(self):
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, cfg)
        sstate = server.init(params)
        trainer = make_multi_round(round_fn, 3)
        all_data = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * 3), data
        )
        plan = drjax.build_plan(
            jax.make_jaxpr(jax.jit(trainer))(params, sstate, all_data), 4
        )
        flat = jax.tree_util.tree_leaves((params, sstate, all_data))
        for loops in ("native", "unroll"):
            assert_compiled_bitwise(plan, flat, loops=loops)

    def test_hierarchical_two_level_reduce(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def pod_round(model, tasks):
            model_b = drjax.broadcast(model)
            grads = drjax.map_fn(
                lambda m, t: 2.0 * (m - t), (model_b, tasks)
            )
            pod_partials = drjax.reduce_mean(grads, placement="clients")
            return drjax.reduce_mean(pod_partials, placement="pods")

        tasks = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)
        args = (jnp.float32(0.5), tasks)
        plan = drjax.build_plan(
            jax.make_jaxpr(pod_round)(*args), {"pods": 2, "clients": 4}
        )
        assert_compiled_bitwise(plan, args)

    def test_repeated_inline_of_cached_jaxpr(self):
        summarize = jax.jit(lambda xs: drjax.reduce_mean(xs))

        @drjax.program(partition_size=3)
        def f(a, b):
            return (
                summarize(drjax.broadcast(a)),
                summarize(drjax.broadcast(b)),
            )

        args = (jnp.float32(1.0), jnp.float32(5.0))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 3)
        assert_compiled_bitwise(plan, args)


# ---------------------------------------------------------------------------
# executable cache + no-retrace invariants
# ---------------------------------------------------------------------------


class TestExecutableCache:
    def _plan_and_args(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            return drjax.reduce_sum(
                drjax.map_fn(lambda a, b: a * b, (drjax.broadcast(x), ys))
            )

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        return (
            lambda: drjax.build_plan(jax.make_jaxpr(f)(*args), 3),
            args,
        )

    def test_one_trace_across_rounds(self):
        build, args = self._plan_and_args()
        compiled = build().compile()
        for _ in range(10):
            compiled(*args)
        assert compiled.trace_count == 1

    def test_replan_hits_cache(self):
        """A structurally identical re-built plan shares the executable:
        same fingerprint, zero new traces."""
        build, args = self._plan_and_args()
        c1 = build().compile()
        c1(*args)
        c2 = build().compile()
        c2(*args)
        assert c2.fingerprint == c1.fingerprint
        assert c2.trace_count == 1  # the SAME entry, not a second trace

    def test_different_consts_different_fingerprint(self):
        """Captured const VALUES are part of the fingerprint — two programs
        differing only in a closed-over constant must not share."""

        def build(cval):
            const = jnp.array([cval, 2.0, 3.0])

            @drjax.program(partition_size=3)
            def f(x):
                return drjax.reduce_sum(drjax.broadcast(x) * const)

            return drjax.build_plan(jax.make_jaxpr(f)(jnp.float32(1.0)), 3)

        f1 = executor_lib.plan_fingerprint(build(1.0))
        f2 = executor_lib.plan_fingerprint(build(7.0))
        assert f1 != f2

    def test_new_shapes_are_a_new_entry(self):
        @drjax.program(partition_size=3)
        def f(x, ys):
            return drjax.reduce_sum(
                drjax.map_fn(lambda a, b: a * b, (drjax.broadcast(x), ys))
            )

        a1 = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        a2 = (
            jnp.float32(2.0),
            jnp.stack([jnp.array([1.0, 2.0, 3.0])] * 2, axis=1),
        )
        plan = drjax.build_plan(jax.make_jaxpr(f)(*a1), 3)
        compiled = plan.compile()
        compiled(*a1)
        # second aval set: separate cache entry, each traced exactly once
        plan2 = drjax.build_plan(jax.make_jaxpr(f)(*a2), 3)
        c2 = plan2.compile()
        c2(*a2)
        assert compiled.trace_count == 1
        assert c2.trace_count == 1

    def test_donation_frees_carried_args(self):
        build, args = self._plan_and_args()
        compiled = build().compile(donate_argnums=(0,))
        x = jnp.float32(5.0)
        compiled(x, args[1])
        assert x.is_deleted()

    def test_multi_round_trainer_one_trace(self):
        """make_multi_round(jit=True): N rounds + repeated meta-calls are
        exactly ONE trace; carries donated into the executable."""
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        round_fn = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, cfg)
        num_rounds = 3
        counter = TraceCounter()
        trainer = make_multi_round(
            counter.wrap(round_fn), num_rounds, jit=True
        )
        all_data = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * num_rounds), data
        )
        params_i, sstate_i = params, server.init(params)
        for _ in range(4):  # 4 meta-calls x 3 rounds each
            params_i, sstate_i, _ = trainer(params_i, sstate_i, all_data)
        assert counter.count == 1  # one trace total, not one per round/call
        # donated carry: the pre-call buffers are gone
        assert all(
            l.is_deleted()
            for l in jax.tree_util.tree_leaves(params)
        )

    def test_donated_round_builder(self):
        loss_fn, params, data = quadratic_setup()
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(partition_size=4, num_local_steps=2)
        ref_round = make_local_sgd_round(loss_fn, optim.sgd(0.05), server, cfg)
        hot_round = make_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, cfg, donate=True
        )
        sstate = server.init(params)
        ref = ref_round(params, sstate, data)
        out = hot_round(params, sstate, data)
        for a, b in zip(
            jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(out)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the donated form consumed its inputs
        assert all(
            l.is_deleted() for l in jax.tree_util.tree_leaves(params)
        )


# ---------------------------------------------------------------------------
# stage fusion
# ---------------------------------------------------------------------------


class TestStageFusion:
    def test_adjacent_local_stages_fuse(self):
        """Interleaved server/group compute: run_plan sees alternating
        GROUP/SERVER stages, the executor one fused unit per local run."""

        @drjax.program(partition_size=3)
        def f(x, ys):
            xb = drjax.broadcast(x)
            z = drjax.map_fn(lambda a, b: a * b, (xb, ys))  # group
            s = x * 3.0  # server, adjacent to group compute
            z2 = drjax.map_fn(lambda a: a + 1.0, z)  # group again
            return drjax.reduce_sum(z2) + s

        args = (jnp.float32(2.0), jnp.array([1.0, 2.0, 3.0]))
        plan = drjax.build_plan(jax.make_jaxpr(f)(*args), 3)
        kinds = [s.kind for s in plan.stages]
        locals_ = [k for k in kinds if k in ("GROUP_COMPUTE", "SERVER_COMPUTE")]
        fused = fuse_stages(plan.stages)
        fused_locals = [s for s in fused if s.kind == "FUSED_COMPUTE"]
        assert len(fused_locals) < len(locals_) or len(locals_) == 1
        assert len(fused) <= len(plan.stages)
        # and fusion does not change results
        assert_compiled_bitwise(plan, args)

    def test_compiled_plan_reports_stage_units(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(drjax.broadcast(x) * 2.0)

        plan = drjax.build_plan(jax.make_jaxpr(f)(jnp.float32(1.0)), 3)
        compiled = plan.compile()
        assert compiled.num_stage_units <= len(plan.stages)


# ---------------------------------------------------------------------------
# elastic per-placement-level split
# ---------------------------------------------------------------------------


class TestElasticSplit:
    def _setup(self, num_pods=4, clients_per_pod=2, steps=2):
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"] + params["b"]
            return jnp.mean((pred - batch["y"]) ** 2)

        params = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (3,)),
            "b": jnp.float32(0.0),
        }
        data = {
            "x": jax.random.normal(
                jax.random.PRNGKey(1), (num_pods, clients_per_pod, steps, 8, 3)
            ),
            "y": jax.random.normal(
                jax.random.PRNGKey(2), (num_pods, clients_per_pod, steps, 8)
            ),
        }
        server = optim.fedavg_momentum(1.0)
        cfg = LocalSGDConfig(
            partition_size=clients_per_pod,
            num_local_steps=steps,
            num_pods=num_pods,
        )
        return loss_fn, params, data, server, cfg

    def test_matches_hierarchical_round(self):
        loss_fn, params, data, server, cfg = self._setup()
        hier = make_hierarchical_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        elastic = make_elastic_hierarchical_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        sstate = server.init(params)
        ref = hier(params, sstate, data)
        out = elastic.step(params, sstate, data)
        for a, b in zip(
            jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(out)
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6
            )

    def test_pod_shrink_never_recompiles_client_leg(self):
        """Elastic pod dropout: the per-client executable is reused (ZERO
        new traces); only the cross-pod leg compiles for the new pod count."""
        loss_fn, params, data, server, cfg = self._setup(num_pods=4)
        elastic = make_elastic_hierarchical_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        sstate = server.init(params)
        elastic.step(params, sstate, data)
        assert elastic.client_trace_count == 1
        assert elastic.cross_compile_count == 1

        # a pod drops out: 4 -> 3
        data3 = jax.tree_util.tree_map(lambda x: x[:3], data)
        out3 = elastic.step(params, sstate, data3)
        assert elastic.client_trace_count == 1  # NEVER recompiled
        assert elastic.cross_compile_count == 2  # only the cross-pod leg

        # and the shrunken round is still the hierarchical round at P=3
        import dataclasses as _dc

        hier3 = make_hierarchical_local_sgd_round(
            loss_fn, optim.sgd(0.05), server, _dc.replace(cfg, num_pods=3)
        )
        ref3 = hier3(params, sstate, data3)
        for a, b in zip(
            jax.tree_util.tree_leaves(ref3), jax.tree_util.tree_leaves(out3)
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6
            )

    def test_pod_regrow_reuses_both_legs(self):
        loss_fn, params, data, server, cfg = self._setup(num_pods=4)
        elastic = make_elastic_hierarchical_round(
            loss_fn, optim.sgd(0.05), server, cfg
        )
        sstate = server.init(params)
        elastic.step(params, sstate, data)
        data3 = jax.tree_util.tree_map(lambda x: x[:3], data)
        elastic.step(params, sstate, data3)
        elastic.step(params, sstate, data)  # pod comes back
        assert elastic.client_trace_count == 1
        assert elastic.cross_compile_count == 2  # P=4 leg was cached


# ---------------------------------------------------------------------------
# serve scheduler: compiled prefill/decode (satellite)
# ---------------------------------------------------------------------------


class TestServeCompiled:
    def test_prefill_traces_once_per_shape(self):
        from repro.launch.serve import BatchScheduler, Request, chunk_schedule
        from repro.models import registry

        cfg = registry.get_config("stablelm_3b").reduced()
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        max_new = 3
        sched = BatchScheduler(cfg, params, batch=2, max_len=6 + max_new)

        def wave():
            reqs = [
                Request(
                    rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (6,)).astype(
                        np.int32
                    ),
                    max_new=max_new,
                )
                for i in range(2)
            ]
            return sched.run_wave(reqs)

        wave()
        # chunked prefill traces one executable per power-of-two bucket the
        # prompt decomposes into (6 -> [4, 2]), not one per prompt shape
        assert sched.prefill_traces == len(chunk_schedule(6, sched.chunk))
        wave()  # same prompt shape: no retrace
        assert sched.prefill_traces == len(chunk_schedule(6, sched.chunk))


# ---------------------------------------------------------------------------
# weak scaling: compiled executor vs jitted program under a real device mesh
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_compiled_plan_weak_scales_with_group_count(device_pool):
    """The compiled executor under a device mesh matches the jitted-program
    baseline bitwise at every group count of the weak-scaling sweep (groups
    per device held constant as REPRO_HOST_DEVICES grows), traces once per
    shape, and keeps the partitioned intermediates sharded (per-device temp
    bytes stay flat as groups double — the Fig. 6 property the sharding
    constraints exist to deliver)."""
    import textwrap

    res = device_pool.run(textwrap.dedent(
        """
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro import compat
        from repro import core as drjax
        from repro.core import interpreter as interp
        from repro.launch.mesh import mesh_for_placements, placement_axes_for
        from repro.runtime.executor import compile_plan

        n_dev = jax.device_count()
        mesh = mesh_for_placements({"clients": n_dev})
        D = 100  # differs from every swept group count

        def build(groups, ann):
            spec = {"clients": groups}
            paxes = placement_axes_for(mesh, spec)

            @drjax.program(placements=spec, partition_axes=paxes, mesh=mesh,
                           use_sharding_annotations=ann)
            def f(x):
                y = drjax.broadcast(x)
                z = drjax.map_fn(lambda a: jnp.tanh(a @ a), y)
                return drjax.reduce_mean(z)

            return f, paxes

        x = jnp.eye(D, dtype=jnp.float32) * 0.5
        out = {"bitwise": [], "traces": [], "temps": {}}
        for groups in (n_dev, 2 * n_dev):
            f, paxes = build(groups, True)
            plan = interp.build_plan(interp.trace(f, x), f.drjax_context)
            compiled = compile_plan(plan, mesh=mesh, placement_axes=paxes)
            with compat.set_mesh(mesh):
                got = compiled(x)
                got = compiled(x)  # second call: no retrace
                ref = jax.jit(f)(x)  # no-donate: bitwise baseline reuses x
            out["traces"].append(compiled.trace_count)
            out["bitwise"].append(bool(
                np.array_equal(np.asarray(got[0]), np.asarray(ref))
            ))
        # Fig. 6 property at the largest count: with annotations the (2n, D,
        # D) partitioned temps live sharded 1/n per device; without, at
        # least one fully-replicated copy materializes.
        for name, ann in (("drjax", True), ("ns", False)):
            f, _ = build(2 * n_dev, ann)
            with compat.set_mesh(mesh):
                c = jax.jit(f).lower(x).compile()  # no-donate: measurement
            out["temps"][name] = int(c.memory_analysis().temp_size_in_bytes)
        print(json.dumps(out))
        """
    ))
    assert all(res["bitwise"]), res
    assert res["traces"] == [1, 1], res
    assert res["temps"]["drjax"] < res["temps"]["ns"], res
