"""Fused reduce+compress fast path (PR 4).

Covers the acceptance criteria of the fused hierarchical reduction:

* interpret-mode Pallas kernels vs their jnp oracles — bitwise;
* ``grad`` through ``hierarchical_reduce_mean(compress_fn=int8_roundtrip)``
  identical fused vs unfused (straight-through roundtrip semantics);
* plan IR: the fused program still stages as ``REDUCE@clients`` →
  ``REDUCE@pods`` and its communication stages match the unfused
  composition stage for stage;
* the flat-packing utility round-trips pytrees bitwise;
* cross-placement ``map_fn`` fusion is bitwise-identical to the nested form.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import compression
from repro.compression import int8_roundtrip
from repro.core import interpreter
from repro.kernels import ops, ref
from repro.kernels import reduce_compress as rc


# ---------------------------------------------------------------------------
# kernels vs oracles
# ---------------------------------------------------------------------------


class TestKernelsVsOracle:
    def test_reduce_compress_bitwise(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 10, 256))
        q, s = rc.reduce_compress(x, interpret=True)
        qr, sr = ref.reduce_compress_ref(x)
        assert q.dtype == jnp.int8 and s.shape == (10, 1)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))

    def test_reduce_compress_row_padding_bitwise(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 7, 128))
        q, s = rc.reduce_compress(x, row_block=4, interpret=True)
        qr, sr = ref.reduce_compress_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))

    def test_roundtrip_kernel_bitwise(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 256))
        back, q, s = rc.reduce_compress_roundtrip(x, interpret=True)
        br, qr, _ = ref.reduce_compress_roundtrip_ref(x)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(br))
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))

    def test_dequant_accumulate_bitwise(self):
        k = jax.random.PRNGKey(3)
        x = jax.random.normal(k, (4, 8, 9, 128))  # (P, G, R, C)
        q, s = jax.vmap(lambda p: rc.reduce_compress(p, interpret=True))(x)
        out = rc.dequant_accumulate(q, s, interpret=True)
        outr = ref.dequant_accumulate_ref(q, s)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(outr))

    def test_pair_equals_roundtrip_then_mean(self):
        """reduce_compress → dequant_accumulate (the backend's two-kernel
        execution) computes the same value as the straight-through roundtrip
        partials followed by the plain cross-pod mean."""
        x = jax.random.normal(jax.random.PRNGKey(4), (3, 5, 4, 256))
        q, s = jax.vmap(lambda p: rc.reduce_compress(p, interpret=True))(x)
        pair = rc.dequant_accumulate(q, s, interpret=True)
        backs = jax.vmap(
            lambda p: rc.reduce_compress_roundtrip(p, interpret=True)[0]
        )(x)
        np.testing.assert_allclose(
            np.asarray(pair), np.asarray(backs.mean(axis=0)), rtol=1e-6
        )


class TestOpsDispatch:
    def test_jnp_fast_path_matches_kernel(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 6, 10, 256))
        fast = ops.reduce_compress_roundtrip(x, axis=1, backend="jnp")
        kern = ops.reduce_compress_roundtrip(
            x, axis=1, backend="pallas", interpret=True
        )
        assert fast.shape == (4, 10, 256)
        np.testing.assert_allclose(
            np.asarray(fast), np.asarray(kern), atol=1e-5
        )

    def test_gemm_and_plain_mean_agree(self):
        # bf16 input takes the plain-mean branch; compare against the f32
        # gemm branch on the same values.
        x32 = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 4, 256))
        gemm = ops.reduce_compress_roundtrip(x32, axis=1, backend="jnp")
        plain = ops.reduce_compress_roundtrip(
            x32.astype(jnp.bfloat16), axis=1, backend="jnp"
        )
        np.testing.assert_allclose(
            np.asarray(gemm), np.asarray(plain, dtype=np.float32),
            atol=0.05,
        )

    def test_axis_zero_no_lead(self):
        x = jax.random.normal(jax.random.PRNGKey(7), (8, 5, 256))
        out = ops.reduce_compress_roundtrip(x, axis=0, backend="jnp")
        ref_back, _, _ = ref.reduce_compress_roundtrip_ref(x)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref_back), atol=1e-5
        )


# ---------------------------------------------------------------------------
# flat packing
# ---------------------------------------------------------------------------


class TestFlatPack:
    def test_roundtrip_bitwise_mixed_dtypes(self):
        tree = {
            "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.linspace(-1, 1, 5, dtype=jnp.float32),
            "step": jnp.arange(3, dtype=jnp.int32),
            "h": jnp.ones((2, 2), jnp.bfloat16),
            "scalar": jnp.float32(3.5),
        }
        bufs, spec = compression.flat_pack(tree, lead_ndim=0)
        assert set(bufs) == {"float32", "int32", "bfloat16"}
        for buf in bufs.values():
            assert buf.shape[-1] == compression.PACK_COLS
        back = compression.flat_unpack(bufs, spec, lead_ndim=0)
        for k in tree:
            assert back[k].dtype == jnp.asarray(tree[k]).dtype
            np.testing.assert_array_equal(
                np.asarray(back[k], np.float32),
                np.asarray(tree[k], np.float32),
            )

    def test_lead_axes_preserved_and_reducible(self):
        tree = {"a": jnp.ones((2, 4, 3)), "b": jnp.zeros((2, 4, 5, 2))}
        bufs, spec = compression.flat_pack(tree, lead_ndim=2)
        (buf,) = bufs.values()
        assert buf.shape[:2] == (2, 4)
        # reduce both group axes away, then unpack at lead_ndim=0
        reduced = {k: v.mean(axis=(0, 1)) for k, v in bufs.items()}
        out = compression.flat_unpack(reduced, spec, lead_ndim=0)
        assert out["a"].shape == (3,) and out["b"].shape == (5, 2)

    def test_mismatched_lead_raises(self):
        with pytest.raises(ValueError, match="lead axes"):
            compression.flat_pack(
                {"a": jnp.ones((2, 3)), "b": jnp.ones((4, 3))}, lead_ndim=1
            )

    def test_scale_blocks_never_span_leaves(self):
        """Regression: a small-magnitude leaf packed next to a huge one must
        keep its own quantization scale — sharing the huge leaf's 256-block
        scale would dequantize the small leaf to exactly zero."""
        tree = {
            "big": jnp.full((10,), 1e4, jnp.float32),
            "small": jnp.full((10,), 1e-3, jnp.float32),
        }
        back = int8_roundtrip(tree)
        np.testing.assert_allclose(
            np.asarray(back["small"]), np.asarray(tree["small"]), rtol=0.01
        )
        np.testing.assert_allclose(
            np.asarray(back["big"]), np.asarray(tree["big"]), rtol=0.01
        )

    def test_fused_reduce_preserves_small_leaf(self):
        """Same property through the fused hierarchical path."""

        @drjax.program(partition_size=4)
        def f(tree):
            return drjax.hierarchical_reduce_mean(
                tree, num_supergroups=2, compress_fn=int8_roundtrip
            )

        tree = {
            "big": jnp.full((4, 10), 1e4, jnp.float32),
            "small": jnp.full((4, 10), 1e-3, jnp.float32),
        }
        out = f(tree)
        np.testing.assert_allclose(
            np.asarray(out["small"]), np.full(10, 1e-3), rtol=0.01
        )


# ---------------------------------------------------------------------------
# fused hierarchical reduction
# ---------------------------------------------------------------------------


def _programs(n, num_pods):
    @drjax.program(partition_size=n)
    def fused(xs):
        return drjax.hierarchical_reduce_mean(
            xs, num_supergroups=num_pods, compress_fn=int8_roundtrip
        )

    @drjax.program(partition_size=n)
    def unfused(xs):
        return drjax.hierarchical_reduce_mean(
            xs, num_supergroups=num_pods, compress_fn=int8_roundtrip,
            use_fused=False,
        )

    @drjax.program(partition_size=n)
    def plain(xs):
        return drjax.hierarchical_reduce_mean(xs, num_supergroups=num_pods)

    return fused, unfused, plain


class TestFusedHierarchical:
    def test_forward_close_to_true_mean(self):
        fused, unfused, _ = _programs(8, 2)
        xs = jax.random.normal(jax.random.PRNGKey(0), (8, 300))
        f, u = fused(xs), unfused(xs)
        scale = float(jnp.max(jnp.abs(xs)))
        assert float(jnp.max(jnp.abs(f - xs.mean(0)))) < 0.02 * scale
        # fused and unfused share the wire format; they differ only in scale
        # block boundaries (packed 256-cols vs per-leaf rows)
        assert float(jnp.max(jnp.abs(f - u))) < 0.02 * scale

    def test_grad_fused_equals_unfused(self):
        """Acceptance: grad through the fused program == unfused composition
        (and both == the uncompressed hierarchical mean — straight-through)."""
        fused, unfused, plain = _programs(8, 2)
        xs = jax.random.normal(jax.random.PRNGKey(1), (8, 70))
        gf = jax.grad(lambda x: fused(x).sum())(xs)
        gu = jax.grad(lambda x: unfused(x).sum())(xs)
        gp = jax.grad(lambda x: plain(x).sum())(xs)
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(gu))
        np.testing.assert_array_equal(np.asarray(gf), np.asarray(gp))

    def test_grad_under_jit(self):
        fused, unfused, _ = _programs(8, 4)
        xs = jax.random.normal(jax.random.PRNGKey(2), (8, 33))
        gf = jax.jit(jax.grad(lambda x: fused(x).sum()))(xs)
        gu = jax.jit(jax.grad(lambda x: unfused(x).sum()))(xs)
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gu), rtol=1e-6)

    def test_nested_stack_pytree(self):
        @drjax.program(placements={"pods": 2, "clients": 4})
        def nested(tree):
            return drjax.hierarchical_reduce_mean(
                tree, compress_fn=int8_roundtrip
            )

        @drjax.program(placements={"pods": 2, "clients": 4})
        def nested_ref(tree):
            return drjax.hierarchical_reduce_mean(
                tree, compress_fn=int8_roundtrip, use_fused=False
            )

        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        tree = {
            "w": jax.random.normal(k1, (2, 4, 40)),
            "b": jax.random.normal(k2, (2, 4)),
        }
        out, outr = nested(tree), nested_ref(tree)
        assert out["w"].shape == (40,) and out["b"].shape == ()
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(outr[k]), atol=0.05
            )
        g = jax.grad(lambda t: nested(t)["w"].sum())(tree)
        gr = jax.grad(lambda t: nested_ref(t)["w"].sum())(tree)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(g[k]), np.asarray(gr[k]))

    def test_non_float_leaf_falls_back(self):
        @drjax.program(partition_size=4)
        def f(tree):
            return drjax.hierarchical_reduce_mean(
                tree, num_supergroups=2, compress_fn=int8_roundtrip
            )

        tree = {"w": jnp.ones((4, 8)), "count": jnp.ones((4,), jnp.int32)}
        out = f(tree)  # must not raise; generic path handles the int leaf
        np.testing.assert_allclose(np.asarray(out["w"]), np.ones(8), atol=0.02)

    def test_use_fused_true_requires_recognized_compressor(self):
        @drjax.program(partition_size=4)
        def f(xs):
            return drjax.hierarchical_reduce_mean(
                xs, num_supergroups=2, compress_fn=lambda t: t, use_fused=True
            )

        with pytest.raises(ValueError, match="use_fused=True"):
            f(jnp.ones((4, 8)))

    def test_env_kill_switch(self, monkeypatch):
        monkeypatch.setenv("REPRO_NO_FUSED_REDUCE", "1")
        fused, _, _ = _programs(4, 2)
        xs = jax.random.normal(jax.random.PRNGKey(4), (4, 16))
        jxp = jax.make_jaxpr(fused)(xs)
        # generic path: no compress-tagged reduce eqn in the trace
        assert "compress" not in str(jxp)

    def test_fused_eqn_in_trace(self):
        fused, _, _ = _programs(4, 2)
        xs = jax.random.normal(jax.random.PRNGKey(5), (4, 16))
        assert "compress=int8" in str(jax.make_jaxpr(fused)(xs))

    def test_vmap_over_fused_program(self):
        """Outer-loop transforms survive the fused eqn (batch rule shifts
        the quantization axis with the appended batch dim)."""
        fused, unfused, _ = _programs(4, 2)
        xs = jax.random.normal(jax.random.PRNGKey(6), (3, 4, 32))
        vf = jax.vmap(fused)(xs)
        vu = jax.vmap(unfused)(xs)
        assert vf.shape == (3, 32)
        np.testing.assert_allclose(np.asarray(vf), np.asarray(vu), atol=0.05)


# ---------------------------------------------------------------------------
# plan IR (§5 interpreter)
# ---------------------------------------------------------------------------


def _comm_signature(plan):
    sig = []
    for s in plan.stages:
        if isinstance(s, interpreter.Reduce):
            sig.append(("REDUCE", s.op, s.placement, s.dest))
        elif isinstance(s, interpreter.Broadcast):
            sig.append(("BROADCAST", s.placement, s.source))
    return sig


class TestFusedPlanIR:
    def test_two_tagged_reduce_stages(self):
        """Acceptance: the fused program still stages REDUCE@clients →
        REDUCE@pods, and its communication structure is stage-for-stage
        identical to the unfused composition's."""
        fused, unfused, plain = _programs(8, 2)
        xs = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        plans = {
            name: drjax.build_plan(jax.make_jaxpr(p)(xs), 8)
            for name, p in [("fused", fused), ("unfused", unfused),
                            ("plain", plain)]
        }
        expected = [
            ("REDUCE", "reduce_mean", "clients", "pods"),
            ("REDUCE", "reduce_mean", "pods", "server"),
        ]
        assert _comm_signature(plans["fused"]) == expected
        assert (_comm_signature(plans["fused"])
                == _comm_signature(plans["unfused"])
                == _comm_signature(plans["plain"]))

    def test_fused_plan_kinds_match_uncompressed(self):
        """Modulo the quantization math riding inside existing stages, the
        fused plan has the same stage skeleton as the uncompressed program:
        no extra communication stages appear."""
        fused, _, plain = _programs(8, 4)
        xs = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
        kinds_fused = [
            s.kind for s in drjax.build_plan(jax.make_jaxpr(fused)(xs), 8).stages
            if s.kind in ("BROADCAST", "REDUCE", "LOOP", "COND")
        ]
        kinds_plain = [
            s.kind for s in drjax.build_plan(jax.make_jaxpr(plain)(xs), 8).stages
            if s.kind in ("BROADCAST", "REDUCE", "LOOP", "COND")
        ]
        assert kinds_fused == kinds_plain == ["REDUCE", "REDUCE"]

    def test_run_plan_matches_direct(self):
        fused, _, _ = _programs(8, 2)
        xs = jax.random.normal(jax.random.PRNGKey(2), (8, 48))
        plan = drjax.build_plan(jax.make_jaxpr(fused)(xs), 8)
        (out,) = drjax.run_plan(plan, xs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(fused(xs)))

    def test_nested_stack_plan(self):
        @drjax.program(placements={"pods": 2, "clients": 3})
        def prog(xs):
            return drjax.hierarchical_reduce_mean(
                xs, compress_fn=int8_roundtrip
            )

        xs = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 16))
        plan = drjax.build_plan(
            jax.make_jaxpr(prog)(xs), {"pods": 2, "clients": 3}
        )
        assert _comm_signature(plan) == [
            ("REDUCE", "reduce_mean", "clients", "pods"),
            ("REDUCE", "reduce_mean", "pods", "server"),
        ]
        (out,) = drjax.run_plan(plan, xs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(prog(xs)))

    def test_to_beam_emits(self):
        fused, _, _ = _programs(8, 2)
        xs = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
        plan = drjax.build_plan(jax.make_jaxpr(fused)(xs), 8)
        beam = plan.to_beam()
        assert "CombinePerKey" in beam or "REDUCE" in beam.upper()


# ---------------------------------------------------------------------------
# cross-placement map_fn fusion
# ---------------------------------------------------------------------------


class TestMapFnFusion:
    def _x(self, key=0, shape=(2, 3, 7)):
        return jax.random.normal(jax.random.PRNGKey(key), shape)

    def test_fused_bitwise_equals_nested(self):
        @drjax.program(placements={"pods": 2, "clients": 3})
        def prog(x, fuse):
            return drjax.map_fn(lambda v: jnp.sin(v) * 2.0 + v.sum(), x,
                                fuse=fuse)

        x = self._x()
        np.testing.assert_array_equal(
            np.asarray(prog(x, None)), np.asarray(prog(x, False))
        )

    def test_fused_tuple_args_bitwise(self):
        @drjax.program(placements={"pods": 2, "clients": 3})
        def prog(m, t, fuse):
            return drjax.map_fn(
                lambda mm, tt: (mm * tt, (mm - tt) ** 2), (m, t), fuse=fuse
            )

        m, t = self._x(1, (2, 3, 5)), self._x(2, (2, 3, 5))
        for a, b in zip(prog(m, t, None), prog(m, t, False)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fused_grad_bitwise(self):
        @drjax.program(placements={"pods": 2, "clients": 3})
        def prog(x, fuse):
            return drjax.map_fn(jnp.tanh, x, fuse=fuse)

        x = self._x(3)
        g = jax.grad(lambda v: prog(v, None).sum())(x)
        gn = jax.grad(lambda v: prog(v, False).sum())(x)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(gn))

    def test_single_vmap_in_fused_trace(self):
        """The fused default-span map collapses both group axes into ONE
        mapped axis: the traced fn sees rank-1 slices of a rank-3 operand."""
        seen = []

        def probe(v):
            seen.append(v.ndim)
            return v * 2

        @drjax.program(placements={"pods": 2, "clients": 3})
        def prog(x, fuse):
            return drjax.map_fn(probe, x, fuse=fuse)

        x = self._x(4)
        prog(x, None)
        assert seen and seen[-1] == 1  # one vmap: per-group slice directly

    def test_mixed_axis_annotations_fall_back(self):
        ctx = drjax.make_context(
            None,
            placements={"pods": 2, "clients": 3},
            partition_axes={"pods": None, "clients": "data"},
        )
        from repro.core.api import _fused_spmd_names

        ok, _ = _fused_spmd_names(ctx)
        assert not ok
        both = drjax.make_context(
            None,
            placements={"pods": 2, "clients": 3},
            partition_axes={"pods": "pod", "clients": "data"},
        )
        ok, names = _fused_spmd_names(both)
        assert ok and names == ("pod", "data")

    def test_flat_single_placement_unchanged(self):
        @drjax.program(partition_size=5)
        def prog(x, fuse):
            return drjax.map_fn(lambda v: v + 1, x, fuse=fuse)

        x = self._x(5, (5, 4))
        np.testing.assert_array_equal(
            np.asarray(prog(x, None)), np.asarray(prog(x, False))
        )

    def test_malformed_leaf_raises(self):
        @drjax.program(placements={"pods": 2, "clients": 3})
        def prog(x):
            return drjax.map_fn(lambda v: v, x)

        with pytest.raises(ValueError, match="group axes"):
            prog(jnp.ones((3, 2, 4)))  # axes transposed
