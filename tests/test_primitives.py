"""Unit tests for the DrJAX building-block primitives (paper §2/§3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import core as drjax


def _ctxd(n, **kw):
    return dict(partition_size=n, **kw)


class TestBroadcast:
    def test_scalar(self):
        @drjax.program(partition_size=4)
        def f(x):
            return drjax.broadcast(x)

        out = f(jnp.float32(2.5))
        np.testing.assert_array_equal(out, np.full((4,), 2.5, np.float32))

    def test_array(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.broadcast(x)

        x = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
        out = f(x)
        assert out.shape == (3, 2, 3)
        for i in range(3):
            np.testing.assert_array_equal(out[i], x)

    def test_pytree(self):
        @drjax.program(partition_size=2)
        def f(tree):
            return drjax.broadcast(tree)

        tree = {"w": jnp.ones((3,)), "b": jnp.zeros(())}
        out = f(tree)
        assert out["w"].shape == (2, 3)
        assert out["b"].shape == (2,)

    def test_jit(self):
        @drjax.program(partition_size=5)
        def f(x):
            return drjax.broadcast(x)

        np.testing.assert_array_equal(jax.jit(f)(jnp.float32(1.0)), np.ones(5))


class TestReduceSum:
    def test_basic(self):
        @drjax.program(partition_size=4)
        def f(x):
            return drjax.reduce_sum(x)

        x = jnp.arange(4, dtype=jnp.float32)
        assert f(x) == 6.0

    def test_matrix(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(x)

        x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
        np.testing.assert_allclose(f(x), x.sum(0))

    def test_wrong_partition_size_raises(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(x)

        with pytest.raises(ValueError, match="does not match"):
            jax.jit(f)(jnp.ones((4,)))

    def test_scalar_operand_raises(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(x)

        with pytest.raises(ValueError, match="scalar"):
            jax.jit(f)(jnp.float32(1.0))


class TestReduceMeanMax:
    def test_mean(self):
        @drjax.program(partition_size=4)
        def f(x):
            return drjax.reduce_mean(x)

        assert f(jnp.array([1.0, 2.0, 3.0, 6.0])) == 3.0

    def test_max(self):
        @drjax.program(partition_size=4)
        def f(x):
            return drjax.reduce_max(x)

        assert f(jnp.array([1.0, 7.0, 3.0, 6.0])) == 7.0

    def test_weighted_mean(self):
        @drjax.program(partition_size=3)
        def f(x, w):
            return drjax.reduce_weighted_mean(x, w)

        x = jnp.array([1.0, 2.0, 4.0])
        w = jnp.array([1.0, 1.0, 2.0])
        np.testing.assert_allclose(f(x, w), (1 + 2 + 8) / 4.0)

    def test_masked_mean_drops_stragglers(self):
        @drjax.program(partition_size=4)
        def f(x, mask):
            return drjax.masked_reduce_mean(x, mask)

        x = jnp.array([1.0, 2.0, 3.0, 100.0])
        mask = jnp.array([1.0, 1.0, 1.0, 0.0])  # group 3 missed the deadline
        np.testing.assert_allclose(f(x, mask), 2.0)


class TestMapFn:
    def test_single_arg(self):
        @drjax.program(partition_size=4)
        def f(x):
            return drjax.map_fn(lambda a: a * a, x)

        x = jnp.arange(4, dtype=jnp.float32)
        np.testing.assert_allclose(f(x), x * x)

    def test_tuple_args_paper_snippet4(self):
        @drjax.program(partition_size=3)
        def f(a, b):
            ab = drjax.broadcast(a)
            return drjax.map_fn(lambda u, v: u + v, (ab, b))

        out = f(jnp.float32(10.0), jnp.arange(3, dtype=jnp.float32))
        np.testing.assert_allclose(out, [10.0, 11.0, 12.0])

    def test_pytree_output(self):
        @drjax.program(partition_size=2)
        def f(x):
            return drjax.map_fn(lambda a: {"sq": a * a, "neg": -a}, x)

        out = f(jnp.array([2.0, 3.0]))
        np.testing.assert_allclose(out["sq"], [4.0, 9.0])
        np.testing.assert_allclose(out["neg"], [-2.0, -3.0])

    def test_composition_broadcast_map_reduce(self):
        # paper Snippet 2: should return 2 * n * x
        @drjax.program(partition_size=3)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: 2 * a, y)
            return drjax.reduce_sum(z)

        assert f(jnp.float32(1.0)) == 6.0
        assert jax.jit(f)(jnp.float32(2.0)) == 12.0


class TestTransforms:
    def test_vmap_over_program(self):
        @drjax.program(partition_size=3)
        def f(x):
            return drjax.reduce_sum(drjax.broadcast(x))

        out = jax.vmap(f)(jnp.arange(5, dtype=jnp.float32))
        np.testing.assert_allclose(out, 3 * np.arange(5))

    def test_vmap_over_partitioned_arg(self):
        @drjax.program(partition_size=3)
        def f(xs):
            return drjax.reduce_sum(xs)

        xs = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)  # batch of 4
        out = jax.vmap(f)(xs)
        np.testing.assert_allclose(out, xs.sum(-1))

    def test_nested_jit_grad(self):
        @drjax.program(partition_size=4)
        def f(x):
            y = drjax.broadcast(x)
            return drjax.reduce_mean(drjax.map_fn(lambda a: a**3, y))

        g = jax.jit(jax.grad(f))(jnp.float32(2.0))
        np.testing.assert_allclose(g, 3 * 2.0**2, rtol=1e-6)

    def test_no_context_raises(self):
        with pytest.raises(RuntimeError, match="placement context"):
            drjax.broadcast(jnp.float32(1.0))

    def test_batch_rules_handle_not_mapped(self):
        """Batching rules must pass batching.not_mapped through untouched
        (an unbatched operand inside a vmap must not get its dim shifted)."""
        from jax.interpreters import batching
        from repro.core import placement as placement_lib
        from repro.core import primitives as prims

        ctx = placement_lib.make_context(3, partition_axes=None)
        x = jnp.float32(2.0)
        out, d = prims._broadcast_batch(
            (x,), (batching.not_mapped,), pctx=ctx
        )
        assert d is batching.not_mapped
        np.testing.assert_array_equal(out, np.full((3,), 2.0, np.float32))

        xs = jnp.arange(3, dtype=jnp.float32)
        reducer = batching.primitive_batchers[prims.reduce_sum_p]
        out, d = reducer((xs,), (batching.not_mapped,), pctx=ctx)
        assert d is batching.not_mapped
        np.testing.assert_allclose(out, 3.0)

    def test_vmap_unbatched_broadcast_operand(self):
        """A broadcast whose operand does not carry the vmap axis composes
        with a batched reduction (mixed in_axes)."""

        @drjax.program(partition_size=3)
        def f(scale, xs):
            y = drjax.broadcast(scale)  # unbatched under the outer vmap
            return drjax.reduce_sum(drjax.map_fn(lambda a, b: a * b, (y, xs)))

        xs = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
        out = jax.vmap(f, in_axes=(None, 0))(jnp.float32(2.0), xs)
        np.testing.assert_allclose(out, 2.0 * xs.sum(-1))


class TestProperties:
    """Hypothesis property tests on algebraic invariants of the primitives."""

    @given(
        n=st.integers(1, 16),
        x=st.floats(-1e3, 1e3, allow_nan=False, width=32),
    )
    @settings(max_examples=25, deadline=None)
    def test_broadcast_then_mean_is_identity(self, n, x):
        @drjax.program(partition_size=n)
        def f(v):
            return drjax.reduce_mean(drjax.broadcast(v))

        np.testing.assert_allclose(f(jnp.float32(x)), x, rtol=1e-5, atol=1e-5)

    @given(
        n=st.integers(1, 16),
        x=st.floats(-100, 100, allow_nan=False, width=32),
    )
    @settings(max_examples=25, deadline=None)
    def test_broadcast_then_sum_scales_by_n(self, n, x):
        @drjax.program(partition_size=n)
        def f(v):
            return drjax.reduce_sum(drjax.broadcast(v))

        np.testing.assert_allclose(f(jnp.float32(x)), n * x, rtol=1e-4, atol=1e-4)

    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=16))
    @settings(max_examples=25, deadline=None)
    def test_reduce_sum_linear(self, xs):
        n = len(xs)
        x = jnp.array(xs, jnp.float32)

        @drjax.program(partition_size=n)
        def f(v):
            return drjax.reduce_sum(v)

        np.testing.assert_allclose(
            f(2.0 * x), 2.0 * f(x), rtol=1e-4, atol=1e-3
        )

    @given(st.lists(st.floats(-50, 50, allow_nan=False, width=32), min_size=1, max_size=16))
    @settings(max_examples=25, deadline=None)
    def test_map_reduce_equals_numpy(self, xs):
        n = len(xs)
        x = jnp.array(xs, jnp.float32)

        @drjax.program(partition_size=n)
        def f(v):
            return drjax.reduce_sum(drjax.map_fn(lambda a: a * a + 1.0, v))

        np.testing.assert_allclose(
            f(x), np.sum(np.float32(xs) ** 2 + 1.0), rtol=1e-4, atol=1e-3
        )


class TestPropertySmoke:
    """Deterministic slices of the algebraic invariants above — these run
    even when hypothesis is not installed."""

    @pytest.mark.parametrize("n,x", [(1, 3.5), (5, -41.0), (16, 987.25)])
    def test_broadcast_then_mean_is_identity(self, n, x):
        @drjax.program(partition_size=n)
        def f(v):
            return drjax.reduce_mean(drjax.broadcast(v))

        np.testing.assert_allclose(f(jnp.float32(x)), x, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n,x", [(2, 7.0), (16, -31.5)])
    def test_broadcast_then_sum_scales_by_n(self, n, x):
        @drjax.program(partition_size=n)
        def f(v):
            return drjax.reduce_sum(drjax.broadcast(v))

        np.testing.assert_allclose(f(jnp.float32(x)), n * x, rtol=1e-4, atol=1e-4)

    def test_reduce_sum_linear(self):
        xs = [1.0, -2.5, 17.0, 0.0, 93.5]
        x = jnp.array(xs, jnp.float32)

        @drjax.program(partition_size=len(xs))
        def f(v):
            return drjax.reduce_sum(v)

        np.testing.assert_allclose(
            f(2.0 * x), 2.0 * f(x), rtol=1e-4, atol=1e-3
        )

    def test_map_reduce_equals_numpy(self):
        xs = [0.5, -12.0, 33.25, 4.0]
        x = jnp.array(xs, jnp.float32)

        @drjax.program(partition_size=len(xs))
        def f(v):
            return drjax.reduce_sum(drjax.map_fn(lambda a: a * a + 1.0, v))

        np.testing.assert_allclose(
            f(x), np.sum(np.float32(xs) ** 2 + 1.0), rtol=1e-4, atol=1e-3
        )
