"""Checkpoint manager tests: atomicity, integrity, async, retention, resume."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                   "step": jnp.int32(7)},
    }


class TestRoundTrip:
    def test_save_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(3, tree, metadata={"lr": 0.1})
        restored, meta = mgr.restore(3, tree)
        assert meta == {"lr": 0.1}
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
        mgr.save(1, tree)
        restored, _ = mgr.restore(1, tree)
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["w"], np.float32),
            np.asarray(tree["w"], np.float32),
        )

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(1, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_restore_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(5, t2)
        step, restored, _ = mgr.restore_latest(t1)
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(t2["w"])
        )


class TestFaultModes:
    def test_integrity_check_detects_corruption(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(2, tree)
        # corrupt the arrays file
        d = os.path.join(str(tmp_path), "step_000000002")
        path = os.path.join(d, "arrays.npz")
        data = dict(np.load(path))
        data["leaf_00000"] = data["leaf_00000"] + 1.0
        np.savez(path, **data)
        with pytest.raises(IOError, match="corruption"):
            mgr.restore(2, tree)

    def test_restore_latest_skips_torn_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(1, tree)
        mgr.save(2, tree)
        # tear checkpoint 2 (remove its arrays)
        os.remove(os.path.join(str(tmp_path), "step_000000002", "arrays.npz"))
        step, _, _ = mgr.restore_latest(tree)
        assert step == 1

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_n=2)
        tree = _tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        steps = sorted(mgr._complete_steps())
        assert steps == [3, 4]

    def test_no_checkpoint_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(_tree()) is None
