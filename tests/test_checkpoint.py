"""Checkpoint manager tests: atomicity, integrity, async, retention, resume."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                   "step": jnp.int32(7)},
    }


class TestRoundTrip:
    def test_save_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(3, tree, metadata={"lr": 0.1})
        restored, meta = mgr.restore(3, tree)
        assert meta == {"lr": 0.1}
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
        mgr.save(1, tree)
        restored, _ = mgr.restore(1, tree)
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["w"], np.float32),
            np.asarray(tree["w"], np.float32),
        )

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(1, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_restore_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(5, t2)
        step, restored, _ = mgr.restore_latest(t1)
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(t2["w"])
        )


class TestFaultModes:
    def test_integrity_check_detects_corruption(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(2, tree)
        # corrupt the arrays file
        d = os.path.join(str(tmp_path), "step_000000002")
        path = os.path.join(d, "arrays.npz")
        data = dict(np.load(path))
        data["leaf_00000"] = data["leaf_00000"] + 1.0
        np.savez(path, **data)
        with pytest.raises(IOError, match="corruption"):
            mgr.restore(2, tree)

    def test_restore_latest_skips_torn_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(1, tree)
        mgr.save(2, tree)
        # tear checkpoint 2 (remove its arrays)
        os.remove(os.path.join(str(tmp_path), "step_000000002", "arrays.npz"))
        step, _, _ = mgr.restore_latest(tree)
        assert step == 1

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_n=2)
        tree = _tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        steps = sorted(mgr._complete_steps())
        assert steps == [3, 4]

    def test_no_checkpoint_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(_tree()) is None


class TestWriteErrorSurfacing:
    def test_async_write_error_carries_originating_step(
        self, tmp_path, monkeypatch
    ):
        import repro.checkpoint.manager as manager_mod

        mgr = CheckpointManager(str(tmp_path))

        def boom(*a, **k):
            raise OSError("disk full")

        monkeypatch.setattr(manager_mod.np, "savez", boom)
        mgr.save(7, _tree(), blocking=False)
        with pytest.raises(RuntimeError, match="step 7") as ei:
            mgr.wait()
        assert isinstance(ei.value.__cause__, OSError)

    def test_error_surfaces_on_next_save_too(self, tmp_path, monkeypatch):
        import repro.checkpoint.manager as manager_mod

        mgr = CheckpointManager(str(tmp_path))
        real_savez = manager_mod.np.savez
        calls = {"n": 0}

        def flaky(*a, **k):
            calls["n"] += 1
            if calls["n"] == 1:
                raise OSError("disk full")
            return real_savez(*a, **k)

        monkeypatch.setattr(manager_mod.np, "savez", flaky)
        mgr.save(3, _tree(), blocking=False)
        with pytest.raises(RuntimeError, match="step 3"):
            mgr.save(4, _tree(), blocking=False)


class TestChaosFaultInjection:
    def test_corrupt_fault_skipped_in_favor_of_previous_step(self, tmp_path):
        """A sha256-corrupted arrays.npz is a COMPLETE checkpoint (manifest
        present) that fails verification — restore_latest must skip it and
        fall back to the previous complete step."""
        mgr = CheckpointManager(str(tmp_path))
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(2, t2)
        mgr.inject_fault(2, "corrupt")
        assert sorted(mgr._complete_steps()) == [1, 2]  # 2 still "complete"
        step, restored, _ = mgr.restore_latest(t1)
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(t1["w"])
        )

    def test_torn_fault_hook_mid_training(self, tmp_path):
        """fault_hook='torn' simulates a crash between the array write and
        the manifest write: no manifest, stale LATEST pointer, and
        restore_latest falls back to the previous step."""
        mgr = CheckpointManager(
            str(tmp_path),
            fault_hook=lambda step: "torn" if step == 2 else None,
        )
        tree = _tree()
        mgr.save(1, tree)
        mgr.save(2, tree)
        with open(os.path.join(str(tmp_path), "LATEST")) as f:
            assert f.read() == "step_000000001"  # torn write never advanced it
        step, _, _ = mgr.restore_latest(tree)
        assert step == 1

    def test_corrupt_fault_hook_async(self, tmp_path):
        mgr = CheckpointManager(
            str(tmp_path),
            fault_hook=lambda step: "corrupt" if step == 5 else None,
        )
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(5, t2, blocking=False)
        step, _, _ = mgr.restore_latest(t1)
        assert step == 1

    def test_clean_resave_clears_fault(self, tmp_path):
        """Replay after a restore re-saves the faulted step; the clean write
        replaces the broken checkpoint."""
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(2, tree)
        mgr.inject_fault(2, "torn")
        assert mgr.restore_latest(tree) is None
        mgr.save(2, tree)
        step, _, _ = mgr.restore_latest(tree)
        assert step == 2

    def test_unknown_fault_kind_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _tree())
        with pytest.raises(ValueError, match="unknown checkpoint fault"):
            mgr.inject_fault(1, "gamma-ray")


def _npz_bytes(directory, step):
    return os.path.getsize(
        os.path.join(directory, f"step_{step:09d}", "arrays.npz")
    )


class TestMidWriteKills:
    """A writer killed at ANY byte offset must leave the previous committed
    step restorable — the crash-consistency contract (temp dir + fsync +
    atomic rename + LATEST-last)."""

    def test_kill_offset_sweep_deterministic(self, tmp_path):
        t1, t2 = _tree(1), _tree(2)
        probe = CheckpointManager(str(tmp_path / "probe"))
        probe.save(1, t1)
        npz = _npz_bytes(str(tmp_path / "probe"), 1)
        # manifest written / npz half-written / pre-rename /
        # post-rename-pre-LATEST, plus the stream boundaries
        offsets = [0, 1, npz // 2, npz, npz + 10, npz + 10_000_000,
                   "pre-rename", "pre-latest"]
        for i, off in enumerate(offsets):
            d = str(tmp_path / f"kill_{i}")
            mgr = CheckpointManager(d)
            mgr.save(1, t1)
            mgr.kill_writer_at_byte(off)
            mgr.save(2, t2)  # writer "dies" — no error may surface
            assert mgr.killed_writes.get(2), f"offset {off!r}: kill not recorded"
            assert mgr.latest_step() == 1, f"offset {off!r}"
            step, restored, _ = mgr.restore_latest(t1)
            assert step == 1, f"offset {off!r}: restored step {step}"
            np.testing.assert_array_equal(
                np.asarray(restored["w"]), np.asarray(t1["w"])
            )
            # recovery replay: the clean re-save commits and becomes latest
            mgr.save(2, t2)
            step, restored, _ = mgr.restore_latest(t1)
            assert step == 2
            np.testing.assert_array_equal(
                np.asarray(restored["w"]), np.asarray(t2["w"])
            )

    def test_async_kill_is_silent(self, tmp_path):
        """A killed async writer surfaces NO write error (a dead process
        reports nothing) but is recorded in killed_writes."""
        mgr = CheckpointManager(str(tmp_path))
        t = _tree()
        mgr.save(1, t)
        mgr.kill_writer_at_byte(64)
        mgr.save(2, t, blocking=False)
        mgr.wait()  # must not raise
        assert 2 in mgr.killed_writes
        assert mgr._write_error is None
        step, _, _ = mgr.restore_latest(t)
        assert step == 1

    def test_pre_latest_kill_leaves_uncommitted_dir_invisible(self, tmp_path):
        """Killed after the rename but before LATEST: the step dir is on
        disk and complete, but was never acknowledged — restore must not
        resume from it."""
        mgr = CheckpointManager(str(tmp_path))
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.kill_writer_at_byte("pre-latest")
        mgr.save(2, t2)
        assert sorted(mgr._complete_steps()) == [1, 2]  # dir exists...
        assert mgr.latest_step() == 1  # ...but is uncommitted
        step, _, _ = mgr.restore_latest(t1)
        assert step == 1

    def test_kill_via_fault_hook_spec(self, tmp_path):
        """fault_hook may return 'kill@<bytes>' specs — the chaos schedule's
        interface to mid-write kills."""
        mgr = CheckpointManager(
            str(tmp_path),
            fault_hook=lambda step: "kill@128" if step == 2 else None,
        )
        t = _tree()
        mgr.save(1, t)
        mgr.save(2, t)
        assert 2 in mgr.killed_writes
        step, _, _ = mgr.restore_latest(t)
        assert step == 1

    def test_kill_before_any_commit_restores_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.kill_writer_at_byte(0)
        mgr.save(1, _tree())
        assert mgr.restore_latest(_tree()) is None

    def test_malformed_kill_spec_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(ValueError, match="unknown checkpoint fault"):
            mgr.kill_writer_at_byte("kill@sometime")
        with pytest.raises(ValueError, match=">= 0"):
            mgr.kill_writer_at_byte(-1)

    def test_kill_offset_sweep_hypothesis(self):
        """Opt-in property variant: EVERY offset in [0, stream end + slack]
        must be survivable (runs only when hypothesis is installed)."""
        import tempfile

        from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

        t1, t2 = _tree(1), _tree(2)
        with tempfile.TemporaryDirectory() as probe_dir:
            probe = CheckpointManager(probe_dir)
            probe.save(1, t1)
            hi = _npz_bytes(probe_dir, 1) + 4096

        @given(st.integers(min_value=0, max_value=hi))
        @settings(max_examples=25, deadline=None)
        def check(offset):
            with tempfile.TemporaryDirectory() as d:
                mgr = CheckpointManager(d)
                mgr.save(1, t1)
                mgr.kill_writer_at_byte(offset)
                mgr.save(2, t2)
                assert 2 in mgr.killed_writes
                step, restored, _ = mgr.restore_latest(t1)
                assert step == 1
                np.testing.assert_array_equal(
                    np.asarray(restored["w"]), np.asarray(t1["w"])
                )

        if not HAVE_HYPOTHESIS:
            pytest.skip("hypothesis not installed")
        check()


class TestGCKeepsLastGood:
    def test_gc_never_deletes_newest_complete_under_faulted_tail(
        self, tmp_path
    ):
        """Regression: corrupt step dirs are 'complete' (manifest present)
        and used to count toward keep_last_n, so a run of faulted writes
        could evict the only restorable checkpoint."""
        mgr = CheckpointManager(
            str(tmp_path), keep_last_n=1,
            fault_hook=lambda step: "corrupt" if step > 1 else None,
        )
        t = _tree()
        mgr.save(1, t)
        mgr.save(2, t)  # corrupt — complete but unverifiable
        mgr.save(3, t)  # corrupt — with the old _gc this evicted step 1
        assert 1 in mgr._complete_steps()
        step, _, _ = mgr.restore_latest(t)
        assert step == 1

    def test_gc_still_prunes_old_clean_steps(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_n=2)
        t = _tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, t)
        assert sorted(mgr._complete_steps()) == [3, 4]

    def test_gc_keeps_latest_target_after_killed_writes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_n=1)
        t = _tree()
        mgr.save(1, t)
        for s in (2, 3):
            mgr.kill_writer_at_byte("pre-latest")
            mgr.save(s, t)  # dirs land but never commit
        # a follow-up clean save GCs; the committed step-1 must survive any
        # intermediate state where uncommitted dirs outnumber the budget
        step, _, _ = mgr.restore_latest(t)
        assert step == 1
