"""Checkpoint manager tests: atomicity, integrity, async, retention, resume."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 4)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                   "step": jnp.int32(7)},
    }


class TestRoundTrip:
    def test_save_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(3, tree, metadata={"lr": 0.1})
        restored, meta = mgr.restore(3, tree)
        assert meta == {"lr": 0.1}
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bf16_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
        mgr.save(1, tree)
        restored, _ = mgr.restore(1, tree)
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["w"], np.float32),
            np.asarray(tree["w"], np.float32),
        )

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(1, tree, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_restore_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(5, t2)
        step, restored, _ = mgr.restore_latest(t1)
        assert step == 5
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(t2["w"])
        )


class TestFaultModes:
    def test_integrity_check_detects_corruption(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(2, tree)
        # corrupt the arrays file
        d = os.path.join(str(tmp_path), "step_000000002")
        path = os.path.join(d, "arrays.npz")
        data = dict(np.load(path))
        data["leaf_00000"] = data["leaf_00000"] + 1.0
        np.savez(path, **data)
        with pytest.raises(IOError, match="corruption"):
            mgr.restore(2, tree)

    def test_restore_latest_skips_torn_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(1, tree)
        mgr.save(2, tree)
        # tear checkpoint 2 (remove its arrays)
        os.remove(os.path.join(str(tmp_path), "step_000000002", "arrays.npz"))
        step, _, _ = mgr.restore_latest(tree)
        assert step == 1

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last_n=2)
        tree = _tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        steps = sorted(mgr._complete_steps())
        assert steps == [3, 4]

    def test_no_checkpoint_returns_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(_tree()) is None


class TestWriteErrorSurfacing:
    def test_async_write_error_carries_originating_step(
        self, tmp_path, monkeypatch
    ):
        import repro.checkpoint.manager as manager_mod

        mgr = CheckpointManager(str(tmp_path))

        def boom(*a, **k):
            raise OSError("disk full")

        monkeypatch.setattr(manager_mod.np, "savez", boom)
        mgr.save(7, _tree(), blocking=False)
        with pytest.raises(RuntimeError, match="step 7") as ei:
            mgr.wait()
        assert isinstance(ei.value.__cause__, OSError)

    def test_error_surfaces_on_next_save_too(self, tmp_path, monkeypatch):
        import repro.checkpoint.manager as manager_mod

        mgr = CheckpointManager(str(tmp_path))
        real_savez = manager_mod.np.savez
        calls = {"n": 0}

        def flaky(*a, **k):
            calls["n"] += 1
            if calls["n"] == 1:
                raise OSError("disk full")
            return real_savez(*a, **k)

        monkeypatch.setattr(manager_mod.np, "savez", flaky)
        mgr.save(3, _tree(), blocking=False)
        with pytest.raises(RuntimeError, match="step 3"):
            mgr.save(4, _tree(), blocking=False)


class TestChaosFaultInjection:
    def test_corrupt_fault_skipped_in_favor_of_previous_step(self, tmp_path):
        """A sha256-corrupted arrays.npz is a COMPLETE checkpoint (manifest
        present) that fails verification — restore_latest must skip it and
        fall back to the previous complete step."""
        mgr = CheckpointManager(str(tmp_path))
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(2, t2)
        mgr.inject_fault(2, "corrupt")
        assert sorted(mgr._complete_steps()) == [1, 2]  # 2 still "complete"
        step, restored, _ = mgr.restore_latest(t1)
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(t1["w"])
        )

    def test_torn_fault_hook_mid_training(self, tmp_path):
        """fault_hook='torn' simulates a crash between the array write and
        the manifest write: no manifest, stale LATEST pointer, and
        restore_latest falls back to the previous step."""
        mgr = CheckpointManager(
            str(tmp_path),
            fault_hook=lambda step: "torn" if step == 2 else None,
        )
        tree = _tree()
        mgr.save(1, tree)
        mgr.save(2, tree)
        with open(os.path.join(str(tmp_path), "LATEST")) as f:
            assert f.read() == "step_000000001"  # torn write never advanced it
        step, _, _ = mgr.restore_latest(tree)
        assert step == 1

    def test_corrupt_fault_hook_async(self, tmp_path):
        mgr = CheckpointManager(
            str(tmp_path),
            fault_hook=lambda step: "corrupt" if step == 5 else None,
        )
        t1, t2 = _tree(1), _tree(2)
        mgr.save(1, t1)
        mgr.save(5, t2, blocking=False)
        step, _, _ = mgr.restore_latest(t1)
        assert step == 1

    def test_clean_resave_clears_fault(self, tmp_path):
        """Replay after a restore re-saves the faulted step; the clean write
        replaces the broken checkpoint."""
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(2, tree)
        mgr.inject_fault(2, "torn")
        assert mgr.restore_latest(tree) is None
        mgr.save(2, tree)
        step, _, _ = mgr.restore_latest(tree)
        assert step == 2

    def test_unknown_fault_kind_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _tree())
        with pytest.raises(ValueError, match="unknown checkpoint fault"):
            mgr.inject_fault(1, "gamma-ray")
