"""Launch-layer integration: step builders lower + compile on a small mesh.

Mini version of the production dry-run (8 fake devices, reduced configs),
covering every family's train/prefill/decode step builders end to end —
run inside the shared multi-device worker (see conftest.device_pool) so the
device count doesn't leak into this process and jax import + compile cache
are paid once per session.
"""

import textwrap

import pytest

_PRELUDE = """
    import json
    import jax, jax.numpy as jnp
    from repro import compat
    from repro.launch import steps as steps_lib, mesh as mesh_lib
    from repro.models import registry
    mesh = mesh_lib.make_mesh(
        (jax.device_count() // 2, 2), ("data", "model"))
"""


def _run(device_pool, body: str) -> dict:
    return device_pool.run(
        textwrap.dedent(_PRELUDE) + textwrap.dedent(body)
    )


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["stablelm_3b", "phi35_moe", "rwkv6_3b"])
def test_train_step_compiles_on_mesh(device_pool, arch):
    res = _run(device_pool, f"""
        cfg = registry.get_config("{arch}").reduced(
            d_model=64, num_heads=4, head_dim=16, vocab_size=512,
            dtype="bfloat16", attn_impl="blocked", q_block=8, kv_block=8)
        step, shardings_for = steps_lib.make_sgd_train_step(cfg, mesh)
        specs = steps_lib.train_input_specs(cfg, 8, 32, mesh)
        in_sh, out_sh = shardings_for(specs)
        compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=(0, 1)).lower(*specs).compile()
        print(json.dumps({{"ok": True,
                           "flops": compat.cost_analysis(compiled).get(
                               "flops", 0)}}))
    """)
    assert res["ok"] and res["flops"] > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["stablelm_3b", "recurrentgemma_2b"])
def test_decode_step_compiles_on_mesh(device_pool, arch):
    res = _run(device_pool, f"""
        cfg = registry.get_config("{arch}").reduced(
            d_model=64, num_heads=4, head_dim=16, vocab_size=512,
            dtype="bfloat16")
        step, shardings_for = steps_lib.make_decode_step(cfg, mesh)
        params, token, caches, memkv = steps_lib.decode_input_specs(cfg, 8, 64)
        shs = shardings_for((params, token, caches, memkv))
        compiled = jax.jit(step, in_shardings=shs[:3],
                           donate_argnums=(2,)).lower(
            params, token, caches).compile()
        print(json.dumps({{"ok": True}}))
    """)
    assert res["ok"]


@pytest.mark.slow
def test_drjax_round_step_compiles_on_mesh(device_pool):
    res = _run(device_pool, """
        cfg = registry.get_config("lm_350m").reduced(
            d_model=64, num_heads=4, head_dim=16, vocab_size=512,
            dtype="bfloat16", attn_impl="blocked", q_block=8, kv_block=8)
        step, param_sh, server_sh, data_sh_fn = steps_lib.make_drjax_round_step(
            cfg, mesh, partition_size=8, num_local_steps=2)
        specs = steps_lib.drjax_round_specs(
            cfg, partition_size=8, num_local_steps=2, local_batch=2, seq=32)
        data_sh = jax.tree_util.tree_map(data_sh_fn, specs[2])
        compiled = jax.jit(step, in_shardings=(param_sh, server_sh, data_sh),
                           donate_argnums=(0, 1)).lower(*specs).compile()
        hlo = compiled.as_text()
        print(json.dumps({"ok": True,
                          "has_allreduce": "all-reduce" in hlo}))
    """)
    assert res["ok"]
    assert res["has_allreduce"]  # the cross-group reduction shards


@pytest.mark.slow
def test_int8_prefill_variant_compiles(device_pool):
    res = _run(device_pool, """
        cfg = registry.get_config("qwen2_72b").reduced(
            d_model=64, num_heads=8, num_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=512, dtype="bfloat16",
            attn_impl="blocked", q_block=8, kv_block=8)
        step, shardings_for = steps_lib.make_prefill_step(
            cfg, mesh, tp_comm="int8")
        specs = steps_lib.prefill_input_specs(cfg, 8, 32)
        compiled = jax.jit(step, in_shardings=shardings_for(specs)).lower(
            *specs).compile()
        hlo = compiled.as_text()
        n_s8 = sum(1 for l in hlo.splitlines()
                   if "all-gather" in l and "s8[" in l)
        print(json.dumps({"ok": True, "s8": n_s8}))
    """)
    assert res["ok"]
    assert res["s8"] >= 1
