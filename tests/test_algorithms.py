"""Algorithm-layer tests: local SGD, FedSGD, DiLoCo, MAML, BTM, compression."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as drjax
from repro import optim
from repro.algorithms.btm import branch_train_merge
from repro.algorithms.maml import make_parallel_maml
from repro.algorithms.rounds import (
    LocalSGDConfig,
    make_fedsgd_round,
    make_local_sgd_round,
)
from repro.compression import ErrorFeedback, int8_roundtrip, topk_sparsify
from repro.data.grouped import GroupedCorpus, CohortSampler
from repro.models import registry


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = registry.get_config("lm_350m").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    loss_fn = functools.partial(registry.loss_fn, cfg)
    return cfg, params, loss_fn


def _round_data(cfg, n, steps, b, s, round_idx=0):
    corpus = GroupedCorpus(vocab_size=cfg.vocab_size, num_groups=64)
    sampler = CohortSampler(corpus, cohort_size=n)
    d = sampler.round_batch(round_idx, steps, b, s)
    return {"tokens": d["tokens"], "labels": d["labels"]}


class TestLocalSGD:
    def test_loss_decreases_over_rounds(self, tiny_lm):
        cfg, params, loss_fn = tiny_lm
        n, steps = 4, 2
        fn = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0),
            LocalSGDConfig(partition_size=n, num_local_steps=steps),
        ))
        sstate = optim.fedavg_momentum(1.0).init(params)
        losses = []
        for r in range(6):
            data = _round_data(cfg, n, steps, 2, 16, r)
            params, sstate, m = fn(params, sstate, data)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_single_group_equals_sequential_sgd(self, tiny_lm):
        """n=1 local SGD must equal plain SGD on the same batches (exactness
        of the MapReduce formulation)."""
        cfg, params, loss_fn = tiny_lm
        steps = 3
        data = _round_data(cfg, 1, steps, 2, 16)
        fn = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.1), optim.fedavg_momentum(1.0),
            LocalSGDConfig(partition_size=1, num_local_steps=steps),
        ))
        sstate = optim.fedavg_momentum(1.0).init(params)
        p_mr, _, _ = fn(params, sstate, data)

        # manual sequential SGD
        p = params
        for t in range(steps):
            batch = {"tokens": data["tokens"][0, t], "labels": data["labels"][0, t]}
            g = jax.grad(loss_fn)(p, batch)
            p = jax.tree_util.tree_map(
                lambda w, gw: (w.astype(jnp.float32)
                               - 0.1 * gw.astype(jnp.float32)).astype(w.dtype),
                p, g)
        for a, b in zip(jax.tree_util.tree_leaves(p_mr),
                        jax.tree_util.tree_leaves(p)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=2e-4, atol=2e-4,
            )

    def test_diloco_server_optimizer(self, tiny_lm):
        cfg, params, loss_fn = tiny_lm
        n, steps = 4, 4
        server = optim.diloco_optimizer(0.7, 0.9)
        fn = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.05), server,
            LocalSGDConfig(partition_size=n, num_local_steps=steps),
        ))
        sstate = server.init(params)
        losses = []
        for r in range(5):
            data = _round_data(cfg, n, steps, 2, 16, r)
            params, sstate, m = fn(params, sstate, data)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        assert int(sstate["step"]) == 5

    def test_grad_clip_path(self, tiny_lm):
        cfg, params, loss_fn = tiny_lm
        fn = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0),
            LocalSGDConfig(partition_size=2, num_local_steps=1, grad_clip=0.5),
        ))
        sstate = optim.fedavg_momentum(1.0).init(params)
        data = _round_data(cfg, 2, 1, 2, 16)
        p2, _, m = fn(params, sstate, data)
        assert np.isfinite(m["loss"])


class TestFedSGD:
    def test_basic_round(self, tiny_lm):
        cfg, params, loss_fn = tiny_lm
        fn = jax.jit(make_fedsgd_round(
            loss_fn, optim.fedadam(1e-2),
            LocalSGDConfig(partition_size=4, num_local_steps=1),
        ))
        sstate = optim.fedadam(1e-2).init(params)
        data = _round_data(cfg, 4, 1, 2, 16)
        batches = {"tokens": data["tokens"][:, 0], "labels": data["labels"][:, 0]}
        p2, s2, m = fn(params, sstate, batches)
        assert np.isfinite(m["loss"])

    def test_learned_weights_hypergrad(self, tiny_lm):
        """Self-tuning reduction: gradient flows to the reduction weights
        through MapReduce AD (paper §6)."""
        cfg, params, loss_fn = tiny_lm
        n = 4
        fn = make_fedsgd_round(
            loss_fn, optim.fedavg_momentum(1.0),
            LocalSGDConfig(partition_size=n, num_local_steps=1),
            learned_weights=True,
        )
        data = _round_data(cfg, n, 1, 2, 16)
        batches = {"tokens": data["tokens"][:, 0], "labels": data["labels"][:, 0]}
        sstate = optim.fedavg_momentum(1.0).init(params)

        def loss_of_weights(w):
            _, _, m = fn(params, sstate, batches, w)
            return m["loss"]

        g = jax.grad(loss_of_weights)(jnp.zeros((n,)))
        assert g.shape == (n,)
        assert np.any(np.asarray(g) != 0.0)


class TestMAML:
    def test_maml_trains(self):
        # scalar quadratic "model": loss = (w - target)^2
        def loss_fn(w, batch):
            return jnp.mean((w - batch) ** 2)

        maml_loss, train_step = make_parallel_maml(
            loss_fn, partition_size=4, inner_lr=0.1, inner_steps=1
        )
        tasks = {
            "support": jnp.array([1.0, 2.0, 3.0, 4.0]),
            "query": jnp.array([1.5, 2.5, 3.5, 4.5]),
        }
        w = jnp.float32(0.0)
        l0 = maml_loss(w, tasks)
        for _ in range(40):
            w, _ = train_step(w, tasks, outer_lr=0.1)
        l1 = maml_loss(w, tasks)
        assert l1 < l0

    def test_maml_jaxpr_closure(self):
        def loss_fn(w, batch):
            return jnp.mean((w - batch) ** 2)

        maml_loss, _ = make_parallel_maml(loss_fn, partition_size=3)
        tasks = {"support": jnp.zeros(3), "query": jnp.ones(3)}
        counts = drjax.count_primitives(
            jax.make_jaxpr(jax.grad(maml_loss))(jnp.float32(0.0), tasks)
        )
        assert "drjax_reduce_sum" in counts  # grad introduces the transpose


class TestBTM:
    def test_branch_train_merge(self, tiny_lm):
        cfg, params, loss_fn = tiny_lm
        n, steps = 3, 2
        btm = jax.jit(branch_train_merge(
            loss_fn, optim.sgd(0.05), partition_size=n, train_steps=steps,
        ))
        data = _round_data(cfg, n, steps, 2, 16)
        merged, metrics = btm(params, data)
        assert np.isfinite(metrics["mean_final_loss"])
        assert np.isfinite(metrics["max_final_loss"])
        assert metrics["max_final_loss"] >= metrics["mean_final_loss"] - 1e-6
        # merged params still produce finite loss
        batch = {"tokens": data["tokens"][0, 0], "labels": data["labels"][0, 0]}
        assert np.isfinite(loss_fn(merged, batch))


class TestCompression:
    def test_int8_roundtrip_small_error(self):
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (64, 32)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (100,))}
        back = int8_roundtrip(tree)
        for k in tree:
            x, y = np.asarray(tree[k]), np.asarray(back[k])
            cos = (x * y).sum() / (np.linalg.norm(x) * np.linalg.norm(y))
            assert cos > 0.999, k

    def test_topk_keeps_largest(self):
        x = {"w": jnp.array([0.1, -5.0, 0.2, 3.0, -0.05])}
        sp = topk_sparsify(x, fraction=0.4)
        np.testing.assert_allclose(sp["w"], [0, -5.0, 0, 3.0, 0])

    def test_topk_tied_magnitudes_keep_exactly_k(self):
        """Regression: a >=-threshold rule kept MORE than k entries when
        magnitudes tie at the cutoff; selection must keep exactly k."""
        x = {"w": jnp.array([1.0, -2.0, 2.0, -2.0, 3.0])}
        sp = topk_sparsify(x, fraction=0.4)  # k = 2, cutoff |2| ties 3-ways
        kept = np.flatnonzero(np.asarray(sp["w"]))
        assert kept.size == 2
        # the max survives; the tie is broken deterministically (index order)
        np.testing.assert_allclose(sp["w"], [0, -2.0, 0, 0, 3.0])

    def test_topk_all_tied(self):
        x = jnp.ones((8,))
        sp = topk_sparsify(x, fraction=0.5)
        assert int((np.asarray(sp) != 0).sum()) == 4

    def test_error_feedback_reduces_bias(self):
        tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (256,))}
        residual = ErrorFeedback.init(tree)
        total_sent = jax.tree_util.tree_map(jnp.zeros_like, tree)
        for _ in range(20):
            compressed, residual = ErrorFeedback.compress(
                tree, residual, topk_sparsify, 0.1
            )
            total_sent = jax.tree_util.tree_map(
                lambda t, c: t + c, total_sent, compressed
            )
        # over many rounds, average sent ≈ true value (error feedback works)
        avg = np.asarray(total_sent["w"]) / 20
        x = np.asarray(tree["w"])
        cos = (x * avg).sum() / (np.linalg.norm(x) * np.linalg.norm(avg))
        assert cos > 0.95

    def test_compressed_round_still_trains(self, tiny_lm):
        cfg, params, loss_fn = tiny_lm
        fn = jax.jit(make_local_sgd_round(
            loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0),
            LocalSGDConfig(partition_size=2, num_local_steps=2,
                           compression="int8"),
        ))
        sstate = optim.fedavg_momentum(1.0).init(params)
        losses = []
        for r in range(4):
            data = _round_data(cfg, 2, 2, 2, 16, r)
            params, sstate, m = fn(params, sstate, data)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
