"""Regression gate over the shipped dry-run artifacts (deliverable e).

Asserts the 40-cell × 2-mesh sweep (+ paper local-SGD cells) is complete and
every applicable cell compiled. Re-generate with scripts/dryrun_sweep.sh and
`python -m repro.launch.dryrun --paper`.
"""

import glob
import json
import os

import pytest

from repro.models import registry

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "dryrun_results")

ASSIGNED = [a for a in registry.ARCH_IDS if not a.startswith("lm_")]


def _load(name):
    path = os.path.join(RESULTS, name + ".json")
    if not os.path.exists(path):
        pytest.skip(f"dry-run artifact missing: run scripts/dryrun_sweep.sh")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("mesh", ["single", "multi"])
@pytest.mark.parametrize("cell", list(registry.SHAPE_CELLS))
@pytest.mark.parametrize("arch", ASSIGNED)
def test_cell_compiled_or_documented_skip(arch, cell, mesh):
    r = _load(f"{arch}__{cell}__{mesh}")
    cfg = registry.get_config(arch)
    applicable, _ = registry.cell_applicable(cfg, cell)
    if applicable:
        assert r["status"] == "ok", r.get("error", "")
        rf = r["roofline"]
        assert rf["step_time_lower_bound_s"] >= 0
        assert rf["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert r["memory"]["peak_hbm_bytes"] > 0
    else:
        assert r["status"] == "skipped"
        assert arch not in registry.SUBQUADRATIC


@pytest.mark.parametrize("mesh", ["single", "multi"])
@pytest.mark.parametrize("arch", ["lm_350m", "lm_1b", "lm_8b"])
def test_paper_local_sgd_cell_compiled(arch, mesh):
    r = _load(f"{arch}__train_4k__{mesh}__local_sgd")
    assert r["status"] == "ok", r.get("error", "")
    # the round really reduces across groups: collectives present
    assert any(k == "all-reduce" for k in r["collectives"])


def test_subquadratic_archs_run_long_500k():
    for arch in registry.SUBQUADRATIC:
        r = _load(f"{arch}__long_500k__single")
        assert r["status"] == "ok"
        # O(1)-state decode: per-device memory far below full-attention KV
        assert r["memory"]["peak_hbm_bytes"] < 16 * 2**30
