"""Serve-runtime tests: continuous batching vs static waves, slot pool
invariants, chunk scheduling, EOS termination, flat trace counts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.serve import (
    BatchScheduler,
    ContinuousBatchingScheduler,
    Request,
    StaticWaveScheduler,
    chunk_schedule,
)
from repro.models import registry, transformer


def _mkreqs(cfg, seed, lens, max_new, arrivals=None):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (l,)).astype(np.int32),
            max_new=max_new,
            arrival=(arrivals[i] if arrivals else 0.0),
        )
        for i, l in enumerate(lens)
    ]


def _oracle(cfg, params, prompt, max_new, max_len):
    """Greedy reference: full prefill + per-request decode."""
    last, caches = transformer.prefill(
        cfg, params, jnp.asarray(prompt)[None], max_len=max_len
    )
    out, tok = [], jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    for _ in range(max_new):
        out.append(int(tok[0, 0]))
        logits, caches = transformer.decode_step(cfg, params, tok, caches)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return out


# ---------------------------------------------------------------------------
# chunk scheduling
# ---------------------------------------------------------------------------


def test_chunk_schedule_exact_binary_decomposition():
    for n in range(1, 100):
        for cmax in (1, 4, 8, 16, 31):
            chunks = chunk_schedule(n, cmax)
            assert sum(chunks) == n  # exact: NO padding
            assert all(c & (c - 1) == 0 for c in chunks)  # powers of two
            assert all(c <= cmax for c in chunks)
            assert chunks == sorted(chunks, reverse=True)  # largest first
    # bounded executable set: every length maps into log2(cmax)+1 buckets
    buckets = {c for n in range(1, 1000) for c in chunk_schedule(n, 16)}
    assert buckets <= {1, 2, 4, 8, 16}


def test_chunk_schedule_rejects_degenerate():
    with pytest.raises(ValueError):
        chunk_schedule(0, 8)
    with pytest.raises(ValueError):
        chunk_schedule(5, 0)


# ---------------------------------------------------------------------------
# slot-pool metadata (registry)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["stablelm_3b", "rwkv6_3b", "recurrentgemma_2b", "phi35_moe"]
)
def test_slot_pool_layout(arch):
    cfg = registry.get_config(arch).reduced()
    slots, max_len = 3, 16
    pool = registry.init_slot_pool(cfg, slots, max_len)
    dims = registry.cache_batch_dims(cfg)
    leaves = jax.tree_util.tree_leaves(pool)
    dleaves = jax.tree_util.tree_leaves(dims)
    assert len(leaves) == len(dleaves)
    for leaf, d in zip(leaves, dleaves):
        if d == registry.POS_LEAF:
            assert leaf.shape[0] == slots  # pos leaves gain a slot axis
        else:
            assert leaf.shape[d] == slots  # batch leaves carry slots
    assert registry.slot_pool_bytes(cfg, slots, max_len) > 0


def test_chunk_prefill_fn_rejects_non_decoder():
    for arch in ("seamless_m4t_medium", "llava_next_34b"):
        cfg = registry.get_config(arch).reduced()
        with pytest.raises(ValueError):
            registry.make_chunk_prefill_fn(cfg)


# ---------------------------------------------------------------------------
# token identity: continuous == static waves == greedy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "arch", ["stablelm_3b", "rwkv6_3b", "recurrentgemma_2b"]
)
def test_continuous_token_identical_to_static(arch):
    """Mixed prompt lengths, more requests than slots (slot reuse), and
    staggered arrivals (mid-stream admission): the scheduling policy must
    not change a single token."""
    cfg = registry.get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    lens = [6, 13, 8, 3, 9, 5]
    # tiny staggered arrivals: with ~ms steps these trickle in mid-run
    arrivals = [i * 2e-4 for i in range(len(lens))]
    cont = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=32,
                                       chunk=8)
    stat = StaticWaveScheduler(cfg, params, batch=2, max_len=32, chunk=8)
    out_c = cont.run(_mkreqs(cfg, 0, lens, 6, arrivals))
    out_s = stat.run(_mkreqs(cfg, 0, lens, 6, arrivals))
    assert out_c == out_s


def test_moe_single_chunk_token_identical():
    """MoE capacity assignment is per-forward, so chunked prefill only
    matches full prefill when the prompt fits one chunk — the identity
    sweep for MoE uses power-of-two prompts (documented caveat)."""
    cfg = registry.get_config("phi35_moe").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    lens = [8, 4, 16, 8, 2]
    cont = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=32,
                                       chunk=16)
    stat = StaticWaveScheduler(cfg, params, batch=2, max_len=32, chunk=16)
    out_c = cont.run(_mkreqs(cfg, 0, lens, 5))
    out_s = stat.run(_mkreqs(cfg, 0, lens, 5))
    assert out_c == out_s


def test_continuous_matches_greedy_oracle():
    """Continuous batching vs the plain full-prefill + decode reference
    (dense/global attention: chunked prefill is bitwise-equal to full
    prefill, so this must match exactly)."""
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    lens, max_new, max_len = [6, 11, 4], 5, 24
    reqs = _mkreqs(cfg, 0, lens, max_new)
    sched = ContinuousBatchingScheduler(cfg, params, slots=2,
                                        max_len=max_len, chunk=8)
    results = sched.run(reqs)
    for r in reqs:
        assert results[r.rid] == _oracle(cfg, params, r.prompt, max_new,
                                         max_len), f"request {r.rid}"


def test_slot_reuse_is_clean():
    """A scheduler instance reused for a second batch of requests (slots
    zero-reset on admission, no reallocation) must produce the same tokens
    as a fresh instance."""
    cfg = registry.get_config("rwkv6_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    lens = [7, 5, 12]
    sched = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=24,
                                        chunk=8)
    sched.run(_mkreqs(cfg, 9, [10, 3], 6))  # dirty the pool
    reused = sched.run(_mkreqs(cfg, 0, lens, 6))
    fresh = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=24,
                                        chunk=8).run(_mkreqs(cfg, 0, lens, 6))
    assert reused == fresh


# ---------------------------------------------------------------------------
# EOS termination
# ---------------------------------------------------------------------------


def test_eos_stops_slot_and_masks_further_tokens():
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    lens, max_new = [6, 9], 8
    base = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=32,
                                       chunk=8)
    out = base.run(_mkreqs(cfg, 0, lens, max_new))
    # pick a token mid-stream of request 0 and declare it EOS
    eos, cut = out[0][3], 3
    cfg_eos = dataclasses.replace(cfg, eos_id=eos)
    sched = ContinuousBatchingScheduler(cfg_eos, params, slots=2, max_len=32,
                                        chunk=8)
    reqs = _mkreqs(cfg_eos, 0, lens, max_new)
    out_eos = sched.run(reqs)
    # the EOS'd request stops right after emitting EOS...
    assert out_eos[0] == out[0][: cut + 1]
    assert out_eos[0][-1] == eos
    assert reqs[0].done and reqs[0].t_done is not None
    # ...and contributes no further tokens while the other request is
    # unaffected (up to its own possible EOS hits)
    expect_1 = out[1]
    if eos in expect_1:
        expect_1 = expect_1[: expect_1.index(eos) + 1]
    assert out_eos[1] == expect_1


def test_eos_in_static_scheduler():
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    base = StaticWaveScheduler(cfg, params, batch=2, max_len=24, chunk=8)
    out = base.run(_mkreqs(cfg, 0, [6, 6], 6))
    eos = out[0][2]
    cfg_eos = dataclasses.replace(cfg, eos_id=eos)
    sched = StaticWaveScheduler(cfg_eos, params, batch=2, max_len=24, chunk=8)
    out_eos = sched.run(_mkreqs(cfg_eos, 0, [6, 6], 6))
    assert out_eos[0] == out[0][:3]


# ---------------------------------------------------------------------------
# flat trace counts (the steady-state invariant)
# ---------------------------------------------------------------------------


def test_trace_counts_flat_under_arbitrary_traffic():
    """After bucket warmup the executable set is fixed: mixed prompt
    lengths, mid-stream admission and slot reuse must cause ZERO retraces
    of either the fused serve step or the decode step."""
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    sched = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=32,
                                        chunk=8)
    # warmup: 2*chunk-1 touches every bucket {8,4,2,1}
    sched.run(_mkreqs(cfg, 1, [15, 15, 15], 4))
    warm = (sched.prefill_traces, sched.decode_traces)
    assert warm[0] == len(chunk_schedule(15, 8))  # one trace per bucket
    assert warm[1] == 1  # fixed slot shapes: a single decode executable
    # arbitrary traffic: different lengths, staggered arrivals, slot churn
    sched.run(_mkreqs(cfg, 2, [1, 9, 3, 14, 6, 2, 11], 5,
                      arrivals=[i * 1e-4 for i in range(7)]))
    assert (sched.prefill_traces, sched.decode_traces) == warm


def test_static_trace_counts_flat():
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    sched = StaticWaveScheduler(cfg, params, batch=2, max_len=32, chunk=8)
    sched.run(_mkreqs(cfg, 1, [15, 15], 4))
    warm = (sched.prefill_traces, sched.decode_traces)
    sched.run(_mkreqs(cfg, 2, [3, 9, 6, 13], 5))
    assert (sched.prefill_traces, sched.decode_traces) == warm


# ---------------------------------------------------------------------------
# legacy wave API (BatchScheduler name, run_wave entry point)
# ---------------------------------------------------------------------------


def test_wave_greedy_matches_manual_decode():
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    max_new, max_len = 5, 11
    reqs = _mkreqs(cfg, 0, [6, 6], max_new)
    sched = BatchScheduler(cfg, params, batch=2, max_len=max_len)
    results = sched.run_wave(reqs)
    for r in reqs:
        assert results[r.rid] == _oracle(cfg, params, r.prompt, max_new,
                                         max_len), f"request {r.rid}"


def test_wave_handles_uneven_max_new():
    cfg = registry.get_config("rwkv6_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, (4,))
                .astype(np.int32), max_new=2),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, (4,))
                .astype(np.int32), max_new=6),
    ]
    sched = BatchScheduler(cfg, params, batch=2, max_len=12)
    results = sched.run_wave(reqs)
    assert len(results[0]) == 2
    assert len(results[1]) == 6


def test_request_too_long_rejected():
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    sched = ContinuousBatchingScheduler(cfg, params, slots=2, max_len=8)
    with pytest.raises(ValueError):
        sched.run(_mkreqs(cfg, 0, [7], 4))
