"""Serving-layer tests: batch scheduler correctness + continuous decode."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import BatchScheduler, Request
from repro.models import registry, transformer


def test_scheduler_greedy_matches_manual_decode():
    cfg = registry.get_config("stablelm_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32),
        rng.integers(0, cfg.vocab_size, size=(6,)).astype(np.int32),
    ]
    max_new = 5
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    sched = BatchScheduler(cfg, params, batch=2, max_len=6 + max_new)
    results = sched.run_wave(reqs)

    # manual per-request greedy decode
    for i, p in enumerate(prompts):
        toks = jnp.asarray(p)[None]
        last, caches = transformer.prefill(cfg, params, toks,
                                           max_len=6 + max_new)
        expected = []
        tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            expected.append(int(tok[0, 0]))
            logits, caches = transformer.decode_step(cfg, params, tok, caches)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert results[i] == expected, f"request {i}"


def test_scheduler_handles_uneven_max_new():
    cfg = registry.get_config("rwkv6_3b").reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    reqs = [
        Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, (4,))
                .astype(np.int32), max_new=2),
        Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, (4,))
                .astype(np.int32), max_new=6),
    ]
    sched = BatchScheduler(cfg, params, batch=2, max_len=12)
    results = sched.run_wave(reqs)
    assert len(results[0]) == 2
    assert len(results[1]) == 6
