"""Fault tolerance, straggler mitigation, and elastic-scaling tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import (
    ElasticSchedule,
    FailureInjector,
    StragglerSimulator,
    rescale_partition,
    run_with_recovery,
    straggler_mask,
)
from repro.runtime.failure import SimulatedDeviceFailure
from repro.runtime.stragglers import effective_round_time


class TestRecovery:
    def test_recovers_from_injected_failures(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        injector = FailureInjector(fail_at=[7, 13])
        log = []

        def step_fn(step, state):
            injector.check(step)
            log.append(step)
            return {"x": state["x"] + 1.0}

        final, stats = run_with_recovery(
            step_fn, {"x": jnp.float32(0.0)}, num_steps=20,
            checkpoint_mgr=mgr, checkpoint_every=5,
        )
        assert stats["restarts"] == 2
        assert float(final["x"]) == 20.0  # exact replay: no lost/double steps

    def test_exceeding_max_restarts_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))

        def always_fail(step, state):
            raise SimulatedDeviceFailure("boom")

        with pytest.raises(RuntimeError, match="max_restarts"):
            run_with_recovery(
                always_fail, {"x": jnp.float32(0)}, 5, mgr, max_restarts=2
            )

    def test_resume_from_existing_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state0 = {"x": jnp.float32(0.0)}
        mgr.save(10, {"x": jnp.float32(10.0)})

        def step_fn(step, state):
            return {"x": state["x"] + 1.0}

        final, stats = run_with_recovery(step_fn, state0, 15, mgr)
        assert float(final["x"]) == 15.0
        assert stats["completed_steps"] == 5  # only 10..15 re-run


class TestStragglers:
    def test_mask_respects_deadline(self):
        durations = np.array([1.0, 2.0, 50.0, 3.0])
        mask = straggler_mask(durations, deadline_s=10.0)
        np.testing.assert_array_equal(mask, [1, 1, 0, 1])

    def test_min_finishers_extends_deadline(self):
        durations = np.array([100.0, 200.0, 300.0, 400.0])
        mask = straggler_mask(durations, deadline_s=1.0, min_finishers=2)
        assert mask.sum() == 2
        np.testing.assert_array_equal(mask, [1, 1, 0, 0])

    def test_dropping_cuts_round_time(self):
        sim = StragglerSimulator(median_s=10.0, sigma=0.8)
        durations = sim.durations(round_idx=0, n=64)
        t_all = durations.max()
        deadline = float(np.percentile(durations, 90))
        t_drop = effective_round_time(durations, deadline, min_finishers=32)
        assert t_drop < t_all

    def test_masked_round_unbiased(self):
        """Masked mean equals mean over the finishers exactly."""
        from repro import core as drjax

        @drjax.program(partition_size=6)
        def f(xs, mask):
            return drjax.masked_reduce_mean(xs, mask)

        xs = jnp.arange(6, dtype=jnp.float32)
        mask = jnp.array([1, 1, 0, 1, 0, 1], jnp.float32)
        np.testing.assert_allclose(f(xs, mask), (0 + 1 + 3 + 5) / 4.0)


class TestElastic:
    def test_cohort_size_tracks_devices(self):
        sched = ElasticSchedule(groups_per_device=2)
        assert sched.cohort_size(256) == 512
        assert sched.cohort_size(128) == 256  # one pod lost

    def test_available_mesh_shapes_returns_all_viable(self):
        from repro.runtime.elastic import available_mesh_shapes

        # 16 devices, mp=8: every halved fallback also tiles the pool
        shapes = available_mesh_shapes(16, 8)
        assert shapes == [(2, 8), (4, 4), (8, 2), (16, 1)]
        # preferred shape first even when fallbacks exist
        assert available_mesh_shapes(8, 4)[0] == (2, 4)

    def test_available_mesh_shapes_degraded_pool(self):
        from repro.runtime.elastic import available_mesh_shapes

        # 12 devices can't tile mp=8, but can tile 4, 2, 1
        shapes = available_mesh_shapes(12, 8)
        assert shapes == [(3, 4), (6, 2), (12, 1)]
        # a pool that only fits fully-data-parallel
        assert available_mesh_shapes(7, 4) == [(7, 1)]

    def test_rescale_shrink_and_grow(self):
        data = {"tokens": np.arange(8 * 3).reshape(8, 3)}
        small = rescale_partition(data, 8, 4)
        assert small["tokens"].shape == (4, 3)
        big = rescale_partition(data, 8, 12)
        assert big["tokens"].shape == (12, 3)

    def test_same_program_smaller_partition(self):
        """The SAME round function (re-jitted) works at any partition size —
        the paper's logical/physical decoupling is what makes this elastic."""
        import functools
        from repro import optim
        from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
        from repro.models import registry

        cfg = registry.get_config("lm_350m").reduced()
        loss_fn = functools.partial(registry.loss_fn, cfg)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        sstate = optim.fedavg_momentum(1.0).init(params)

        losses = {}
        for n in (8, 4):  # pod loss: 8 -> 4 groups
            round_fn = jax.jit(make_local_sgd_round(
                loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0),
                LocalSGDConfig(partition_size=n, num_local_steps=1),
            ))
            batch = registry.make_concrete_batch(cfg, n, 16)
            data = {
                "tokens": batch["tokens"].reshape(n, 1, 1, 16),
                "labels": batch["labels"].reshape(n, 1, 1, 16),
            }
            _, _, metrics = round_fn(params, sstate, data)
            losses[n] = float(metrics["loss"])
        assert all(np.isfinite(v) for v in losses.values())
