"""Fault tolerance, straggler mitigation, and elastic-scaling tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime import (
    ElasticSchedule,
    FailureInjector,
    StragglerSimulator,
    rescale_partition,
    run_with_recovery,
    straggler_mask,
)
from repro.runtime.failure import SimulatedDeviceFailure
from repro.runtime.stragglers import effective_round_time


class TestRecovery:
    def test_recovers_from_injected_failures(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        injector = FailureInjector(fail_at=[7, 13])
        log = []

        def step_fn(step, state):
            injector.check(step)
            log.append(step)
            return {"x": state["x"] + 1.0}

        final, stats = run_with_recovery(
            step_fn, {"x": jnp.float32(0.0)}, num_steps=20,
            checkpoint_mgr=mgr, checkpoint_every=5,
        )
        assert stats["restarts"] == 2
        assert float(final["x"]) == 20.0  # exact replay: no lost/double steps

    def test_exceeding_max_restarts_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))

        def always_fail(step, state):
            raise SimulatedDeviceFailure("boom")

        with pytest.raises(RuntimeError, match="max_restarts"):
            run_with_recovery(
                always_fail, {"x": jnp.float32(0)}, 5, mgr, max_restarts=2
            )

    def test_resume_from_existing_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state0 = {"x": jnp.float32(0.0)}
        mgr.save(10, {"x": jnp.float32(10.0)})

        def step_fn(step, state):
            return {"x": state["x"] + 1.0}

        final, stats = run_with_recovery(step_fn, state0, 15, mgr)
        assert float(final["x"]) == 15.0
        assert stats["completed_steps"] == 5  # only 10..15 re-run


class TestRecoveryHardening:
    def test_non_recoverable_error_fails_fast(self, tmp_path):
        """Programming bugs propagate immediately — no restarts burned on an
        error every replay would hit again."""
        mgr = CheckpointManager(str(tmp_path))
        calls = []

        def step_fn(step, state):
            calls.append(step)
            raise TypeError("programming bug")

        with pytest.raises(TypeError, match="programming bug"):
            run_with_recovery(
                step_fn, {"x": jnp.float32(0)}, 5, mgr, max_restarts=5
            )
        assert calls == [0]

    def test_custom_recoverable_allowlist(self, tmp_path):
        class FlakyStore(Exception):
            pass

        mgr = CheckpointManager(str(tmp_path))
        fired = []

        def step_fn(step, state):
            if step == 2 and not fired:
                fired.append(step)
                raise FlakyStore("transient")
            return {"x": state["x"] + 1.0}

        final, stats = run_with_recovery(
            step_fn, {"x": jnp.float32(0)}, 5, mgr,
            recoverable=(FlakyStore,),
        )
        assert float(final["x"]) == 5.0
        assert stats["restarts"] == 1

    def test_scratch_restart_does_not_overcount_progress(self, tmp_path):
        """Regression: a restart from the initial state (no checkpoint yet)
        replays the prefix; completed_steps must count forward progress
        once, with the replays tallied separately."""
        mgr = CheckpointManager(str(tmp_path))
        injector = FailureInjector(fail_at=[3])

        def step_fn(step, state):
            injector.check(step)
            return {"x": state["x"] + 1.0}

        final, stats = run_with_recovery(
            step_fn, {"x": jnp.float32(0)}, 5, mgr, checkpoint_every=10
        )
        assert float(final["x"]) == 5.0
        assert stats["scratch_restarts"] == 1
        assert stats["completed_steps"] == 5  # not 5 + the replayed prefix
        assert stats["replayed_steps"] == 3  # steps 0..2 re-run once

    def test_backoff_grows_exponentially(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        injector = FailureInjector(fail_at=[1, 2])

        def step_fn(step, state):
            injector.check(step)
            return {"x": state["x"] + 1.0}

        _, stats = run_with_recovery(
            step_fn, {"x": jnp.float32(0)}, 4, mgr,
            backoff_base_s=0.01, backoff_cap_s=30.0,
        )
        assert stats["restarts"] == 2
        # 0.01 * 2**0 + 0.01 * 2**1
        assert stats["backoff_s"] == pytest.approx(0.03)

    def test_backoff_respects_cap(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        injector = FailureInjector(fail_at=[1, 2, 3])

        def step_fn(step, state):
            injector.check(step)
            return {"x": state["x"] + 1.0}

        _, stats = run_with_recovery(
            step_fn, {"x": jnp.float32(0)}, 5, mgr,
            backoff_base_s=0.01, backoff_cap_s=0.015,
        )
        # 0.01, then 0.02 -> capped at 0.015, then 0.04 -> 0.015
        assert stats["backoff_s"] == pytest.approx(0.04)


class TestStragglers:
    def test_mask_respects_deadline(self):
        durations = np.array([1.0, 2.0, 50.0, 3.0])
        mask = straggler_mask(durations, deadline_s=10.0)
        np.testing.assert_array_equal(mask, [1, 1, 0, 1])

    def test_min_finishers_extends_deadline(self):
        durations = np.array([100.0, 200.0, 300.0, 400.0])
        mask = straggler_mask(durations, deadline_s=1.0, min_finishers=2)
        assert mask.sum() == 2
        np.testing.assert_array_equal(mask, [1, 1, 0, 0])

    def test_dropping_cuts_round_time(self):
        sim = StragglerSimulator(median_s=10.0, sigma=0.8)
        durations = sim.durations(round_idx=0, n=64)
        t_all = durations.max()
        deadline = float(np.percentile(durations, 90))
        t_drop = effective_round_time(durations, deadline, min_finishers=32)
        assert t_drop < t_all

    def test_masked_round_unbiased(self):
        """Masked mean equals mean over the finishers exactly."""
        from repro import core as drjax

        @drjax.program(partition_size=6)
        def f(xs, mask):
            return drjax.masked_reduce_mean(xs, mask)

        xs = jnp.arange(6, dtype=jnp.float32)
        mask = jnp.array([1, 1, 0, 1, 0, 1], jnp.float32)
        np.testing.assert_allclose(f(xs, mask), (0 + 1 + 3 + 5) / 4.0)


class TestStragglerEdgeCases:
    def test_min_finishers_equal_n_is_synchronous(self):
        """min_finishers == n keeps every group and waits for the slowest —
        the synchronous limit."""
        d = np.array([5.0, 50.0, 500.0])
        mask = straggler_mask(d, deadline_s=1.0, min_finishers=3)
        np.testing.assert_array_equal(mask, [1, 1, 1])
        assert effective_round_time(d, 1.0, min_finishers=3) == 500.0

    def test_min_finishers_clamped_to_cohort_size(self):
        d = np.array([5.0, 50.0, 500.0])
        big = straggler_mask(d, deadline_s=1.0, min_finishers=10)
        exact = straggler_mask(d, deadline_s=1.0, min_finishers=3)
        np.testing.assert_array_equal(np.asarray(big), np.asarray(exact))
        assert effective_round_time(d, 1.0, min_finishers=10) == 500.0

    def test_zero_min_finishers_means_no_floor(self):
        d = np.array([1.0, 2.0, 50.0])
        none = straggler_mask(d, deadline_s=10.0, min_finishers=None)
        zero = straggler_mask(d, deadline_s=10.0, min_finishers=0)
        np.testing.assert_array_equal(np.asarray(none), np.asarray(zero))

    def test_all_groups_miss_deadline(self):
        """Without a finisher floor an all-miss round yields the all-zero
        mask and the round ends at the deadline (you waited it out)."""
        d = np.array([20.0, 30.0, 40.0])
        mask = straggler_mask(d, deadline_s=10.0)
        np.testing.assert_array_equal(mask, [0, 0, 0])
        assert effective_round_time(d, 10.0) == 10.0

    def test_zero_weight_mask_composes_nan_free(self):
        """The all-zero mask must flow through masked_reduce_mean as zeros,
        not NaN — straggler_mask + masked reduction stay composable in the
        worst case."""
        from repro import core as drjax

        @drjax.program(partition_size=3)
        def f(xs, mask):
            return drjax.masked_reduce_mean(xs, mask)

        d = np.array([20.0, 30.0, 40.0])
        mask = straggler_mask(d, deadline_s=10.0)
        out = np.asarray(f(jnp.array([1.0, 2.0, 3.0]), mask))
        np.testing.assert_array_equal(out, 0.0)

    def test_min_finishers_floor_still_nan_free(self):
        """min_finishers > 0 on an all-miss round extends the deadline, so
        the mask is non-zero and the masked mean is over the k finishers."""
        from repro import core as drjax

        @drjax.program(partition_size=3)
        def f(xs, mask):
            return drjax.masked_reduce_mean(xs, mask)

        d = np.array([20.0, 30.0, 40.0])
        mask = straggler_mask(d, deadline_s=10.0, min_finishers=2)
        np.testing.assert_array_equal(np.asarray(mask), [1, 1, 0])
        np.testing.assert_allclose(
            np.asarray(f(jnp.array([1.0, 2.0, 3.0]), mask)), 1.5
        )
        assert effective_round_time(d, 10.0, min_finishers=2) == 30.0


class TestElastic:
    def test_cohort_size_tracks_devices(self):
        sched = ElasticSchedule(groups_per_device=2)
        assert sched.cohort_size(256) == 512
        assert sched.cohort_size(128) == 256  # one pod lost

    def test_available_mesh_shapes_returns_all_viable(self):
        from repro.runtime.elastic import available_mesh_shapes

        # 16 devices, mp=8: every halved fallback also tiles the pool
        shapes = available_mesh_shapes(16, 8)
        assert shapes == [(2, 8), (4, 4), (8, 2), (16, 1)]
        # preferred shape first even when fallbacks exist
        assert available_mesh_shapes(8, 4)[0] == (2, 4)

    def test_available_mesh_shapes_degraded_pool(self):
        from repro.runtime.elastic import available_mesh_shapes

        # 12 devices can't tile mp=8, but can tile 4, 2, 1
        shapes = available_mesh_shapes(12, 8)
        assert shapes == [(3, 4), (6, 2), (12, 1)]
        # a pool that only fits fully-data-parallel
        assert available_mesh_shapes(7, 4) == [(7, 1)]

    def test_available_mesh_shapes_placement_stack(self):
        """N-level form: inner levels keep their sizes, the OUTERMOST level
        absorbs the degraded pool, axis names come from
        launch.mesh.level_axes_for."""
        from repro.runtime.elastic import available_mesh_shapes

        # full 8-device (pods, clients) pool, one pod lost (6 devices left)
        shapes = available_mesh_shapes(
            6, placements={"pods": 4, "clients": 2}
        )
        assert shapes == [((3, 2), ("pod", "data"))]
        # model parallelism appends the "model" axis, halved fallbacks too
        shapes = available_mesh_shapes(
            16, 4, placements={"pods": 4, "clients": 2}
        )
        assert shapes == [
            ((2, 2, 4), ("pod", "data", "model")),
            ((4, 2, 2), ("pod", "data", "model")),
            ((8, 2, 1), ("pod", "data", "model")),
        ]
        # 3-level superpod stack: only the outermost (superpod) level scales
        shapes = available_mesh_shapes(
            12, placements={"superpods": 2, "pods": 3, "clients": 2}
        )
        assert shapes == [((2, 3, 2), ("superpod", "pod", "data"))]
        # a pool the inner levels can't tile yields no shapes
        assert available_mesh_shapes(
            5, placements={"pods": 4, "clients": 2}
        ) == []

    def test_rescale_shrink_and_grow(self):
        data = {"tokens": np.arange(8 * 3).reshape(8, 3)}
        small = rescale_partition(data, 8, 4)
        assert small["tokens"].shape == (4, 3)
        big = rescale_partition(data, 8, 12)
        assert big["tokens"].shape == (12, 3)

    def test_same_program_smaller_partition(self):
        """The SAME round function (re-jitted) works at any partition size —
        the paper's logical/physical decoupling is what makes this elastic."""
        import functools
        from repro import optim
        from repro.algorithms.rounds import LocalSGDConfig, make_local_sgd_round
        from repro.models import registry

        cfg = registry.get_config("lm_350m").reduced()
        loss_fn = functools.partial(registry.loss_fn, cfg)
        params = registry.init_params(jax.random.PRNGKey(0), cfg)
        sstate = optim.fedavg_momentum(1.0).init(params)

        losses = {}
        for n in (8, 4):  # pod loss: 8 -> 4 groups
            round_fn = jax.jit(make_local_sgd_round(
                loss_fn, optim.sgd(0.05), optim.fedavg_momentum(1.0),
                LocalSGDConfig(partition_size=n, num_local_steps=1),
            ))
            batch = registry.make_concrete_batch(cfg, n, 16)
            data = {
                "tokens": batch["tokens"].reshape(n, 1, 1, 16),
                "labels": batch["labels"].reshape(n, 1, 1, 16),
            }
            _, _, metrics = round_fn(params, sstate, data)
            losses[n] = float(metrics["loss"])
        assert all(np.isfinite(v) for v in losses.values())
