"""Sharding-annotation tests (paper §3 "Sharding DrJAX computations", Fig. 6).

These must run with multiple XLA host devices, but the device count is locked
at first JAX init — and the rest of the suite must see ONE device. So each
test here runs a small script in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> dict:
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from repro import core as drjax
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr}\nstdout:\n{out.stdout}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_partitioned_value_is_sharded_over_data_axis():
    res = _run(
        """
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))

        @drjax.program(partition_size=8, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)          # (8, 1024) partitioned
            z = drjax.map_fn(lambda a: a * 2.0, y)
            return drjax.reduce_sum(z)

        x = jnp.ones((1024,), jnp.float32)
        with jax.set_mesh(mesh):
            lowered = jax.jit(f).lower(x)
            compiled = lowered.compile()
        # output correct under sharding
        np.testing.assert_allclose(np.asarray(jax.jit(f)(x)), 16.0 * np.ones(1024))
        mem = compiled.memory_analysis()
        print(json.dumps({"temp": mem.temp_size_in_bytes,
                          "ok": True}))
        """
    )
    assert res["ok"]


@pytest.mark.slow
def test_ns_ablation_memory_blowup():
    """DrJAX vs DrJAX-NS: without annotations the partitioned intermediate is
    replicated per device; with annotations it is sharded 1/m. (Fig. 6)"""
    res = _run(
        """
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        D = 256

        def build(use_ann):
            @drjax.program(partition_size=8, partition_axes="data", mesh=mesh,
                           use_sharding_annotations=use_ann)
            def f(w):
                wb = drjax.broadcast(w)                  # (8, D, D) model copies

                def local_steps(wi):
                    # two dependent "local steps": matmuls force the
                    # partitioned copies to materialize (no full fusion).
                    for _ in range(2):
                        wi = jnp.tanh(wi @ wi)
                    return wi

                z = drjax.map_fn(local_steps, wb)
                return drjax.reduce_mean(z)
            return f

        from jax.sharding import NamedSharding, PartitionSpec as P
        w = jax.ShapeDtypeStruct((D, D), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, None)))
        stats = {}
        for name, ann in [("drjax", True), ("ns", False)]:
            with jax.set_mesh(mesh):
                c = jax.jit(build(ann)).lower(w).compile()
            m = c.memory_analysis()
            stats[name] = m.temp_size_in_bytes
        print(json.dumps(stats))
        """
    )
    # with annotations the big (8, D) partitioned temps live sharded (1/8 per
    # device); the NS program keeps at least one fully-replicated copy.
    assert res["drjax"] < res["ns"], res


@pytest.mark.slow
def test_logical_partition_decoupled_from_device_count():
    """partition_size n shards over m devices for any m | n (paper §3)."""
    res = _run(
        """
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))

        @drjax.program(partition_size=32, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)      # 32 logical groups over 8 devices
            z = drjax.map_fn(lambda a: a ** 2, y)
            return drjax.reduce_sum(z)

        with jax.set_mesh(mesh):
            out = jax.jit(f)(jnp.float32(2.0))
        print(json.dumps({"out": float(out)}))
        """
    )
    assert res["out"] == 32 * 4.0


@pytest.mark.slow
def test_spmd_axis_name_annotates_map_intermediates():
    """map_fn must pass spmd_axis_name so intermediates carry the data axis."""
    res = _run(
        """
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))

        @drjax.program(partition_size=8, partition_axes="data", mesh=mesh)
        def f(x):
            y = drjax.broadcast(x)
            z = drjax.map_fn(lambda a: jnp.sin(a) * jnp.cos(a), y)
            return z

        x = jnp.ones((64,), jnp.float32)
        with jax.set_mesh(mesh):
            lowered = jax.jit(f).lower(x)
        txt = lowered.as_text()
        print(json.dumps({"has_sharding": "sharding" in txt}))
        """
    )
    assert res["has_sharding"]
